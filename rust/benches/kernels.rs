//! Kernel micro-bench: scalar baseline vs pooled chunk-parallel kernels
//! on large flats, plus the lane-level arms — strict scalar sweeps
//! (`parallel::lanes::scalar`) vs the unrolled lane kernels
//! (`parallel::lanes`) on an L2-resident chunk — and the zero-alloc
//! steady-state assertions for the collectives, optimizer, and lane
//! paths (counting global allocator, as in `benches/compress.rs`).
//!
//!     cargo bench --bench kernels [-- --quick]
//!
//! `--quick` shrinks sizes/durations for the CI smoke step. Results
//! (µs/iter per arm, speedup, allocs/iter) land in `BENCH_kernels.json`
//! at the repo root — the perf-trajectory artifact. The `lanes` rows
//! marked `gated` carry the ≥2× `lane_speedup` floor enforced by
//! `scripts/bench_gate.py`.

use std::time::Instant;

use detonation::collectives::{ring_all_reduce_avg, ring_reduce_scatter_avg, CollCtx, CollScratch};
use detonation::dct::{Dct, DctScratch};
use detonation::net::{NetModel, Topology, TrafficMatrix};
use detonation::optim::{OptSpec, Optimizer};
use detonation::parallel::{lanes, PoolHandle, WorkerPool, CHUNK};
use detonation::runtime::Runtime;
use detonation::tensor;
use detonation::util::json::Json;
use detonation::util::rng::Rng;

#[path = "util/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_count, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Time `f`: (micros/iter, allocs/iter).
fn bench<F: FnMut()>(budget: f64, mut f: F) -> (f64, f64) {
    for _ in 0..3 {
        f();
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < budget {
        f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (alloc_count() - a0) as f64 / iters as f64;
    (dt / iters as f64 * 1e6, allocs)
}

struct Row {
    name: &'static str,
    scalar_us: f64,
    pooled_us: f64,
    pooled_allocs: f64,
}

impl Row {
    fn print(&self) {
        println!(
            "{:<28} scalar {:>9.1} µs  pooled {:>9.1} µs  speedup {:>5.2}x  {:>6.1} allocs/iter",
            self.name,
            self.scalar_us,
            self.pooled_us,
            self.scalar_us / self.pooled_us,
            self.pooled_allocs
        );
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("scalar_micros_per_iter", Json::Num(self.scalar_us)),
            ("pooled_micros_per_iter", Json::Num(self.pooled_us)),
            ("speedup", Json::Num(self.scalar_us / self.pooled_us)),
            ("pooled_allocs_per_iter", Json::Num(self.pooled_allocs)),
        ])
    }
}

struct LaneRow {
    name: &'static str,
    scalar_us: f64,
    vector_us: f64,
    vector_allocs: f64,
    /// Carries the ≥2× `lane_speedup` floor in `scripts/bench_gate.py`.
    gated: bool,
}

impl LaneRow {
    fn print(&self) {
        println!(
            "{:<28} scalar {:>9.2} µs  vector {:>9.2} µs  lane speedup {:>5.2}x{}",
            self.name,
            self.scalar_us,
            self.vector_us,
            self.scalar_us / self.vector_us,
            if self.gated { "  [gated >=2x]" } else { "" }
        );
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("scalar_micros_per_iter", Json::Num(self.scalar_us)),
            ("vector_micros_per_iter", Json::Num(self.vector_us)),
            ("lane_speedup", Json::Num(self.scalar_us / self.vector_us)),
            ("vector_allocs_per_iter", Json::Num(self.vector_allocs)),
            ("gated", Json::Bool(self.gated)),
        ])
    }
}

/// Count allocations of exactly one steady-state invocation.
fn allocs_of<F: FnMut()>(mut f: F) -> u64 {
    f(); // warm
    let a0 = alloc_count();
    f();
    alloc_count() - a0
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = if quick { 0.05 } else { 0.4 };
    let n: usize = if quick { 1 << 18 } else { 1 << 22 };
    let pool = WorkerPool::new(0);
    println!(
        "kernels bench: n = {n} elements, pool width = {} ({})",
        pool.width(),
        if quick { "quick" } else { "full" }
    );
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let mut rows: Vec<Row> = Vec::new();

    // -- axpy ------------------------------------------------------------
    let mut y = vec![0.0f32; n];
    let (scalar_us, _) = bench(budget, || {
        tensor::axpy(&mut y, 0.5, &x);
        std::hint::black_box(y[0]);
    });
    let (pooled_us, pooled_allocs) = bench(budget, || {
        tensor::axpy_pooled(&pool, &mut y, 0.5, &x);
        std::hint::black_box(y[0]);
    });
    rows.push(Row {
        name: "axpy",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });

    // -- mean_into (4 parts) ---------------------------------------------
    let parts_data: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 + 0.5; n]).collect();
    let parts: Vec<&[f32]> = parts_data.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; n];
    let (scalar_us, _) = bench(budget, || {
        tensor::mean_into(&mut out, &parts);
        std::hint::black_box(out[0]);
    });
    let (pooled_us, pooled_allocs) = bench(budget, || {
        tensor::mean_into_pooled(&pool, &mut out, &parts);
        std::hint::black_box(out[0]);
    });
    rows.push(Row {
        name: "mean_into g=4",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });

    // -- collectives (g=4): scalar baseline = the pre-PR alloc-per-call
    // loops, spelled out; pooled = the shipped zero-alloc kernels.
    let g = 4usize;
    let topo = Topology::new(1, g);
    let net = NetModel::hpc();
    let traffic = TrafficMatrix::new(1);
    let mut scratch = CollScratch::new();
    let shards: Vec<(usize, usize)> = (0..g).map(|i| (i * n / g, (i + 1) * n / g)).collect();
    let mut bufs: Vec<Vec<f32>> = (0..g).map(|i| vec![i as f32 + 1.0; n]).collect();

    let baseline_all_reduce = |bufs: &mut [Vec<f32>]| {
        let mut acc = vec![0.0f32; n];
        for b in bufs.iter() {
            tensor::axpy(&mut acc, 1.0, b);
        }
        let inv = 1.0 / g as f32;
        for v in acc.iter_mut() {
            *v *= inv;
        }
        for b in bufs.iter_mut() {
            b.copy_from_slice(&acc);
        }
    };
    let (scalar_us, _) = bench(budget, || {
        baseline_all_reduce(&mut bufs);
        std::hint::black_box(bufs[0][0]);
    });
    let mut ctx = CollCtx {
        topo: &topo,
        model: &net,
        traffic: &traffic,
        pool: &pool,
        scratch: &mut scratch,
    };
    let group: Vec<usize> = (0..g).collect();
    let (pooled_us, pooled_allocs) = bench(budget, || {
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        ring_all_reduce_avg(&mut ctx, &group, &mut refs);
        std::hint::black_box(bufs[0][0]);
    });
    rows.push(Row {
        name: "ring_all_reduce_avg g=4",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });
    // zero-alloc assertion (steady state): the refs Vec is the caller's;
    // the collective itself must not allocate.
    let coll_allocs = {
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        ring_all_reduce_avg(&mut ctx, &group, &mut refs); // warm
        let a0 = alloc_count();
        ring_all_reduce_avg(&mut ctx, &group, &mut refs);
        alloc_count() - a0
    };
    assert_eq!(
        coll_allocs, 0,
        "steady-state ring_all_reduce_avg allocated {coll_allocs} times"
    );

    let baseline_reduce_scatter = |bufs: &mut [Vec<f32>]| {
        let inv = 1.0 / g as f32;
        for (i, &(lo, hi)) in shards.iter().enumerate() {
            let mut acc = vec![0.0f32; hi - lo];
            for b in bufs.iter() {
                tensor::axpy(&mut acc, 1.0, &b[lo..hi]);
            }
            for v in acc.iter_mut() {
                *v *= inv;
            }
            bufs[i][lo..hi].copy_from_slice(&acc);
        }
    };
    let (scalar_us, _) = bench(budget, || {
        baseline_reduce_scatter(&mut bufs);
        std::hint::black_box(bufs[0][0]);
    });
    let (pooled_us, pooled_allocs) = bench(budget, || {
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        ring_reduce_scatter_avg(&mut ctx, &group, &mut refs, &shards);
        std::hint::black_box(bufs[0][0]);
    });
    rows.push(Row {
        name: "ring_reduce_scatter g=4",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });
    let rs_allocs = {
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        ring_reduce_scatter_avg(&mut ctx, &group, &mut refs, &shards); // warm
        let a0 = alloc_count();
        ring_reduce_scatter_avg(&mut ctx, &group, &mut refs, &shards);
        alloc_count() - a0
    };
    assert_eq!(
        rs_allocs, 0,
        "steady-state ring_reduce_scatter_avg allocated {rs_allocs} times"
    );

    // -- optimizers: scalar baseline = the pre-PR two-pass update --------
    let grad = &x;
    let mut params = vec![1.0f32; n];

    // demo-sgd accumulate + apply (wd on, so the fused decay path runs)
    let mut scalar_opt = OptSpec::parse("demo-sgd:wd=0.01")?.build(n);
    let baseline_apply = |params: &mut [f32], q: &[f32], lr: f32, wd: f32| {
        let decay = 1.0 - lr * wd;
        for p in params.iter_mut() {
            *p *= decay;
        }
        tensor::axpy(params, -lr, q);
    };
    let (scalar_us, _) = bench(budget, || {
        scalar_opt.accumulate(grad);
        baseline_apply(&mut params, grad, 1e-3, 0.01);
        std::hint::black_box(params[0]);
    });
    let mut pooled_opt = OptSpec::parse("demo-sgd:wd=0.01")?.build(n);
    pooled_opt.attach_pool(PoolHandle::new(pool.clone()));
    let (pooled_us, pooled_allocs) = bench(budget, || {
        pooled_opt.accumulate(grad);
        pooled_opt.apply(&mut params, grad, 1e-3);
        std::hint::black_box(params[0]);
    });
    rows.push(Row {
        name: "demo-sgd accumulate+apply",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });
    let opt_allocs = allocs_of(|| {
        pooled_opt.accumulate(grad);
        pooled_opt.apply(&mut params, grad, 1e-3);
    });
    assert_eq!(
        opt_allocs, 0,
        "steady-state demo-sgd step allocated {opt_allocs} times"
    );

    // adamw apply (the heaviest per-element chain)
    let mut scalar_adam = AdamScalarBaseline::new(n);
    let (scalar_us, _) = bench(budget, || {
        scalar_adam.apply(&mut params, grad, 1e-3);
        std::hint::black_box(params[0]);
    });
    let mut pooled_adam = OptSpec::parse("adamw:wd=0.01")?.build(n);
    pooled_adam.attach_pool(PoolHandle::new(pool.clone()));
    let (pooled_us, pooled_allocs) = bench(budget, || {
        pooled_adam.apply(&mut params, grad, 1e-3);
        std::hint::black_box(params[0]);
    });
    rows.push(Row {
        name: "adamw apply",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });
    let adam_allocs = allocs_of(|| {
        pooled_adam.apply(&mut params, grad, 1e-3);
    });
    assert_eq!(adam_allocs, 0, "steady-state adamw apply allocated {adam_allocs} times");

    // decoupled-adamw accumulate (fused moments + buffer push)
    let mut scalar_dadam = OptSpec::parse("decoupled-adamw")?.build(n);
    let (scalar_us, _) = bench(budget, || {
        scalar_dadam.accumulate(grad);
        std::hint::black_box(scalar_dadam.buffer_mut()[0]);
    });
    let mut pooled_dadam = OptSpec::parse("decoupled-adamw")?.build(n);
    pooled_dadam.attach_pool(PoolHandle::new(pool.clone()));
    let (pooled_us, pooled_allocs) = bench(budget, || {
        pooled_dadam.accumulate(grad);
        std::hint::black_box(pooled_dadam.buffer_mut()[0]);
    });
    rows.push(Row {
        name: "decoupled-adamw accumulate",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });

    // -- surrogate eval step ---------------------------------------------
    let rt = Runtime::cpu()?;
    let model = rt.load_model(std::path::Path::new("artifacts"), "synthetic-lm")?;
    let flat = model.manifest.init_flat(3);
    let task = detonation::data::task_for(&model.manifest, 3);
    let batch = task.val_batch(0);
    let (scalar_us, _) = bench(budget, || {
        std::hint::black_box(model.eval_step(&flat, &batch).unwrap());
    });
    let (pooled_us, pooled_allocs) = bench(budget, || {
        std::hint::black_box(model.eval_step_pooled(&flat, &batch, &pool).unwrap());
    });
    rows.push(Row {
        name: "surrogate eval_step",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });

    // -- DCT block batch forward ------------------------------------------
    let chunk = 64usize;
    let d = Dct::plan(chunk);
    let sig = &x[..n - n % chunk];
    let mut coeffs = vec![0.0f32; sig.len()];
    let mut serial_scratch = DctScratch::new();
    let (scalar_us, _) = bench(budget, || {
        d.forward_chunked_with(sig, &mut coeffs, &mut serial_scratch);
        std::hint::black_box(coeffs[0]);
    });
    let mut ws: Vec<DctScratch> = (0..pool.width()).map(|_| DctScratch::new()).collect();
    let (pooled_us, pooled_allocs) = bench(budget, || {
        d.forward_chunked_pooled(sig, &mut coeffs, &pool, &mut ws);
        std::hint::black_box(coeffs[0]);
    });
    rows.push(Row {
        name: "dct forward_chunked c=64",
        scalar_us,
        pooled_us,
        pooled_allocs,
    });

    // -- lane kernels: strict scalar sweep vs unrolled lane arm -----------
    // Working set = one grid chunk (CHUNK elements, L2-resident), so both
    // arms are compute-bound and `lane_speedup` measures the explicit
    // unrolling rather than memory bandwidth. The scalar arm is
    // `parallel::lanes::scalar` — the pre-lane per-element sweeps with a
    // black_box-pinned loop index, so the auto-vectorizer cannot quietly
    // turn the baseline into SIMD. Rows marked `gated` carry the ≥2×
    // floor in scripts/bench_gate.py; every lane arm is asserted
    // allocation-free in steady state.
    let m = CHUNK;
    let mut lane_rows: Vec<LaneRow> = Vec::new();

    // fused decay step: the demo-sgd / sgd / decoupled-adamw apply path
    let q = &x[..m];
    let mut p = vec![1.0f32; m];
    let (scalar_us, _) = bench(budget, || {
        lanes::scalar::decay_step(&mut p, 0.99, 1e-3, q);
        std::hint::black_box(p[0]);
    });
    let (vector_us, vector_allocs) = bench(budget, || {
        lanes::decay_step(&mut p, 0.99, 1e-3, q);
        std::hint::black_box(p[0]);
    });
    assert_eq!(
        allocs_of(|| lanes::decay_step(&mut p, 0.99, 1e-3, q)),
        0,
        "lane decay_step allocated"
    );
    lane_rows.push(LaneRow {
        name: "fused_decay_step",
        scalar_us,
        vector_us,
        vector_allocs,
        gated: true,
    });

    // collective reduce: the g-way accumulate + average inner loop of
    // ring_all_reduce_avg / ring_reduce_scatter_avg, per chunk
    let parts4: Vec<&[f32]> = (0..4).map(|i| &x[i * m..(i + 1) * m]).collect();
    let mut acc = vec![0.0f32; m];
    let (scalar_us, _) = bench(budget, || {
        acc.fill(0.0);
        for part in &parts4 {
            lanes::scalar::axpy(&mut acc, 1.0, part);
        }
        lanes::scalar::scale(&mut acc, 0.25);
        std::hint::black_box(acc[0]);
    });
    let (vector_us, vector_allocs) = bench(budget, || {
        acc.fill(0.0);
        for part in &parts4 {
            lanes::axpy(&mut acc, 1.0, part);
        }
        lanes::scale(&mut acc, 0.25);
        std::hint::black_box(acc[0]);
    });
    assert_eq!(
        allocs_of(|| {
            for part in &parts4 {
                lanes::axpy(&mut acc, 1.0, part);
            }
            lanes::scale(&mut acc, 0.25);
        }),
        0,
        "lane collective reduce allocated"
    );
    lane_rows.push(LaneRow {
        name: "collective_reduce",
        scalar_us,
        vector_us,
        vector_allocs,
        gated: true,
    });

    // residual scatter: sparse DCT-III accumulation (the extract hot
    // path). Vector arm = the shipped `inverse_sparse`; scalar arm = the
    // same k strict-scalar axpys of `chunk`-length rows.
    let idx: Vec<u32> = vec![0, 3, 9, 17, 25, 33, 47, 62];
    let vals: Vec<f32> = idx.iter().map(|&i| 1.0 + i as f32 * 0.25).collect();
    let mut out64 = vec![0.0f32; chunk];
    let mut ds = DctScratch::new();
    let reps = m / chunk;
    let (scalar_us, _) = bench(budget, || {
        for _ in 0..reps {
            out64.fill(0.0);
            for (&i, &v) in idx.iter().zip(&vals) {
                let row = &x[i as usize * chunk..(i as usize + 1) * chunk];
                lanes::scalar::axpy(&mut out64, v, row);
            }
        }
        std::hint::black_box(out64[0]);
    });
    let (vector_us, vector_allocs) = bench(budget, || {
        for _ in 0..reps {
            d.inverse_sparse(0, &idx, &vals, &mut out64, &mut ds);
        }
        std::hint::black_box(out64[0]);
    });
    assert_eq!(
        allocs_of(|| d.inverse_sparse(0, &idx, &vals, &mut out64, &mut ds)),
        0,
        "sparse scatter allocated"
    );
    lane_rows.push(LaneRow {
        name: "residual_scatter",
        scalar_us,
        vector_us,
        vector_allocs,
        gated: true,
    });

    // adamw fused moments+step sweep (reported, ungated: division and
    // sqrt dominate both arms, so the lane win is structurally smaller)
    let consts = lanes::AdamConsts {
        beta1: 0.9,
        beta2: 0.999,
        bc1: 1.0 - 0.9f32.powi(8),
        bc2: 1.0 - 0.999f32.powi(8),
        eps: 1e-8,
    };
    let mut m1 = vec![0.0f32; m];
    let mut m2 = vec![0.0f32; m];
    let mut pb = vec![1.0f32; m];
    let (scalar_us, _) = bench(budget, || {
        lanes::scalar::adamw_step(&mut m1, &mut m2, &mut pb, q, consts, 1e-3, 0.01);
        std::hint::black_box(pb[0]);
    });
    let (vector_us, vector_allocs) = bench(budget, || {
        lanes::adamw_step(&mut m1, &mut m2, &mut pb, q, consts, 1e-3, 0.01);
        std::hint::black_box(pb[0]);
    });
    assert_eq!(
        allocs_of(|| lanes::adamw_step(&mut m1, &mut m2, &mut pb, q, consts, 1e-3, 0.01)),
        0,
        "lane adamw_step allocated"
    );
    lane_rows.push(LaneRow {
        name: "adamw_moments_step",
        scalar_us,
        vector_us,
        vector_allocs,
        gated: false,
    });

    // eval reduction (reported, ungated: the one reassociated kernel)
    let t = &x[m..2 * m];
    let (scalar_us, _) = bench(budget, || {
        std::hint::black_box(lanes::scalar::sq_dev_half_sum(q, t));
    });
    let (vector_us, vector_allocs) = bench(budget, || {
        std::hint::black_box(lanes::sq_dev_half_sum(q, t));
    });
    assert_eq!(
        allocs_of(|| {
            std::hint::black_box(lanes::sq_dev_half_sum(q, t));
        }),
        0,
        "lane sq_dev_half_sum allocated"
    );
    lane_rows.push(LaneRow {
        name: "eval_sq_dev_sum",
        scalar_us,
        vector_us,
        vector_allocs,
        gated: false,
    });

    println!();
    for r in &rows {
        r.print();
    }
    println!();
    for r in &lane_rows {
        r.print();
    }
    let best = rows
        .iter()
        .map(|r| r.scalar_us / r.pooled_us)
        .fold(0.0f64, f64::max);
    println!("\nbest kernel speedup: {best:.2}x (pool width {})", pool.width());
    println!("steady-state allocations: collectives 0, optimizer 0, lane kernels 0 (asserted)");

    let out = Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        ("elements", Json::Num(n as f64)),
        ("lane_elements", Json::Num(m as f64)),
        ("lane_width_f32", Json::Num(lanes::F32_LANES as f64)),
        ("lane_width_f64", Json::Num(lanes::F64_LANES as f64)),
        ("pool_width", Json::Num(pool.width() as f64)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows.iter().map(Row::json).collect())),
        ("lanes", Json::Arr(lane_rows.iter().map(LaneRow::json).collect())),
        ("best_speedup", Json::Num(best)),
        ("collectives_steady_state_allocs", Json::Num(0.0)),
        ("optimizer_steady_state_allocs", Json::Num(0.0)),
        ("vector_steady_state_allocs", Json::Num(0.0)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_kernels.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// The pre-PR AdamW apply, spelled out: the same float chain as
/// `optim::AdamW::apply` but single-threaded (scalar timing baseline).
struct AdamScalarBaseline {
    m1: Vec<f32>,
    m2: Vec<f32>,
    t: u64,
}

impl AdamScalarBaseline {
    fn new(n: usize) -> AdamScalarBaseline {
        AdamScalarBaseline {
            m1: vec![0.0; n],
            m2: vec![0.0; n],
            t: 0,
        }
    }

    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32) {
        let (b1, b2, eps, wd) = (0.9f32, 0.999f32, 1e-8f32, 0.01f32);
        self.t += 1;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = q[i];
            self.m1[i] = b1 * self.m1[i] + (1.0 - b1) * g;
            self.m2[i] = b2 * self.m2[i] + (1.0 - b2) * g * g;
            let mhat = self.m1[i] / bc1;
            let vhat = self.m2[i] / bc2;
            if wd > 0.0 {
                params[i] *= 1.0 - lr * wd;
            }
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}
