//! Figure-regeneration harness: one entry per table/figure in the paper's
//! evaluation (DESIGN.md §4 experiment index).
//!
//!     cargo bench --bench figures            # regenerate everything
//!     cargo bench --bench figures -- fig3 fig10
//!
//! Each figure trains the scaled-down substitute workloads (DESIGN.md §2)
//! and writes CSV series + a summary into `results/<fig>/`, printing the
//! same rows/series the paper reports. Absolute losses differ from the
//! paper (different data/scale by necessity); the *shape* — who wins, by
//! roughly what factor, where crossovers fall — is the reproduction
//! target and is asserted in EXPERIMENTS.md.
//!
//! Step counts scale with DETONATION_FIG_STEPS (default 150).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::net::NetModel;
use detonation::replicate::ReplSpec;
use detonation::runtime::Runtime;
use detonation::train::Trainer;
use detonation::util::{fmt_bytes, fmt_secs};

fn steps() -> u64 {
    std::env::var("DETONATION_FIG_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150)
}

/// Paper-scale reference sizes for the latency-scaled network model
/// (NetModel::paper_scaled): OLMo2-1B, T5-Large, ViT-B.
fn paper_params(model: &str) -> f64 {
    match model.split('-').next().unwrap_or("") {
        "lm" => 1.2e9,
        "seq2seq" => 737e6,
        "vit" => 86e6,
        _ => 1e9,
    }
}

fn our_params(model: &str) -> usize {
    let meta = std::fs::read_to_string(format!("artifacts/{model}.meta.json"))
        .expect("run `make artifacts` first");
    detonation::runtime::Manifest::parse(&meta)
        .expect("manifest")
        .param_count
}

fn base(model: &str, nodes: usize, accels: usize) -> ExperimentConfig {
    ExperimentConfig {
        model: model.into(),
        nodes,
        accels_per_node: accels,
        steps: steps(),
        val_every: (steps() / 5).max(1),
        val_batches: 8,
        lr: 1e-3,
        net: NetModel::paper_scaled(our_params(model), paper_params(model)),
        ..Default::default()
    }
}

fn run_specs(
    rt: &Runtime,
    exp: &mut Experiment,
    base_cfg: &ExperimentConfig,
    specs: &[(&str, &str, &str)], // (label, opt, repl)
) -> Result<()> {
    for (label, opt, repl) in specs {
        let mut cfg = base_cfg.clone();
        cfg.apply_arg("opt", opt)?;
        cfg.apply_arg("repl", repl)?;
        exp.run(rt, &cfg, Some(label))?;
    }
    Ok(())
}

/// Fig 1: DeMo-SGD vs Decoupled-AdamW across replication schemes on the
/// translation task, bandwidth held constant across schemes.
/// Bandwidth matching: random/striding ship values only, so at equal wire
/// budget they carry 2× DeMo's components (paper §Replication Schemes).
fn fig1(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig1", &results_root());
    let cfg = base("seq2seq-tiny", 2, 2);
    run_specs(
        rt,
        &mut exp,
        &cfg,
        &[
            ("sgd-demo", "demo-sgd", "demo:1/16"),
            ("sgd-random", "demo-sgd", "random:1/8"),
            ("sgd-striding", "demo-sgd", "striding:1/8"),
            ("sgd-diloco", "demo-sgd", "diloco:8"),
            ("adamw-demo", "decoupled-adamw", "demo:1/16"),
            ("adamw-random", "decoupled-adamw", "random:1/8"),
            ("adamw-striding", "decoupled-adamw", "striding:1/8"),
            ("adamw-diloco", "decoupled-adamw", "diloco:8"),
        ],
    )?;
    println!("\n--- Fig 1: optimizer x replicator @ equal bandwidth (T5 stand-in) ---");
    println!("{}", exp.finish()?);
    if let Some((l, v)) = exp.best_val() {
        println!("winner: {l} (val {v:.4})  [paper: DeMo-SGD + Random]");
    }
    Ok(())
}

/// Fig 2a (+15): replicator × compression on translation.
fn fig2a(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig2a", &results_root());
    let cfg = base("seq2seq-tiny", 2, 4);
    let mut specs: Vec<(String, String)> = Vec::new();
    for c in [2u32, 4, 8, 16, 32] {
        specs.push((format!("random-1/{c}"), format!("random:1/{c}")));
        specs.push((format!("demo-1/{c}"), format!("demo:1/{c}:chunk=32")));
    }
    for c in [8u32, 32] {
        specs.push((format!("striding-1/{c}"), format!("striding:1/{c}")));
        specs.push((format!("diloco-1/{c}"), format!("diloco:{c}")));
    }
    for (label, repl) in &specs {
        let mut c = cfg.clone();
        c.repl = ReplSpec::parse(repl)?;
        exp.run(rt, &c, Some(label))?;
    }
    println!("\n--- Fig 2a/15: T5 stand-in, replicator x compression ---");
    println!("{}", exp.finish()?);
    if let Some((l, v)) = exp.best_val() {
        println!("winner: {l} (val {v:.4})  [paper: Random 1/2, 1/4 best]");
    }
    Ok(())
}

/// Fig 2b (+16): replicator × compression on ViT.
fn fig2b(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig2b", &results_root());
    let mut cfg = base("vit-tiny", 2, 4);
    cfg.lr = 5e-4;
    let mut specs: Vec<(String, String)> = Vec::new();
    for c in [2u32, 4, 16] {
        specs.push((format!("demo-1/{c}"), format!("demo:1/{c}:chunk=32")));
        specs.push((format!("random-1/{c}"), format!("random:1/{c}")));
    }
    specs.push(("striding-1/8".into(), "striding:1/8".into()));
    specs.push(("diloco-1/2".into(), "diloco:2".into()));
    specs.push(("diloco-1/16".into(), "diloco:16".into()));
    for (label, repl) in &specs {
        let mut c = cfg.clone();
        c.repl = ReplSpec::parse(repl)?;
        exp.run(rt, &c, Some(label))?;
    }
    println!("\n--- Fig 2b/16: ViT stand-in, replicator x compression ---");
    println!("{}", exp.finish()?);
    if let Some((l, v)) = exp.best_val() {
        println!("winner: {l} (val {v:.4})  [paper: DeMo 1/2, 1/4 best; Random struggles]");
    }
    Ok(())
}

/// Figs 3+4: causal LM, loss vs steps AND vs simulated wall-clock
/// (same runs, two x-axes — the CSVs carry both columns).
fn fig3(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig3", &results_root());
    let mut cfg = base("lm-tiny", 2, 4);
    cfg.warmup_steps = steps() / 25; // OLMo-style 4% warmup
    run_specs(
        rt,
        &mut exp,
        &cfg,
        &[
            ("demo-1/32", "demo-sgd", "demo:1/32:chunk=64"),
            ("demo-1/16", "demo-sgd", "demo:1/16:chunk=64"),
            ("demo-1/4", "demo-sgd", "demo:1/4:chunk=64"),
            ("random-1/16", "demo-sgd", "random:1/16"),
            ("random-1/4", "demo-sgd", "random:1/4"),
            ("striding-1/16", "demo-sgd", "striding:1/16"),
            ("diloco-1/16", "demo-sgd", "diloco:16"),
            ("adamw-full", "adamw", "full"),
        ],
    )?;
    println!("\n--- Fig 3/4: OLMo2 stand-in, train loss vs steps & sim wall-clock ---");
    println!("{}", exp.finish()?);
    let full_t = exp.runs.last().unwrap().mean_step_time();
    for r in &exp.runs[..exp.runs.len() - 1] {
        println!(
            "  {:<14} {:.2}x faster per step than full-sync AdamW  (exposed comm {}, {:.0}% hidden)",
            r.label,
            full_t / r.mean_step_time(),
            fmt_secs(r.total_exposed_comm()),
            r.overlap_efficiency() * 100.0,
        );
    }
    println!("  [paper: all replicators ~2.6x faster than Hybrid-FSDP AdamW; DeMo 1/32 best loss]");
    Ok(())
}

/// Figs 5+6: 64-node scaling (loss vs steps, loss vs sim time).
fn fig5(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig5", &results_root());
    let mut cfg = base("lm-tiny", 64, 4);
    cfg.compute_streams = 8;
    cfg.val_every = 0; // rank-0-only tracking, like the paper's scale runs
    run_specs(
        rt,
        &mut exp,
        &cfg,
        &[
            ("demo-1/32", "demo-sgd", "demo:1/32:chunk=64"),
            ("random-1/32", "demo-sgd", "random:1/32"),
            ("adamw-full", "adamw", "full"),
        ],
    )?;
    println!("\n--- Fig 5/6: 64-node scaling ---");
    println!("{}", exp.finish()?);
    let t = |i: usize| exp.runs[i].mean_step_time();
    println!(
        "step time demo {} vs random {} vs full {} -> random {:.0}% faster than full; demo {:.1}x slower than random",
        fmt_secs(t(0)),
        fmt_secs(t(1)),
        fmt_secs(t(2)),
        (1.0 - t(1) / t(2)) * 100.0,
        t(0) / t(1),
    );
    println!("  [paper: DeMo does not scale (all-gather); Random ~64% faster than conventional]");
    Ok(())
}

/// Fig 7 (Appendix A): the DeMo-vs-FlexDeMo communication pattern, as
/// per-node traffic matrices.
fn fig7(rt: &Runtime) -> Result<()> {
    let out = results_root().join("fig7");
    std::fs::create_dir_all(&out)?;
    let mut render_all = String::new();
    for (label, nodes, accels, repl) in [
        ("demo-ddp (|S|=1, 2 nodes x 4 accels as 8 nodes)", 8usize, 1usize, "demo:1/8"),
        ("flexdemo (2 nodes x 4 accels hybrid)", 2, 4, "demo:1/8"),
    ] {
        let mut cfg = base("lm-tiny", nodes, accels);
        cfg.steps = 3;
        cfg.val_every = 0;
        cfg.repl = ReplSpec::parse(repl)?;
        let mut tr = Trainer::new(rt, cfg)?;
        for _ in 0..3 {
            tr.step()?;
        }
        let rendered = tr.traffic.render();
        println!("\n--- Fig 7: {label} ---\n{rendered}");
        println!(
            "inter-node total {} / intra-node total {}",
            fmt_bytes(tr.traffic.inter_node_bytes()),
            fmt_bytes(tr.traffic.intra_node_bytes())
        );
        render_all.push_str(&format!("{label}\n{rendered}\n"));
    }
    detonation::util::atomic_write(&out.join("traffic.txt"), render_all.as_bytes())?;
    println!("  [paper App. A: FlexDeMo keeps expensive traffic intra-node, one gather per node]");
    Ok(())
}

/// Fig 8: TopK sweep for the DeMo replicator.
fn fig8(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig8", &results_root());
    let cfg = base("seq2seq-tiny", 2, 2);
    for k in [1u32, 2, 4, 8, 16] {
        let mut c = cfg.clone();
        // chunk=64 fixed; rate = k/64.
        c.repl = ReplSpec::parse(&format!("demo:1/{}:chunk=64", 64 / k))?;
        exp.run(rt, &c, Some(&format!("top{k}")))?;
    }
    println!("\n--- Fig 8: TopK sweep (chunk 64) ---");
    println!("{}", exp.finish()?);
    if let Some((l, v)) = exp.best_val() {
        println!("winner: {l} (val {v:.4})  [paper: Top4 best, Top16 degrades]");
    }
    Ok(())
}

/// Fig 9: sign vs no-sign across replicators.
fn fig9(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig9", &results_root());
    let cfg = base("seq2seq-tiny", 2, 2);
    for (scheme, rate) in [("demo", 8), ("random", 8), ("striding", 8)] {
        for sign in ["sign", "nosign"] {
            let mut c = cfg.clone();
            c.repl = ReplSpec::parse(&format!("{scheme}:1/{rate}:{sign}"))?;
            exp.run(rt, &c, Some(&format!("{scheme}-{sign}")))?;
        }
    }
    for sign in ["sign", "nosign"] {
        let mut c = cfg.clone();
        c.repl = ReplSpec::parse(&format!("diloco:8:{sign}"))?;
        exp.run(rt, &c, Some(&format!("diloco-{sign}")))?;
    }
    println!("\n--- Fig 9: sign vs no-sign ---");
    println!("{}", exp.finish()?);
    // aggregate: mean val loss signed vs unsigned
    let mean = |suffix: &str| {
        let v: Vec<f64> = exp
            .runs
            .iter()
            .filter(|r| r.label.ends_with(suffix))
            .filter_map(|r| r.final_val_loss())
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    println!(
        "mean val loss: sign {:.4} vs nosign {:.4}  [paper: sign clearly positive]",
        mean("-sign"),
        mean("-nosign")
    );
    Ok(())
}

/// Fig 10: average time per step vs inter-node bandwidth (a+b panels).
fn fig10(rt: &Runtime) -> Result<()> {
    let bandwidths = [10.0, 100.0, 1000.0, 10000.0];
    for (panel, model) in [("a-t5", "seq2seq-tiny"), ("b-vit", "vit-tiny")] {
        let mut exp = Experiment::new(&format!("fig10{panel}"), &results_root());
        let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
        let mut exposed_at_10 = Vec::new();
        for (opt, repl) in [
            ("demo-sgd", "demo:1/16"),
            ("demo-sgd", "demo:1/32"),
            ("demo-sgd", "random:1/16"),
            ("demo-sgd", "random:1/32"),
            ("decoupled-adamw", "full:sign"),
        ] {
            let mut times = Vec::new();
            for mbps in bandwidths {
                let mut cfg = base(model, 2, 2);
                cfg.steps = 16;
                cfg.val_every = 0;
                cfg.net = NetModel::paper_scaled(our_params(model), paper_params(model))
                    .with_inter_mbps(mbps);
                cfg.apply_arg("opt", opt)?;
                cfg.apply_arg("repl", repl)?;
                let run = exp.run(rt, &cfg, Some(&format!("{}-{}mbps", cfg.repl.label(), mbps)))?;
                times.push(run.mean_step_time());
                if mbps == bandwidths[0] {
                    // overlap breakdown at the most throttled point: the
                    // exposed_comm/hidden_comm CSV columns, aggregated
                    exposed_at_10.push((
                        format!("{opt}+{repl}"),
                        run.total_exposed_comm(),
                        run.overlap_efficiency(),
                    ));
                }
            }
            rows.push((format!("{opt}+{repl}"), times));
        }
        println!("\n--- Fig 10{panel}: time/step vs bandwidth ---");
        print!("{:<36}", "scheme");
        for b in bandwidths {
            print!("{:>12}", format!("{b} Mbps"));
        }
        println!();
        for (label, times) in &rows {
            print!("{label:<36}");
            for t in times {
                print!("{:>12}", fmt_secs(*t));
            }
            println!();
        }
        let at10 = |i: usize| rows[i].1[0];
        println!(
            "at 10 Mbps: random-1/32 {:.2}x faster than demo-1/32; {:.1}x faster than full-repl \
             [paper: 3.33x and ~18x]",
            at10(1) / at10(3),
            at10(4) / at10(3)
        );
        println!("overlap breakdown at 10 Mbps (exposed comm | hidden fraction):");
        for (label, exposed, eff) in &exposed_at_10 {
            println!("  {label:<36} {:>12} | {:.0}% hidden", fmt_secs(*exposed), eff * 100.0);
        }
        exp.finish()?;
    }
    Ok(())
}

/// Fig 11+12: DeMo chunk-size sweep — validation loss and bandwidth usage.
fn fig11(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig11", &results_root());
    let cfg = base("seq2seq-tiny", 2, 2);
    let mut bw_rows: Vec<(String, u64)> = Vec::new();
    for rate in [8u32, 16] {
        for chunk in [16u32, 32, 64, 96, 128, 192, 256] {
            if chunk / rate == 0 {
                continue; // k would clamp to 1 anyway; paper skips these too
            }
            let mut c = cfg.clone();
            c.repl = ReplSpec::parse(&format!("demo:1/{rate}:chunk={chunk}"))?;
            let label = format!("c{chunk}-1/{rate}");
            let run = exp.run(rt, &c, Some(&label))?;
            let per_step = run.total_inter_bytes() / run.steps.len().max(1) as u64;
            bw_rows.push((label, per_step));
        }
    }
    println!("\n--- Fig 11: chunk-size sweep (val loss) ---");
    println!("{}", exp.finish()?);
    println!("--- Fig 12: bandwidth usage per chunk size ---");
    for (label, bytes) in &bw_rows {
        println!("  {label:<14} {:>12}/step", fmt_bytes(*bytes));
    }
    println!("  [paper: 1/8 small chunks slightly better; usage flat across chunk sizes]");
    Ok(())
}

/// Fig 13+14: transfer dtype — bandwidth usage and validation loss.
fn fig13(rt: &Runtime) -> Result<()> {
    let mut exp = Experiment::new("fig13", &results_root());
    let cfg = base("seq2seq-tiny", 2, 2);
    let mut bw_rows: Vec<(String, u64)> = Vec::new();
    for dt in ["f32", "bf16", "f16"] {
        for (scheme, spec) in [
            ("demo", format!("demo:1/8:nosign:{dt}")),
            ("random", format!("random:1/8:nosign:{dt}")),
            ("full-sync", format!("diloco:8:nosign:{dt}")),
        ] {
            let mut c = cfg.clone();
            c.repl = ReplSpec::parse(&spec)?;
            let label = format!("{scheme}-{dt}");
            let run = exp.run(rt, &c, Some(&label))?;
            let per_step = run.total_inter_bytes() / run.steps.len().max(1) as u64;
            bw_rows.push((label, per_step));
        }
    }
    println!("\n--- Fig 13: bandwidth per transfer dtype ---");
    for (label, bytes) in &bw_rows {
        println!("  {label:<16} {:>12}/step", fmt_bytes(*bytes));
    }
    println!("--- Fig 14: val loss per transfer dtype ---");
    println!("{}", exp.finish()?);
    println!("  [paper: full precision best for DeMo/Random; full-sync dtype-insensitive]");
    Ok(())
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    if std::env::var("DETONATION_FIG_SKIP").is_ok() {
        // The figure suite takes ~20 CPU-minutes; `make bench` honours
        // this escape hatch so the micro-benches can be re-captured
        // without re-running every training sweep.
        eprintln!("figures: skipped (DETONATION_FIG_SKIP set; series already in results/)");
        return Ok(());
    }
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--")) // cargo bench passes --bench
        .collect();
    let all = [
        "fig1", "fig2a", "fig2b", "fig3", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11",
        "fig13",
    ];
    let selected: Vec<&str> = if args.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|f| args.iter().any(|a| a == f)).collect()
    };
    anyhow::ensure!(
        !selected.is_empty(),
        "no figure matched {args:?}; available: {all:?}"
    );
    let rt = runtime()?;
    let t0 = std::time::Instant::now();
    for fig in &selected {
        let t = std::time::Instant::now();
        match *fig {
            "fig1" => fig1(&rt)?,
            "fig2a" => fig2a(&rt)?,
            "fig2b" => fig2b(&rt)?,
            "fig3" => fig3(&rt)?,
            "fig5" => fig5(&rt)?,
            "fig7" => fig7(&rt)?,
            "fig8" => fig8(&rt)?,
            "fig9" => fig9(&rt)?,
            "fig10" => fig10(&rt)?,
            "fig11" => fig11(&rt)?,
            "fig13" => fig13(&rt)?,
            _ => unreachable!(),
        }
        eprintln!("[{fig} done in {:.1}s]", t.elapsed().as_secs_f64());
    }
    eprintln!(
        "all figures regenerated in {:.1}s -> {}",
        t0.elapsed().as_secs_f64(),
        results_root().display()
    );
    Ok(())
}
