//! Fault-injection bench: flaky links and self-healing transfers.
//!
//!     cargo bench --bench faults [-- --quick]
//!
//! On the chaos bench's 4×1 DiLoCo mesh (`diloco:4`, 200 Mbps), sweeps
//! the `--link-fault` timeline across six arms:
//!
//! * `baseline` — perfect network, default retry knobs;
//! * `faultfree` — an *empty* fault timeline but non-default retry
//!   knobs: the self-healing machinery must be pure control flow when
//!   unused (bit-identical losses and per-step sim times to baseline);
//! * `drop5` — every link drops each attempt with p = 0.05 (the paper
//!   regime of occasional loss absorbed by retries);
//! * `retry` — heavy loss *and* corruption (p = 0.3 each) healed by the
//!   default timeout/backoff retry lane;
//! * `resend` — the same fault spec, but the retry timeout is one full
//!   DiLoCo window: the naive "re-send with the next window" strawman.
//!   Self-healing retries must finish strictly sooner in sim time;
//! * `partition` — node 1's outbound links are down for the whole run
//!   (`flap:1-*`) under `--quorum 3`: the run must complete with finite
//!   losses via the quorum fallback, never deadlock.
//!
//! Asserted here (deterministic, seeded): the fault-free arm is
//! bit-identical to baseline, faulted arms actually retry and detect
//! corruption, and the partition arm finishes finite. The *bands* —
//! drop5's tail loss within 1.5× of baseline and retry strictly beating
//! resend per sim step — are written into `BENCH_faults.json` (schema:
//! docs/BENCHMARKS.md) and enforced by `scripts/bench_gate.py`.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const PERIOD: u64 = 4;
/// Tail window for the loss comparisons (steps).
const TAIL: usize = 8;

fn base_cfg(steps: u64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: 4,
        accels_per_node: 1,
        steps,
        lr: 0.02,
        seed: 17,
        val_every: steps, // validate once, at the end
        val_batches: 8,
        ..Default::default()
    };
    // A visibly throttled link so retries and degradation move the
    // clock, not just the numerics.
    c.apply_arg("inter-mbps", "200")?;
    c.apply_arg("repl", &format!("diloco:{PERIOD}"))?;
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = detonation::train::Trainer::new(&rt, c)?;
    let m = t.run()?;
    anyhow::ensure!(
        m.steps.iter().all(|r| r.loss.is_finite()),
        "non-finite loss"
    );
    Ok(m)
}

fn row(label: &str, m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("inter_bytes", Json::Num(m.total_inter_bytes() as f64)),
        (
            "tail_loss",
            m.tail_loss(TAIL).map(Json::Num).unwrap_or(Json::Null),
        ),
        ("retries", Json::Num(m.total_retries() as f64)),
        (
            "corrupt_detected",
            Json::Num(m.total_corrupt_detected() as f64),
        ),
    ])
}

/// Bit-level fingerprint of a run: per-step losses and sim times.
fn bits(m: &RunMetrics) -> (Vec<u64>, Vec<u64>) {
    (
        m.steps.iter().map(|r| r.loss.to_bits()).collect(),
        m.steps.iter().map(|r| r.sim_time.to_bits()).collect(),
    )
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps: u64 = if quick { 16 } else { 40 };

    println!(
        "{:<12} {:>12} {:>12} {:>10} {:>8} {:>8}",
        "arm", "t/step", "total", "tail", "retries", "corrupt"
    );
    let print_row = |label: &str, m: &RunMetrics| {
        println!(
            "{:<12} {:>12} {:>12} {:>10.4} {:>8} {:>8}",
            label,
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            m.tail_loss(TAIL).unwrap_or(f64::NAN),
            m.total_retries(),
            m.total_corrupt_detected(),
        );
    };

    // baseline: perfect network
    let base = run(base_cfg(steps)?)?;
    print_row("baseline", &base);
    assert_eq!(base.total_retries(), 0, "retries on a perfect network");
    assert_eq!(base.total_corrupt_detected(), 0);
    assert!(base.steps.iter().all(|r| r.faulted_links == 0));

    // faultfree: empty timeline + non-default retry knobs must be inert
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("max-retries", "5")?;
    cfg.apply_arg("retry-timeout", "0.5")?;
    cfg.apply_arg("retry-backoff", "0.2")?;
    let faultfree = run(cfg)?;
    print_row("faultfree", &faultfree);
    let faultfree_identical = bits(&base) == bits(&faultfree);
    assert!(
        faultfree_identical,
        "an empty --link-fault changed the schedule or the numerics"
    );

    // drop5: 5% per-attempt loss on every link, healed by retries
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("link-fault", "drop:*-*@p0.05")?;
    let drop5 = run(cfg)?;
    print_row("drop5", &drop5);
    assert!(drop5.total_retries() > 0, "5% loss never retried");
    assert!(drop5.steps.iter().all(|r| r.faulted_links == 12));

    // retry vs resend: identical heavy loss + corruption, default
    // timeout/backoff vs a timeout of one full DiLoCo window (the naive
    // "re-send it with the next window" strawman).
    const FLAKY: &str = "drop:*-*@p0.3,corrupt:*-*@p0.3";
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("link-fault", FLAKY)?;
    let retry = run(cfg)?;
    print_row("retry", &retry);
    assert!(retry.total_retries() > 0);
    assert!(
        retry.total_corrupt_detected() > 0,
        "corruption never detected at decode"
    );

    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("link-fault", FLAKY)?;
    cfg.retry_timeout = PERIOD as f64 * base.mean_step_time();
    let resend = run(cfg)?;
    print_row("resend", &resend);
    let retry_beats_resend = retry.total_sim_time() < resend.total_sim_time()
        && retry.mean_step_time() < resend.mean_step_time();
    assert!(
        retry_beats_resend,
        "timeout/backoff retries did not beat window-scale re-sends: {} vs {}",
        retry.total_sim_time(),
        resend.total_sim_time()
    );

    // partition: node 1 unreachable all run; quorum finalizes without it
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("link-fault", &format!("flap:1-*@0..{steps}"))?;
    cfg.quorum = 3;
    let partition = run(cfg)?;
    print_row("partition", &partition);
    let partition_completed = partition.steps.len() == steps as usize
        && partition.total_sim_time().is_finite();
    assert!(partition_completed, "partitioned run did not complete");
    assert!(partition.steps.iter().all(|r| r.faulted_links == 3));

    let out = Json::obj(vec![
        ("bench", Json::Str("faults".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("mesh", Json::Str("4x1".into())),
        ("period", Json::Num(PERIOD as f64)),
        ("steps", Json::Num(steps as f64)),
        ("tail_window", Json::Num(TAIL as f64)),
        ("quick", Json::Bool(quick)),
        ("faultfree_identical", Json::Bool(faultfree_identical)),
        ("retry_beats_resend", Json::Bool(retry_beats_resend)),
        ("partition_completed", Json::Bool(partition_completed)),
        (
            "arms",
            Json::Arr(vec![
                row("baseline", &base),
                row("faultfree", &faultfree),
                row("drop5", &drop5),
                row("retry", &retry),
                row("resend", &resend),
                row("partition", &partition),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_faults.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
