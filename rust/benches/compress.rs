//! Compression micro-bench: sign/ternary packing, dtype casts, top-k
//! selection, and the full extract pipeline old-vs-new (perf
//! deliverable; acceptance: ≥2× extract throughput at paper settings
//! chunk=64, k=8, sign, and **zero steady-state heap allocations**,
//! asserted here with a counting global allocator).
//!
//!     cargo bench --bench compress
//!
//! Results (elements/sec + allocation counts) land in
//! `BENCH_compress.json` at the repo root — the perf-trajectory
//! artifact.

use std::time::Instant;

use detonation::compress::{pack_ternary, unpack_ternary, Payload, Scratch};
use detonation::dct::Dct;
use detonation::replicate::{DemoReplicator, ReplCtx, Replicator};
use detonation::tensor::{f32_to_bf16, f32_to_f16, Dtype};
use detonation::topk::topk_per_chunk;
use detonation::util::json::Json;
use detonation::util::rng::Rng;

#[path = "util/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_count, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Time `f` and return (micros/iter, iters, allocs/iter).
fn bench<F: FnMut()>(mut f: F) -> (f64, u64, f64) {
    for _ in 0..3 {
        f();
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < 0.4 {
        f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (alloc_count() - a0) as f64 / iters as f64;
    (dt / iters as f64 * 1e6, iters, allocs)
}

fn report(name: &str, elems_per_iter: u64, bytes_per_iter: u64, res: (f64, u64, f64)) -> Json {
    let (us, _iters, allocs) = res;
    let eps = elems_per_iter as f64 / (us / 1e6);
    println!(
        "{name:<34} {us:>10.1} µs/iter {:>9.1} Melem/s {:>8.2} GB/s {allocs:>8.1} allocs",
        eps / 1e6,
        bytes_per_iter as f64 / (us / 1e6) / 1e9,
    );
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("micros_per_iter", Json::Num(us)),
        ("elements_per_sec", Json::Num(eps)),
        ("allocs_per_iter", Json::Num(allocs)),
    ])
}

/// The pre-PR extract pipeline, spelled out: dense scratch buffers
/// allocated per call, dense kept-mass materialization, recursive
/// per-chunk transforms. This is the baseline the tentpole replaces;
/// numerics match the new path bit-for-bit (tested in `replicate::demo`).
fn baseline_extract(
    chunk: usize,
    k: usize,
    sign: bool,
    buf: &mut [f32],
) -> (Vec<f32>, Payload) {
    let d = Dct::plan(chunk);
    let mut coeffs = vec![0.0f32; buf.len()];
    d.forward_chunked_recursive(buf, &mut coeffs);
    let indices = topk_per_chunk(&coeffs, chunk, k);
    let values: Vec<f32> = indices.iter().map(|&i| coeffs[i as usize]).collect();
    let mut kept = vec![0.0f32; buf.len()];
    for (&i, &v) in indices.iter().zip(&values) {
        kept[i as usize] = v;
    }
    let mut removed = vec![0.0f32; buf.len()];
    d.inverse_chunked_recursive(&kept, &mut removed);
    for (b, r) in buf.iter_mut().zip(&removed) {
        *b -= r;
    }
    let payload = Payload::new(Some(indices), values, Dtype::F32, sign);
    // decode q_local from the payload via a dense coefficient buffer
    let mut dense = vec![0.0f32; buf.len()];
    for (&i, &v) in payload
        .indices
        .as_ref()
        .unwrap()
        .iter()
        .zip(&payload.values)
    {
        dense[i as usize] = v;
    }
    let mut q = vec![0.0f32; buf.len()];
    d.inverse_chunked_recursive(&dense, &mut q);
    (q, payload)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2);
    let n = 1 << 20;
    let vals: Vec<f32> = (0..n)
        .map(|_| *[-1.0f32, 0.0, 1.0].get(rng.range(0, 3)).unwrap())
        .collect();
    let dense: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let bytes = (n * 4) as u64;
    let mut rows = Vec::new();

    let packed = pack_ternary(&vals);
    let r = bench(|| {
        std::hint::black_box(pack_ternary(&vals));
    });
    rows.push(report("pack_ternary", n as u64, bytes, r));
    let r = bench(|| {
        std::hint::black_box(unpack_ternary(&packed, n));
    });
    rows.push(report("unpack_ternary", n as u64, bytes, r));
    let r = bench(|| {
        let v: Vec<u16> = dense.iter().map(|&x| f32_to_bf16(x)).collect();
        std::hint::black_box(v);
    });
    rows.push(report("f32->bf16 cast", n as u64, bytes, r));
    let r = bench(|| {
        let v: Vec<u16> = dense.iter().map(|&x| f32_to_f16(x)).collect();
        std::hint::black_box(v);
    });
    rows.push(report("f32->f16 cast", n as u64, bytes, r));
    for (chunk, k) in [(64usize, 8usize), (256, 8), (64, 32)] {
        let r = bench(|| {
            std::hint::black_box(topk_per_chunk(&dense, chunk, k));
        });
        rows.push(report(&format!("topk_per_chunk c{chunk} k{k}"), n as u64, bytes, r));
    }
    // partial selection into reused buffers — the hot-path variant
    {
        let mut perm = Vec::new();
        let mut out = Vec::new();
        let r = bench(|| {
            detonation::topk::topk_per_chunk_into(&dense, 64, 8, &mut perm, &mut out);
            std::hint::black_box(out.len());
        });
        rows.push(report("topk_per_chunk_into c64 k8", n as u64, bytes, r));
    }

    // -- extract pipeline, paper settings (chunk=64, k=8, sign) ----------
    let shard = 1usize << 18; // 256k elements ≈ 1 MiB shard
    let momentum: Vec<f32> = {
        let mut r = Rng::new(7);
        (0..shard).map(|_| r.normal_f32(1.0)).collect()
    };
    let ctx = ReplCtx {
        step: 0,
        shard: 0,
        seed: 1,
    };

    let mut buf = momentum.clone();
    let old = bench(|| {
        buf.copy_from_slice(&momentum);
        std::hint::black_box(baseline_extract(64, 8, true, &mut buf));
    });
    let old_row = report("extract OLD c64 k8 sign", shard as u64, (shard * 4) as u64, old);

    let mut repl = DemoReplicator::new(64, 8, true, Dtype::F32);
    let mut scratch = Scratch::new();
    let new = bench(|| {
        buf.copy_from_slice(&momentum);
        let (q, p) = repl.extract(&ctx, &mut buf, &mut scratch);
        if let Some(p) = p {
            scratch.recycle_payload(p);
        }
        scratch.put_f32(q);
    });
    let new_row = report("extract NEW c64 k8 sign", shard as u64, (shard * 4) as u64, new);

    let speedup = old.0 / new.0;
    println!("extract speedup: {speedup:.2}x (target >= 2x)");

    // -- zero-alloc assertion (steady state, counting allocator) ---------
    buf.copy_from_slice(&momentum);
    let a0 = alloc_count();
    let (q, p) = repl.extract(&ctx, &mut buf, &mut scratch);
    let steady_allocs = alloc_count() - a0;
    if let Some(p) = p {
        scratch.recycle_payload(p);
    }
    scratch.put_f32(q);
    assert_eq!(
        steady_allocs, 0,
        "steady-state extract allocated {steady_allocs} times"
    );
    println!("steady-state extract allocations: {steady_allocs} (asserted 0)");

    let out = Json::obj(vec![
        ("bench", Json::Str("compress".into())),
        ("elements", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
        (
            "extract",
            Json::obj(vec![
                ("chunk", Json::Num(64.0)),
                ("k", Json::Num(8.0)),
                ("sign", Json::Bool(true)),
                ("shard_elements", Json::Num(shard as f64)),
                ("old", old_row),
                ("new", new_row),
                ("speedup", Json::Num(speedup)),
                ("steady_state_allocs", Json::Num(steady_allocs as f64)),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_compress.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
