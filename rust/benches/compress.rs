//! Compression micro-bench: sign/ternary packing, dtype casts, top-k
//! selection (perf deliverable; target ≥ 4 GB/s sign-pack).
//!
//!     cargo bench --bench compress

use detonation::compress::{pack_ternary, unpack_ternary};
use detonation::tensor::{f32_to_bf16, f32_to_f16};
use detonation::topk::topk_per_chunk;
use detonation::util::rng::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, bytes_per_iter: u64, mut f: F) {
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < 0.5 {
        f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name:<32} {:>10.1} µs/iter {:>8.2} GB/s",
        dt / iters as f64 * 1e6,
        (bytes_per_iter * iters) as f64 / dt / 1e9
    );
}

fn main() {
    let mut rng = Rng::new(2);
    let n = 1 << 20;
    let vals: Vec<f32> = (0..n)
        .map(|_| *[-1.0f32, 0.0, 1.0].get(rng.range(0, 3)).unwrap())
        .collect();
    let dense: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let bytes = (n * 4) as u64;

    let packed = pack_ternary(&vals);
    bench("pack_ternary", bytes, || {
        std::hint::black_box(pack_ternary(&vals));
    });
    bench("unpack_ternary", bytes, || {
        std::hint::black_box(unpack_ternary(&packed, n));
    });
    bench("f32->bf16 cast", bytes, || {
        let v: Vec<u16> = dense.iter().map(|&x| f32_to_bf16(x)).collect();
        std::hint::black_box(v);
    });
    bench("f32->f16 cast", bytes, || {
        let v: Vec<u16> = dense.iter().map(|&x| f32_to_f16(x)).collect();
        std::hint::black_box(v);
    });
    for (chunk, k) in [(64usize, 8usize), (256, 8), (64, 32)] {
        bench(&format!("topk_per_chunk c{chunk} k{k}"), bytes, || {
            std::hint::black_box(topk_per_chunk(&dense, chunk, k));
        });
    }
}
