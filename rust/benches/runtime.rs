//! Runtime micro-bench: artifact execution latency (fwd+bwd) and the cost
//! of literal marshalling — the L3-vs-L2 boundary. Target: marshalling
//! ≤ 30% of exec time for tiny models, ≤ 5% for small+.
//!
//!     cargo bench --bench runtime

use detonation::data::task_for;
use detonation::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    detonation::util::logging::init();
    let rt = Runtime::cpu()?;
    let dir = std::path::PathBuf::from("artifacts");
    for name in ["lm-tiny", "lm-small", "seq2seq-tiny", "vit-tiny"] {
        if !dir.join(format!("{name}.meta.json")).exists() {
            println!("{name:<16} skipped (artifact missing — run `make artifacts`)");
            continue;
        }
        let model = rt.load_model(&dir, name)?;
        let params = model.manifest.init_flat(1);
        let task = task_for(&model.manifest, 1);
        let batch = task.train_batch(0, 0);

        // warmup
        model.train_step(&params, &batch)?;
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed().as_secs_f64() < 2.0 {
            std::hint::black_box(model.train_step(&params, &batch)?);
            iters += 1;
        }
        let step_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;

        let t0 = Instant::now();
        let mut eiters = 0u64;
        while t0.elapsed().as_secs_f64() < 1.0 {
            std::hint::black_box(model.eval_step(&params, &batch)?);
            eiters += 1;
        }
        let eval_ms = t0.elapsed().as_secs_f64() / eiters as f64 * 1e3;

        let flops = model.manifest.step_flops();
        println!(
            "{name:<16} train {step_ms:>8.2} ms/step  eval {eval_ms:>7.2} ms  ~{:.1} GFLOP/s",
            flops / (step_ms / 1e3) / 1e9
        );
    }
    Ok(())
}
