//! Runtime micro-bench: model execution latency (fwd+bwd / eval) — the
//! L3-vs-L2 boundary. Artifact-backed models bench the PJRT path when
//! artifacts exist; `synthetic-lm` always runs (surrogate backend), so
//! the JSON artifact is populated on every checkout.
//!
//!     cargo bench --bench runtime [-- --quick]
//!
//! Results land in `BENCH_runtime.json` at the repo root.

use detonation::data::task_for;
use detonation::runtime::Runtime;
use detonation::util::json::Json;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let (train_budget, eval_budget) = if quick { (0.2, 0.1) } else { (2.0, 1.0) };
    let rt = Runtime::cpu()?;
    let dir = std::path::PathBuf::from("artifacts");
    let mut rows = Vec::new();
    for name in ["synthetic-lm", "lm-tiny", "lm-small", "seq2seq-tiny", "vit-tiny"] {
        let is_synthetic = name.starts_with("synthetic");
        if !is_synthetic && !dir.join(format!("{name}.meta.json")).exists() {
            println!("{name:<16} skipped (artifact missing — run `make artifacts`)");
            continue;
        }
        let model = rt.load_model(&dir, name)?;
        let params = model.manifest.init_flat(1);
        let task = task_for(&model.manifest, 1);
        let batch = task.train_batch(0, 0);

        // warmup
        model.train_step(&params, &batch)?;
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed().as_secs_f64() < train_budget {
            std::hint::black_box(model.train_step(&params, &batch)?);
            iters += 1;
        }
        let step_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;

        let t0 = Instant::now();
        let mut eiters = 0u64;
        while t0.elapsed().as_secs_f64() < eval_budget {
            std::hint::black_box(model.eval_step(&params, &batch)?);
            eiters += 1;
        }
        let eval_ms = t0.elapsed().as_secs_f64() / eiters as f64 * 1e3;

        let flops = model.manifest.step_flops();
        let gflops = flops / (step_ms / 1e3) / 1e9;
        println!(
            "{name:<16} train {step_ms:>8.2} ms/step  eval {eval_ms:>7.2} ms  ~{gflops:.1} GFLOP/s"
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("train_ms_per_step", Json::Num(step_ms)),
            ("eval_ms", Json::Num(eval_ms)),
            ("gflops_per_sec", Json::Num(gflops)),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::Str("runtime".into())),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_runtime.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
