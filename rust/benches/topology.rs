//! Sync-topology bench: gossip and partial connectivity at scale.
//!
//!     cargo bench --bench topology [-- --quick]
//!
//! Sweeps `--topology` across group sizes g ∈ {4, 16, 64} (one rank per
//! node, `diloco:4` windows on a 200 Mbps link) with four arms per g:
//!
//! * `full` — the whole-group exchange, explicitly requested: must be
//!   bit-identical to a default-config run (the pre-topology path is
//!   frozen);
//! * `ring` — each member exchanges with its ±1 neighbors only;
//! * `random-pair` — a seeded perfect matching re-drawn every window;
//! * `hier2` — the rotating two-wide circulant fanout (`hier:2`).
//!
//! The claim under test is the gossip scaling law: a member's exposed
//! per-window communication is O(degree), not O(g), so the per-step
//! simulated time of the sparse arms stays roughly flat from g = 4 to
//! g = 64 while the full-group arm grows with the group. Asserted here
//! (deterministic, seeded): the explicit-full arm is bit-identical to
//! the default config at every g, every sparse arm at g = 64 stays
//! within `FLAT_BAND`× its own g = 4 per-step time, and full at g = 64
//! is strictly slower than full at g = 4. The same invariants — plus
//! the sparse arms' tail loss staying within a band of full — are
//! written into `BENCH_topology.json` (schema: docs/BENCHMARKS.md) and
//! enforced by `scripts/bench_gate.py`.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const PERIOD: u64 = 4;
/// Tail window for the loss comparisons (steps).
const TAIL: usize = 4;
/// Sparse arms at g = 64 may cost at most this multiple of their own
/// g = 4 per-step time (O(1) gossip, with slack for arrival jitter).
const FLAT_BAND: f64 = 1.5;

const GROUPS: [usize; 3] = [4, 16, 64];
const SPARSE: [&str; 3] = ["ring", "random-pair", "hier:2"];

fn base_cfg(nodes: usize, steps: u64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes,
        accels_per_node: 1,
        steps,
        lr: 0.02,
        seed: 31,
        val_every: steps, // validate once, at the end
        val_batches: 4,
        // a handful of distinct data streams so the 64-node arm dedupes
        // compute instead of running 64 unique models
        compute_streams: 4,
        ..Default::default()
    };
    // A visibly throttled link so the exchange degree moves the clock.
    c.apply_arg("inter-mbps", "200")?;
    c.apply_arg("repl", &format!("diloco:{PERIOD}"))?;
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = detonation::train::Trainer::new(&rt, c)?;
    let m = t.run()?;
    anyhow::ensure!(
        m.steps.iter().all(|r| r.loss.is_finite()),
        "non-finite loss"
    );
    Ok(m)
}

fn row(label: &str, m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("inter_bytes", Json::Num(m.total_inter_bytes() as f64)),
        (
            "tail_loss",
            m.tail_loss(TAIL).map(Json::Num).unwrap_or(Json::Null),
        ),
    ])
}

/// Bit-level fingerprint of a run: per-step losses and sim times.
fn bits(m: &RunMetrics) -> (Vec<u64>, Vec<u64>) {
    (
        m.steps.iter().map(|r| r.loss.to_bits()).collect(),
        m.steps.iter().map(|r| r.sim_time.to_bits()).collect(),
    )
}

/// `hier:2` → `hier2`: colon-free arm labels for the JSON rows.
fn arm_label(g: usize, topo: &str) -> String {
    format!("g{g}-{}", topo.replace(':', ""))
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    // every g arm survives --quick: the scaling claim *is* the bench
    let steps: u64 = if quick { 2 * PERIOD } else { 4 * PERIOD };

    println!(
        "{:<18} {:>12} {:>12} {:>14} {:>10}",
        "arm", "t/step", "total", "inter", "tail"
    );
    let print_row = |label: &str, m: &RunMetrics| {
        println!(
            "{:<18} {:>12} {:>12} {:>14} {:>10.4}",
            label,
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            m.total_inter_bytes(),
            m.tail_loss(TAIL).unwrap_or(f64::NAN),
        );
    };

    let mut arms: Vec<Json> = Vec::new();
    // per g: (full, [sparse…]) for the invariant checks below
    let mut full_by_g: Vec<RunMetrics> = Vec::new();
    let mut sparse_by_g: Vec<Vec<(String, RunMetrics)>> = Vec::new();
    let mut full_bit_identical = true;

    for &g in &GROUPS {
        // the regression anchor: explicit `--topology full` against the
        // untouched default config, bit for bit
        let default_run = run(base_cfg(g, steps)?)?;
        let mut cfg = base_cfg(g, steps)?;
        cfg.apply_arg("topology", "full")?;
        let full = run(cfg)?;
        if bits(&default_run) != bits(&full) {
            full_bit_identical = false;
        }
        let label = arm_label(g, "full");
        print_row(&label, &full);
        arms.push(row(&label, &full));

        let mut sparse_runs = Vec::new();
        for topo in SPARSE {
            let mut cfg = base_cfg(g, steps)?;
            cfg.apply_arg("topology", topo)?;
            let m = run(cfg)?;
            let label = arm_label(g, topo);
            print_row(&label, &m);
            // a sparse window must never ship more than the full group
            assert!(
                m.total_inter_bytes() < full.total_inter_bytes(),
                "{label}: sparse exchange moved {} bytes vs full {}",
                m.total_inter_bytes(),
                full.total_inter_bytes()
            );
            arms.push(row(&label, &m));
            sparse_runs.push((topo.to_string(), m));
        }
        full_by_g.push(full);
        sparse_by_g.push(sparse_runs);
    }
    assert!(
        full_bit_identical,
        "--topology full diverged from the pre-topology path"
    );

    // gossip scaling: every sparse arm stays roughly flat in g…
    let mut gossip_flat = true;
    for (topo, m64) in &sparse_by_g[GROUPS.len() - 1] {
        let m4 = &sparse_by_g[0]
            .iter()
            .find(|(t, _)| t == topo)
            .expect("same sparse sweep per g")
            .1;
        let growth = m64.mean_step_time() / m4.mean_step_time();
        println!("{topo}: g64/g4 per-step growth {growth:.3}");
        if growth > FLAT_BAND {
            gossip_flat = false;
        }
    }
    assert!(
        gossip_flat,
        "a gossip arm's per-step sim time grew past {FLAT_BAND}x from g=4 to g=64"
    );
    // …while the full-group exchange grows with the group.
    let full_grows = full_by_g[GROUPS.len() - 1].mean_step_time() > full_by_g[0].mean_step_time();
    assert!(
        full_grows,
        "full-group exchange did not grow with g: {} vs {}",
        full_by_g[GROUPS.len() - 1].mean_step_time(),
        full_by_g[0].mean_step_time()
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("topology".into())),
        ("model", Json::Str("synthetic-lm".into())),
        (
            "groups",
            Json::Arr(GROUPS.iter().map(|&g| Json::Num(g as f64)).collect()),
        ),
        ("period", Json::Num(PERIOD as f64)),
        ("steps", Json::Num(steps as f64)),
        ("tail_window", Json::Num(TAIL as f64)),
        ("flat_band", Json::Num(FLAT_BAND)),
        ("quick", Json::Bool(quick)),
        ("full_bit_identical", Json::Bool(full_bit_identical)),
        ("gossip_flat", Json::Bool(gossip_flat)),
        ("full_grows", Json::Bool(full_grows)),
        ("arms", Json::Arr(arms)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_topology.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
