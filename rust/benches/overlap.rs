//! Overlap-engine bench: what the event-driven scheduler buys.
//!
//!     cargo bench --bench overlap
//!
//! Two comparisons, across replication schemes on a throttled (100 Mbps)
//! two-node link with the synthetic surrogate model:
//!
//! * **serialized vs overlapped sim-time** — the simulated speedup from
//!   hiding phase 0/2 intra-node traffic behind backward compute and the
//!   replication gather behind the next forward;
//! * **threaded vs single-thread wall-clock** — the real speedup from
//!   fanning the deduplicated per-stream fwd/bwd calls out to
//!   `std::thread::scope` workers;
//! * **whole-phase vs bucketed (`--bucket-mb`) exposure** — on a
//!   compute-rich arm, how much exposed communication the per-bucket
//!   pipeline shaves by starting the first gather bucket inside the
//!   backward window.
//!
//! Results land in `BENCH_overlap.json` at the repo root (the perf
//! trajectory artifact) and are printed as a table.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::net::NetModel;
use detonation::train::Trainer;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

fn cfg(repl: &str, overlap: bool, threads: usize) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: 2,
        accels_per_node: 2,
        steps: 24,
        lr: 0.02,
        seed: 7,
        net: NetModel::throttled(100.0),
        overlap,
        threads,
        ..Default::default()
    };
    c.apply_arg("repl", repl)?;
    Ok(c)
}

fn sim_time(repl: &str, overlap: bool) -> Result<(f64, f64, f64)> {
    let rt = runtime()?;
    let mut t = Trainer::new(&rt, cfg(repl, overlap, 1)?)?;
    let m = t.run()?;
    Ok((
        m.mean_step_time(),
        m.total_exposed_comm(),
        m.total_hidden_comm(),
    ))
}

fn wall_time(repl: &str, threads: usize) -> Result<f64> {
    let rt = runtime()?;
    let mut t = Trainer::new(&rt, cfg(repl, true, threads)?)?;
    let t0 = std::time::Instant::now();
    t.run()?;
    Ok(t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let schemes = ["full", "demo:1/8", "random:1/16", "diloco:8"];
    let mut rows = Vec::new();
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>10} {:>10} {:>10} {:>8}",
        "scheme", "serial/step", "overlap/step", "speedup", "hidden", "wall 1t", "wall 4t", "wallx"
    );
    for repl in schemes {
        let (ser, _, _) = sim_time(repl, false)?;
        let (ovl, exposed, hidden) = sim_time(repl, true)?;
        let w1 = wall_time(repl, 1)?;
        let w4 = wall_time(repl, 4)?;
        println!(
            "{:<14} {:>12} {:>12} {:>7.2}x {:>10} {:>10} {:>10} {:>7.2}x",
            repl,
            fmt_secs(ser),
            fmt_secs(ovl),
            ser / ovl,
            fmt_secs(hidden),
            fmt_secs(w1),
            fmt_secs(w4),
            w1 / w4,
        );
        rows.push(Json::obj(vec![
            ("scheme", Json::Str(repl.to_string())),
            ("serialized_step_s", Json::Num(ser)),
            ("overlapped_step_s", Json::Num(ovl)),
            ("sim_speedup", Json::Num(ser / ovl)),
            ("exposed_comm_s", Json::Num(exposed)),
            ("hidden_comm_s", Json::Num(hidden)),
            ("wall_1_thread_s", Json::Num(w1)),
            ("wall_4_threads_s", Json::Num(w4)),
            ("wall_speedup", Json::Num(w1 / w4)),
        ]));
    }
    // -- bucketed pipeline: exposed-comm comparison on a compute-rich arm
    let bucket_run = |bucket_mb: f64| -> Result<(f64, f64, f64)> {
        let rt = runtime()?;
        let mut c = cfg("demo:1/8", true, 1)?;
        c.net.device_flops = 5e10; // backward window ≫ per-bucket α
        c.bucket_mb = bucket_mb;
        let mut t = Trainer::new(&rt, c)?;
        let m = t.run()?;
        Ok((
            m.mean_step_time(),
            m.total_exposed_comm(),
            m.total_hidden_comm(),
        ))
    };
    let (whole_step, whole_exposed, _) = bucket_run(0.0)?;
    let (bucket_step, bucket_exposed, _) = bucket_run(0.01)?;
    println!(
        "bucketed demo:1/8 @0.01 MiB: step {} -> {} ({:.2}x), exposed {} -> {}",
        fmt_secs(whole_step),
        fmt_secs(bucket_step),
        whole_step / bucket_step,
        fmt_secs(whole_exposed),
        fmt_secs(bucket_exposed),
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("overlap".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("inter_mbps", Json::Num(100.0)),
        ("schemes", Json::Arr(rows)),
        (
            "bucketed",
            Json::obj(vec![
                ("scheme", Json::Str("demo:1/8".into())),
                ("bucket_mb", Json::Num(0.01)),
                ("whole_step_s", Json::Num(whole_step)),
                ("bucketed_step_s", Json::Num(bucket_step)),
                ("step_speedup", Json::Num(whole_step / bucket_step)),
                ("whole_exposed_s", Json::Num(whole_exposed)),
                ("bucketed_exposed_s", Json::Num(bucket_exposed)),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_overlap.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
