//! Chaos bench: elastic membership under churn.
//!
//!     cargo bench --bench chaos [-- --quick]
//!
//! On a 4×1 mesh running synchronous DiLoCo (`diloco:4`) over a
//! comm-visible link, sweeps a deterministic membership timeline across
//! five arms:
//!
//! * `baseline` — fixed group, no churn;
//! * `churn-mild` — node 1 leaves a quarter into the run and rejoins at
//!   the half-way mark;
//! * `churn-heavy` — nodes 1 *and* 2 leave (staggered) and rejoin later;
//! * `crash-norejoin` — node 1 crashes at the half-way mark and never
//!   returns (the survivors re-form a 3-node group for the rest);
//! * `crash-rejoin-ckpt` — node 1 crashes with `--checkpoint-dir` set
//!   and rejoins, restoring its private state from the stashed
//!   checkpoint (the full crash→stash→restore→broadcast path).
//!
//! Asserted here (deterministic, schedule-independent):
//!
//! * every arm completes with finite losses, and the `membership` steps
//!   column tracks the timeline exactly (masks at probe steps);
//! * the crash arm actually stashed `crash-node1.ckpt`;
//! * departed nodes stop driving inter-node traffic (mild churn's total
//!   inter bytes stay below baseline's plus the join broadcast).
//!
//! The *statistical* invariants — graceful degradation (churned tail
//! losses stay inside a bounded band of baseline) and the
//! crash-then-rejoin gap (checkpointed rejoin lands within a bounded
//! gap of the uninterrupted run) — are written into `BENCH_chaos.json`
//! (schema: docs/BENCHMARKS.md) and enforced by
//! `scripts/bench_gate.py`, so a regression fails CI with the numbers
//! in hand.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const PERIOD: u64 = 4;
/// Tail window for the loss comparisons (steps).
const TAIL: usize = 8;

fn base_cfg(steps: u64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: 4,
        accels_per_node: 1,
        steps,
        lr: 0.02,
        seed: 17,
        val_every: steps, // validate once, at the end
        val_batches: 8,
        ..Default::default()
    };
    // A visibly throttled link so membership changes move the clock,
    // not just the numerics.
    c.apply_arg("inter-mbps", "200")?;
    c.apply_arg("repl", &format!("diloco:{PERIOD}"))?;
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = detonation::train::Trainer::new(&rt, c)?;
    let m = t.run()?;
    anyhow::ensure!(
        m.steps.iter().all(|r| r.loss.is_finite()),
        "non-finite loss"
    );
    Ok(m)
}

fn row(label: &str, m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("inter_bytes", Json::Num(m.total_inter_bytes() as f64)),
        (
            "tail_loss",
            m.tail_loss(TAIL).map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "final_val_loss",
            m.final_val_loss().map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "final_membership",
            Json::Str(
                m.steps
                    .last()
                    .map(|r| r.membership.clone())
                    .unwrap_or_default(),
            ),
        ),
    ])
}

fn mask_at(m: &RunMetrics, step: u64) -> &str {
    &m.steps[step as usize].membership
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps: u64 = if quick { 16 } else { 40 };
    let t_leave = steps / 4; // mild/heavy leave, crash-rejoin crash
    let t_join = steps / 2; // mild/heavy rejoin, crash-norejoin crash

    println!(
        "{:<20} {:>12} {:>12} {:>10} {:>10}  {}",
        "arm", "t/step", "total", "tail", "val", "final mask"
    );
    let print_row = |label: &str, m: &RunMetrics| {
        println!(
            "{:<20} {:>12} {:>12} {:>10.4} {:>10.4}  {}",
            label,
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            m.tail_loss(TAIL).unwrap_or(f64::NAN),
            m.final_val_loss().unwrap_or(f64::NAN),
            m.steps.last().map(|r| r.membership.as_str()).unwrap_or(""),
        );
    };

    // baseline: fixed group
    let base = run(base_cfg(steps)?)?;
    print_row("baseline", &base);
    assert!(
        base.steps.iter().all(|r| r.membership.is_empty()),
        "baseline must not carry a membership column"
    );

    // churn-mild: node 1 out for a quarter of the run
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("churn", &format!("leave:1@{t_leave},join:1@{t_join}"))?;
    let mild = run(cfg)?;
    print_row("churn-mild", &mild);
    assert_eq!(mask_at(&mild, 0), "1111");
    assert_eq!(mask_at(&mild, t_leave), "1011");
    assert_eq!(mask_at(&mild, t_join), "1111");

    // churn-heavy: nodes 1 and 2 out, staggered
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg(
        "churn",
        &format!(
            "leave:1@{t_leave},leave:2@{},join:1@{t_join},join:2@{}",
            t_leave + 1,
            t_join + 1
        ),
    )?;
    let heavy = run(cfg)?;
    print_row("churn-heavy", &heavy);
    assert_eq!(mask_at(&heavy, t_leave + 1), "1001");
    assert_eq!(mask_at(&heavy, t_join + 1), "1111");

    // crash-norejoin: node 1 dies half-way and stays dead
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("crash", &format!("1@{t_join}"))?;
    let norejoin = run(cfg)?;
    print_row("crash-norejoin", &norejoin);
    assert_eq!(mask_at(&norejoin, t_join), "1011");
    assert_eq!(mask_at(&norejoin, steps - 1), "1011");

    // crash-rejoin-ckpt: crash + checkpointed rejoin
    let ckpt_dir = std::env::temp_dir().join("detonation-chaos-ckpt");
    std::fs::remove_dir_all(&ckpt_dir).ok();
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("crash", &format!("1@{t_leave}:{t_join}"))?;
    cfg.checkpoint_dir = Some(ckpt_dir.clone());
    let rejoin = run(cfg)?;
    print_row("crash-rejoin-ckpt", &rejoin);
    assert_eq!(mask_at(&rejoin, t_leave), "1011");
    assert_eq!(mask_at(&rejoin, t_join), "1111");
    assert!(
        ckpt_dir.join("crash-node1.ckpt").exists(),
        "crash did not stash a checkpoint"
    );
    std::fs::remove_dir_all(&ckpt_dir).ok();

    // Structural traffic check: while a node is away the gather loses a
    // member, so mild churn can only reduce total gather traffic; the
    // one addition is the join broadcast (param buffer from node 0).
    // Bound: mild's inter bytes < baseline's + 2× the parameter bytes.
    let param_bytes = {
        let t = detonation::train::Trainer::new(&runtime()?, base_cfg(1)?)?;
        (t.layout.padded_len * 4) as u64
    };
    assert!(
        mild.total_inter_bytes() < base.total_inter_bytes() + 2 * param_bytes,
        "mild churn drove more traffic than the fixed group: {} vs {} (+{param_bytes} join)",
        mild.total_inter_bytes(),
        base.total_inter_bytes()
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("mesh", Json::Str("4x1".into())),
        ("period", Json::Num(PERIOD as f64)),
        ("steps", Json::Num(steps as f64)),
        ("tail_window", Json::Num(TAIL as f64)),
        ("quick", Json::Bool(quick)),
        ("membership_masks_tracked", Json::Bool(true)),
        ("crash_checkpoint_stashed", Json::Bool(true)),
        (
            "arms",
            Json::Arr(vec![
                row("baseline", &base),
                row("churn-mild", &mild),
                row("churn-heavy", &heavy),
                row("crash-norejoin", &norejoin),
                row("crash-rejoin-ckpt", &rejoin),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_chaos.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
