//! DCT micro-bench: encode/decode throughput across chunk sizes — the L3
//! extraction hot path (perf deliverable; target ≥ 1 GB/s/core encode).
//! Compares the blocked multi-chunk kernel against the recursive
//! per-chunk reference and writes element-throughput + allocation counts
//! to `BENCH_dct.json` (the perf-trajectory artifact).
//!
//!     cargo bench --bench dct

use std::time::Instant;

use detonation::dct::{Dct, DctScratch};
use detonation::util::json::Json;
use detonation::util::rng::Rng;

#[path = "util/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_count, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Time `f`; returns (micros/iter, allocs/iter).
fn bench<F: FnMut()>(name: &str, elems_per_iter: u64, mut f: F) -> (f64, f64) {
    for _ in 0..3 {
        f();
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < 0.4 {
        f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let us = dt / iters as f64 * 1e6;
    let allocs = (alloc_count() - a0) as f64 / iters as f64;
    println!(
        "{name:<34} {us:>10.1} µs/iter {:>9.1} Melem/s {:>8.2} GB/s {allocs:>8.1} allocs",
        elems_per_iter as f64 / (us / 1e6) / 1e6,
        (elems_per_iter * 4) as f64 / (us / 1e6) / 1e9,
    );
    (us, allocs)
}

fn row(name: &str, chunk: usize, elems: u64, (us, allocs): (f64, f64)) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("chunk", Json::Num(chunk as f64)),
        ("micros_per_iter", Json::Num(us)),
        ("elements_per_sec", Json::Num(elems as f64 / (us / 1e6))),
        ("allocs_per_iter", Json::Num(allocs)),
    ])
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    let n = 1 << 20; // 1M elements = 4 MiB
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let mut out = vec![0.0f32; n];
    println!("chunked DCT over {} MiB buffer:", n * 4 / (1 << 20));
    let mut rows = Vec::new();

    for chunk in [16usize, 32, 64, 128, 256] {
        let d = Dct::plan(chunk);
        let mut s = DctScratch::new();
        let r = bench(&format!("dct2 blocked chunk={chunk}"), n as u64, || {
            d.forward_chunked_with(&x, &mut out, &mut s);
        });
        rows.push(row("dct2_blocked", chunk, n as u64, r));
        let r = bench(&format!("dct2 recursive chunk={chunk}"), n as u64, || {
            d.forward_chunked_recursive(&x, &mut out);
        });
        rows.push(row("dct2_recursive", chunk, n as u64, r));
    }
    for chunk in [64usize, 256] {
        let d = Dct::plan(chunk);
        // dense inverse
        let c = out.clone();
        let mut back = vec![0.0f32; n];
        let mut s = DctScratch::new();
        let r = bench(&format!("dct3 dense chunk={chunk}"), n as u64, || {
            d.inverse_chunked_with(&c, &mut back, &mut s);
        });
        rows.push(row("dct3_dense_blocked", chunk, n as u64, r));
        // sparse inverse (k=chunk/8 nonzero) — the real decode workload
        let mut sparse = vec![0.0f32; n];
        for ch in 0..n / chunk {
            for k in 0..chunk / 8 {
                sparse[ch * chunk + k * 7 % chunk] = 1.0;
            }
        }
        let r = bench(&format!("dct3 sparse chunk={chunk}"), n as u64, || {
            d.inverse_chunked_with(&sparse, &mut back, &mut s);
        });
        rows.push(row("dct3_sparse", chunk, n as u64, r));
    }

    let out_json = Json::obj(vec![
        ("bench", Json::Str("dct".into())),
        ("elements", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_dct.json");
    detonation::util::atomic_write(&path, out_json.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
