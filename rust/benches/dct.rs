//! DCT micro-bench: encode/decode throughput across chunk sizes — the L3
//! extraction hot path (perf deliverable; target ≥ 1 GB/s/core encode).
//!
//!     cargo bench --bench dct

use detonation::dct::Dct;
use detonation::util::rng::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, bytes_per_iter: u64, mut f: F) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < 0.5 {
        f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let gbps = (bytes_per_iter * iters) as f64 / dt / 1e9;
    println!(
        "{name:<32} {:>10.1} µs/iter {:>8.2} GB/s",
        dt / iters as f64 * 1e6,
        gbps
    );
}

fn main() {
    let mut rng = Rng::new(1);
    let n = 1 << 20; // 1M elements = 4 MiB
    let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let mut out = vec![0.0f32; n];
    println!("chunked DCT over {} MiB buffer:", n * 4 / (1 << 20));

    for chunk in [16usize, 32, 64, 128, 256] {
        let d = Dct::plan(chunk);
        bench(&format!("dct2 chunk={chunk}"), (n * 4) as u64, || {
            d.forward_chunked(&x, &mut out);
        });
    }
    for chunk in [64usize, 256] {
        let d = Dct::plan(chunk);
        // dense inverse
        let c = out.clone();
        let mut back = vec![0.0f32; n];
        bench(&format!("dct3 dense chunk={chunk}"), (n * 4) as u64, || {
            d.inverse_chunked(&c, &mut back);
        });
        // sparse inverse (k=chunk/8 nonzero) — the real decode workload
        let mut sparse = vec![0.0f32; n];
        for ch in 0..n / chunk {
            for k in 0..chunk / 8 {
                sparse[ch * chunk + k * 7 % chunk] = 1.0;
            }
        }
        bench(&format!("dct3 sparse chunk={chunk}"), (n * 4) as u64, || {
            d.inverse_chunked(&sparse, &mut back);
        });
    }
}
