//! Straggler bench: late-arrival policy × straggler severity.
//!
//!     cargo bench --bench stragglers [-- --quick]
//!
//! On a 2×2 mesh whose inter-node bandwidth is tuned so one sync
//! transfer spans ~20 fast-node steps (comm-exposed, but still inside a
//! 4× straggler's arrival deadline), sweeps async DiLoCo
//! (`diloco:8`, S = 2) over
//!
//! * straggler severity — node 1 compute slowdown ∈ {1×, 2×, 4×} — and
//! * late policy — `wait` (PR 4 whole-group window) vs `drop` (NoLoCo
//!   quorum) vs `partial` (late deltas fold into the next window) —
//!
//! plus a `--staleness auto` arm that derives each node's window from
//! its profile. Asserts the PR's acceptance criteria while writing
//! `BENCH_stragglers.json` at the repo root (schema: docs/BENCHMARKS.md;
//! `--quick` shrinks the run for the CI smoke step):
//!
//! * under the 4× straggler, `drop` and `partial` are strictly faster
//!   than `wait` in simulated time (an admitted contribution can never
//!   stall its admitter; `wait` blocks every arrival on the straggler's
//!   launch + full send queue);
//! * on the homogeneous cluster, the `wait` arm — configured through the
//!   per-node staleness table — is bit-identical to the PR 4 async path
//!   configured through the plain global `--staleness` knob;
//! * the tolerant arms actually exercised the policy (`dropped_syncs`
//!   counted late contributions under the 4× straggler);
//! * a NIC-severity sweep (node 1's link at 1/2× and 1/4× of the tuned
//!   bandwidth, `wait` vs `drop`) shows the same ordering for degraded
//!   links as for degraded compute: at 4× NIC severity `drop` is
//!   strictly faster and actually dropped late contributions.

use anyhow::Result;
use detonation::compress::Scratch;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::net::ClusterModel;
use detonation::replicate::{ReplBuildCtx, ReplCtx, Replicator, ReplSpec};
use detonation::train::Trainer;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const PERIOD: u64 = 8;
const STALENESS: u64 = 2;
/// How many fast-node compute steps one sync transfer spans. The
/// interesting regime is `S·severity < XFER_STEPS < period·severity`
/// for the 4× arm: the transfer is too long for the straggler's
/// `wait` window (so `wait` stalls every arrival) but short enough
/// that the NIC is not saturated (so tolerating the straggler actually
/// moves the horizon) — and fast contributions still land inside the
/// straggler's own deadline, keeping the quorums non-trivial.
const XFER_STEPS: f64 = 20.0;
/// Pinned fast-node step time (s). Chosen far above the α latency so
/// the tuned transfer is bandwidth- not latency-shaped.
const STEP_TIME: f64 = 1e-3;

fn base_cfg(steps: u64, step_flops: f64, inter_bw: f64, severity: f64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: 2,
        accels_per_node: 2,
        steps,
        lr: 0.02,
        seed: 11,
        val_every: steps, // validate once, at the end of the run
        val_batches: 8,
        ..Default::default()
    };
    c.net.device_flops = step_flops / STEP_TIME;
    c.net.inter_bw = inter_bw;
    if severity != 1.0 {
        c.cluster = ClusterModel {
            slowdown: ClusterModel::parse_slowdown(&format!("1:{severity}"))?,
            node_inter_bw: vec![],
        };
    }
    c.apply_arg("repl", &format!("diloco:{PERIOD}"))?;
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = Trainer::new(&rt, c)?;
    t.run()
}

fn row(label: &str, severity: f64, policy: &str, m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("severity", Json::Num(severity)),
        ("policy", Json::Str(policy.to_string())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("exposed_comm_s", Json::Num(m.total_exposed_comm())),
        ("hidden_comm_s", Json::Num(m.total_hidden_comm())),
        ("dropped_syncs", Json::Num(m.total_dropped_syncs() as f64)),
        (
            "node_staleness",
            Json::Str(
                m.steps
                    .first()
                    .map(|r| r.node_staleness.clone())
                    .unwrap_or_default(),
            ),
        ),
        (
            "final_val_loss",
            m.final_val_loss().map(Json::Num).unwrap_or(Json::Null),
        ),
    ])
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 3 * PERIOD } else { 6 * PERIOD };

    // Tune the mesh: pin the fast-node step to STEP_TIME via
    // device_flops, probe the exact wire size of a full-buffer payload
    // at this mesh's shard length, and set the inter-node bandwidth so
    // one sync transfer spans XFER_STEPS fast steps
    // (bytes / bw = XFER_STEPS · STEP_TIME).
    let (wire_bytes, step_flops) = {
        let probe_cfg = base_cfg(1, 1e9, 1e9, 1.0)?;
        let t = Trainer::new(&runtime()?, probe_cfg)?;
        let shard_len = t.mesh.shards.shard_len();
        let mut repl =
            ReplSpec::parse("diloco:1")?.build_for_node(0, &ReplBuildCtx::uniform(shard_len))?;
        let mut buf = vec![0.0f32; shard_len];
        let ctx = ReplCtx {
            step: 0,
            shard: 0,
            seed: 1,
        };
        let (_, p) = repl.extract(&ctx, &mut buf, &mut Scratch::new());
        let wire = p.expect("diloco:1 syncs at step 0").wire_bytes();
        (wire, t.model.manifest.step_flops())
    };
    let inter_bw = wire_bytes as f64 / (XFER_STEPS * STEP_TIME);
    println!(
        "tuned link: payload {wire_bytes} B, step {} -> {:.3} Mbit/s",
        fmt_secs(STEP_TIME),
        inter_bw * 8.0 / 1e6
    );

    println!(
        "{:<26} {:>9} {:>8} {:>12} {:>12} {:>9} {:>10}",
        "arm", "severity", "policy", "t/step", "total", "dropped", "val"
    );
    let print_row = |label: &str, m: &RunMetrics| {
        println!(
            "{:<26} {:>9} {:>8} {:>12} {:>12} {:>9} {:>10.4}",
            label,
            "",
            "",
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            m.total_dropped_syncs(),
            m.final_val_loss().unwrap_or(f64::NAN),
        );
    };

    // PR 4 reference: the plain global --staleness knob, homogeneous.
    let mut pr4_cfg = base_cfg(steps, step_flops, inter_bw, 1.0)?;
    pr4_cfg.apply_arg("staleness", &STALENESS.to_string())?;
    let pr4 = run(pr4_cfg)?;
    print_row("pr4 async (global S)", &pr4);
    let mut rows = vec![row("pr4-async-global", 1.0, "wait", &pr4)];

    let mut by_key = std::collections::BTreeMap::new();
    for &severity in &[1.0f64, 2.0, 4.0] {
        for policy in ["wait", "drop", "partial"] {
            let mut cfg = base_cfg(steps, step_flops, inter_bw, severity)?;
            if policy == "wait" {
                // Route the uniform window through the per-node table so
                // the bit-identity claim below covers the resolution
                // logic, not just identical specs.
                cfg.apply_arg("node-staleness", &format!("0:{STALENESS},1:{STALENESS}"))?;
                cfg.apply_arg("late-policy", "wait")?;
            } else {
                cfg.apply_arg("staleness", &STALENESS.to_string())?;
                cfg.apply_arg("late-policy", policy)?;
            }
            let m = run(cfg)?;
            print_row(&format!("s{severity} {policy}"), &m);
            rows.push(row(
                &format!("severity{severity}-{policy}"),
                severity,
                policy,
                &m,
            ));
            by_key.insert((severity as u64, policy.to_string()), m);
        }
    }

    // Acceptance 1: homogeneous wait (via the node table) is
    // bit-identical to the PR 4 global-staleness path.
    let wait1 = &by_key[&(1u64, "wait".to_string())];
    assert_eq!(
        wait1
            .steps
            .iter()
            .map(|r| r.loss.to_bits())
            .collect::<Vec<_>>(),
        pr4.steps
            .iter()
            .map(|r| r.loss.to_bits())
            .collect::<Vec<_>>(),
        "homogeneous wait diverged from the PR 4 async losses"
    );
    assert_eq!(
        wait1.total_sim_time().to_bits(),
        pr4.total_sim_time().to_bits(),
        "homogeneous wait changed the PR 4 async schedule"
    );
    assert_eq!(
        wait1.final_val_loss().map(f64::to_bits),
        pr4.final_val_loss().map(f64::to_bits),
        "homogeneous wait diverged from the PR 4 async validation"
    );

    // Acceptance 2: under the 4× straggler, drop and partial are
    // strictly faster than wait in simulated time.
    let wait4 = &by_key[&(4u64, "wait".to_string())];
    for policy in ["drop", "partial"] {
        let m = &by_key[&(4u64, policy.to_string())];
        assert!(
            m.total_sim_time() < wait4.total_sim_time(),
            "{policy} not faster than wait under the 4x straggler: {} vs {}",
            m.total_sim_time(),
            wait4.total_sim_time()
        );
        assert!(
            m.total_dropped_syncs() > 0,
            "{policy} recorded no late contributions under the 4x straggler"
        );
    }
    assert_eq!(
        wait4.total_dropped_syncs(),
        0,
        "the wait window must never drop"
    );

    // NIC-severity sweep: instead of slow *compute*, node 1 gets a slow
    // *NIC* (its link runs at 1/severity of the tuned bandwidth, so its
    // sync transfer spans severity·XFER_STEPS fast steps — far past the
    // S = 2 deadline). The same ordering must hold: tolerating the
    // degraded link beats waiting for it.
    let mut nic_by_key = std::collections::BTreeMap::new();
    for &severity in &[2.0f64, 4.0] {
        for policy in ["wait", "drop"] {
            let mut cfg = base_cfg(steps, step_flops, inter_bw, 1.0)?;
            cfg.cluster.node_inter_bw = ClusterModel::parse_node_mbps(&format!(
                "1:{}",
                inter_bw / severity * 8.0 / 1e6
            ))?;
            cfg.apply_arg("staleness", &STALENESS.to_string())?;
            cfg.apply_arg("late-policy", policy)?;
            let m = run(cfg)?;
            print_row(&format!("nic{severity} {policy}"), &m);
            rows.push(row(
                &format!("nic{severity}-{policy}"),
                severity,
                policy,
                &m,
            ));
            nic_by_key.insert((severity as u64, policy.to_string()), m);
        }
    }

    // Acceptance 3: under the 4× NIC degradation, drop is strictly
    // faster than wait, and the policy actually fired.
    let nic_wait4 = &nic_by_key[&(4u64, "wait".to_string())];
    let nic_drop4 = &nic_by_key[&(4u64, "drop".to_string())];
    assert!(
        nic_drop4.total_sim_time() < nic_wait4.total_sim_time(),
        "drop not faster than wait under the 4x NIC straggler: {} vs {}",
        nic_drop4.total_sim_time(),
        nic_wait4.total_sim_time()
    );
    assert!(
        nic_drop4.total_dropped_syncs() > 0,
        "drop recorded no late contributions under the 4x NIC straggler"
    );

    // The auto arm: profile-derived per-node windows under the 4×
    // straggler (recorded, not asserted — the table is the datum).
    let mut auto_cfg = base_cfg(steps, step_flops, inter_bw, 4.0)?;
    auto_cfg.apply_arg("staleness", "auto")?;
    auto_cfg.apply_arg("late-policy", "drop")?;
    let auto = run(auto_cfg)?;
    print_row("s4 auto drop", &auto);
    rows.push(row("severity4-auto-drop", 4.0, "drop", &auto));

    let out = Json::obj(vec![
        ("bench", Json::Str("stragglers".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("mesh", Json::Str("2x2".into())),
        ("period", Json::Num(PERIOD as f64)),
        ("staleness", Json::Num(STALENESS as f64)),
        ("xfer_steps", Json::Num(XFER_STEPS)),
        ("inter_mbps", Json::Num(inter_bw * 8.0 / 1e6)),
        ("steps", Json::Num(steps as f64)),
        ("quick", Json::Bool(quick)),
        ("homogeneous_bit_identical_to_pr4_async", Json::Bool(true)),
        ("drop_beats_wait_under_4x_straggler", Json::Bool(true)),
        ("partial_beats_wait_under_4x_straggler", Json::Bool(true)),
        ("drop_beats_wait_under_4x_nic_straggler", Json::Bool(true)),
        ("arms", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_stragglers.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
