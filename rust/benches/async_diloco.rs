//! Async DiLoCo bench: convergence vs wallclock as the staleness knob
//! sweeps.
//!
//!     cargo bench --bench async_diloco [-- --quick]
//!
//! On a comm-exposed two-node link (100 Mbps — the paper's Fig 10
//! regime) with the synthetic surrogate LM, runs
//!
//! * synchronous DiLoCo (`diloco:8`) and the conventional AdamW
//!   full-sync baseline, and
//! * async DiLoCo at `--staleness S` for `S ∈ {0, 1, 2, 4}`,
//!
//! recording simulated time per step (the wallclock axis: local steps
//! keep running while the periodic gather is in flight) against the
//! final validation loss (the convergence axis: the averaged delta
//! lands S steps late). Asserts the PR's acceptance criteria — `S = 0`
//! reproduces synchronous DiLoCo bit-for-bit, and every `S ≥ 1` is
//! strictly faster per step than the synchronous scheme — and writes
//! the sweep to `BENCH_async_diloco.json` at the repo root
//! (schema: docs/BENCHMARKS.md; `--quick` shrinks the run for the CI
//! smoke step).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::net::NetModel;
use detonation::train::Trainer;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const PERIOD: u64 = 8;

fn cfg(opt: &str, repl: &str, staleness: Option<u64>, steps: u64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: 2,
        accels_per_node: 2,
        steps,
        lr: 0.02,
        seed: 11,
        val_every: steps, // validate once, at the end of the run
        val_batches: 8,
        net: NetModel::throttled(100.0),
        ..Default::default()
    };
    c.apply_arg("opt", opt)?;
    c.apply_arg("repl", repl)?;
    if let Some(s) = staleness {
        c.apply_arg("staleness", &s.to_string())?;
    }
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = Trainer::new(&rt, c)?;
    t.run()
}

fn row(label: &str, staleness: Option<u64>, m: &RunMetrics, val_sync: f64) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        (
            "staleness",
            staleness.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
        ),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("exposed_comm_s", Json::Num(m.total_exposed_comm())),
        ("hidden_comm_s", Json::Num(m.total_hidden_comm())),
        ("inter_bytes", Json::Num(m.total_inter_bytes() as f64)),
        (
            "final_val_loss",
            m.final_val_loss().map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "val_delta_vs_sync_diloco",
            m.final_val_loss()
                .map(|v| Json::Num(v - val_sync))
                .unwrap_or(Json::Null),
        ),
    ])
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 3 * PERIOD } else { 8 * PERIOD };

    // Baselines: synchronous DiLoCo and conventional AdamW full-sync.
    let sync = run(cfg("demo-sgd", &format!("diloco:{PERIOD}"), None, steps)?)?;
    let adamw = run(cfg("adamw", "full", None, steps)?)?;
    let val_sync = sync.final_val_loss().expect("sync diloco validated");

    println!(
        "{:<22} {:>6} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "arm", "S", "t/step", "total", "hidden", "val", "Δval"
    );
    let print_row = |label: &str, m: &RunMetrics, s: Option<u64>| {
        println!(
            "{:<22} {:>6} {:>12} {:>12} {:>10} {:>10.4} {:>+10.4}",
            label,
            s.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            fmt_secs(m.total_hidden_comm()),
            m.final_val_loss().unwrap_or(f64::NAN),
            m.final_val_loss().unwrap_or(f64::NAN) - val_sync,
        );
    };
    print_row("diloco (sync)", &sync, None);
    print_row("adamw full-sync", &adamw, None);

    let mut rows = vec![
        row("diloco-sync", None, &sync, val_sync),
        row("adamw-full", None, &adamw, val_sync),
    ];
    for s in [0u64, 1, 2, 4] {
        let m = run(cfg("demo-sgd", &format!("diloco:{PERIOD}"), Some(s), steps)?)?;
        print_row(&format!("async diloco S={s}"), &m, Some(s));

        // Acceptance: S = 0 is synchronous DiLoCo, bit for bit…
        if s == 0 {
            assert_eq!(
                m.final_val_loss().map(f64::to_bits),
                sync.final_val_loss().map(f64::to_bits),
                "staleness 0 diverged from synchronous DiLoCo"
            );
            assert_eq!(
                m.total_sim_time().to_bits(),
                sync.total_sim_time().to_bits(),
                "staleness 0 changed the schedule"
            );
        } else {
            // …and any in-flight window buys wallclock on a
            // comm-exposed link.
            assert!(
                m.mean_step_time() < sync.mean_step_time(),
                "S={s} not faster per step: {} vs sync {}",
                m.mean_step_time(),
                sync.mean_step_time()
            );
        }
        rows.push(row(&format!("async-diloco-s{s}"), Some(s), &m, val_sync));
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("async_diloco".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("mesh", Json::Str("2x2".into())),
        ("inter_mbps", Json::Num(100.0)),
        ("period", Json::Num(PERIOD as f64)),
        ("steps", Json::Num(steps as f64)),
        ("quick", Json::Bool(quick)),
        ("arms", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_async_diloco.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
