//! Shared counting allocator for the perf benches: every `alloc`/
//! `realloc` bumps a global counter so a bench can assert (or report)
//! allocation counts per iteration. Each bench binary registers it with
//! `#[global_allocator]` — included via `#[path = …] mod counting_alloc;`
//! (this lives in a subdirectory so Cargo never mistakes it for a bench
//! target).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations (+ reallocations) since process start.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}
