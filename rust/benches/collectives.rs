//! Collectives micro-bench: real data movement + cost model, across group
//! sizes and buffer sizes (perf deliverable: coordinator off the critical
//! path relative to artifact execution).
//!
//!     cargo bench --bench collectives

use detonation::collectives::{naive_all_gather_bytes, ring_all_gather, ring_reduce_scatter_avg, CollCtx};
use detonation::net::{NetModel, Topology, TrafficMatrix};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    for _ in 0..2 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < 0.4 {
        f();
        iters += 1;
    }
    let us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
    println!("{name:<44} {us:>10.1} µs/op");
    us
}

fn main() {
    let model = NetModel::hpc();
    for (g, n) in [(2usize, 1 << 18), (4, 1 << 18), (8, 1 << 18), (4, 1 << 22)] {
        let topo = Topology::new(1, g);
        let traffic = TrafficMatrix::new(1);
        let ctx = CollCtx {
            topo: &topo,
            model: &model,
            traffic: &traffic,
        };
        let group: Vec<usize> = (0..g).collect();
        let shards: Vec<(usize, usize)> = (0..g).map(|i| (i * n / g, (i + 1) * n / g)).collect();
        let mut bufs: Vec<Vec<f32>> = (0..g).map(|i| vec![i as f32; n]).collect();
        bench(
            &format!("ring_reduce_scatter g={g} n={}K", n >> 10),
            || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_reduce_scatter_avg(&ctx, &group, &mut refs, &shards);
            },
        );
        bench(&format!("ring_all_gather    g={g} n={}K", n >> 10), || {
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            ring_all_gather(&ctx, &group, &mut refs, &shards);
        });
        let payloads: Vec<(Vec<u8>, u64)> = (0..g).map(|_| (vec![0u8; n / 8], (n / 8) as u64)).collect();
        bench(&format!("naive_all_gather   g={g} b={}K", n >> 13), || {
            std::hint::black_box(naive_all_gather_bytes(&ctx, &group, &payloads));
        });
    }
}
