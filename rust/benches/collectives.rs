//! Collectives micro-bench: real data movement + cost model, across group
//! sizes and buffer sizes (perf deliverable: coordinator off the critical
//! path relative to artifact execution). The data plane runs on the
//! worker pool — this bench reports pooled throughput per shape.
//!
//!     cargo bench --bench collectives [-- --quick]
//!
//! Results (µs/op + GB/s) land in `BENCH_collectives.json` at the repo
//! root (the perf-trajectory artifact).

use detonation::collectives::{
    naive_all_gather_bytes, ring_all_gather, ring_reduce_scatter_avg, CollCtx, CollScratch,
};
use detonation::net::{NetModel, Topology, TrafficMatrix};
use detonation::parallel::WorkerPool;
use detonation::util::json::Json;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, budget: f64, mut f: F) -> f64 {
    for _ in 0..2 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_secs_f64() < budget {
        f();
        iters += 1;
    }
    let us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
    println!("{name:<44} {us:>10.1} µs/op");
    us
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let budget = if quick { 0.05 } else { 0.4 };
    let model = NetModel::hpc();
    let pool = WorkerPool::new(0);
    let mut scratch = CollScratch::new();
    let mut rows = Vec::new();
    let shapes: &[(usize, usize)] = if quick {
        &[(2usize, 1 << 16), (4, 1 << 16)]
    } else {
        &[(2, 1 << 18), (4, 1 << 18), (8, 1 << 18), (4, 1 << 22)]
    };
    for &(g, n) in shapes {
        let topo = Topology::new(1, g);
        let traffic = TrafficMatrix::new(1);
        let mut ctx = CollCtx {
            topo: &topo,
            model: &model,
            traffic: &traffic,
            pool: &pool,
            scratch: &mut scratch,
        };
        let group: Vec<usize> = (0..g).collect();
        let shards: Vec<(usize, usize)> = (0..g).map(|i| (i * n / g, (i + 1) * n / g)).collect();
        let mut bufs: Vec<Vec<f32>> = (0..g).map(|i| vec![i as f32; n]).collect();
        let bytes_moved = (g * n * 4) as f64;
        let name = format!("ring_reduce_scatter g={g} n={}K", n >> 10);
        let us = bench(&name, budget, || {
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            ring_reduce_scatter_avg(&mut ctx, &group, &mut refs, &shards);
        });
        rows.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("micros_per_op", Json::Num(us)),
            ("gb_per_sec", Json::Num(bytes_moved / (us / 1e6) / 1e9)),
        ]));
        let name = format!("ring_all_gather    g={g} n={}K", n >> 10);
        let us = bench(&name, budget, || {
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            ring_all_gather(&mut ctx, &group, &mut refs, &shards);
        });
        rows.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("micros_per_op", Json::Num(us)),
            ("gb_per_sec", Json::Num(bytes_moved / (us / 1e6) / 1e9)),
        ]));
        let payloads: Vec<(Vec<u8>, u64)> =
            (0..g).map(|_| (vec![0u8; n / 8], (n / 8) as u64)).collect();
        let name = format!("naive_all_gather   g={g} b={}K", n >> 13);
        let us = bench(&name, budget, || {
            std::hint::black_box(naive_all_gather_bytes(&mut ctx, &group, &payloads));
        });
        rows.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("micros_per_op", Json::Num(us)),
            ("gb_per_sec", Json::Num((g * n / 8) as f64 / (us / 1e6) / 1e9)),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::Str("collectives".into())),
        ("pool_width", Json::Num(pool.width() as f64)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_collectives.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
