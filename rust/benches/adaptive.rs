//! Adaptive rate-control bench: AIMD vs uniform fixed rates on a mixed
//! cluster.
//!
//!     cargo bench --bench adaptive [-- --quick]
//!
//! Four nodes, one rank each, `random` replication every step on a
//! comm-exposed 100 Mbps link — except node 0, whose NIC runs at 25 Mbps
//! (the 4x mixed-NIC profile). Arms:
//!
//! * `fixed8` / `fixed16` / `fixed32` — uniform `random:1/N`, no
//!   controller: every node ships the same fraction, so the slow node's
//!   send paces every window;
//! * `aimd` — `--compress-control aimd` with a `[1/64, 1/16]` band: the
//!   controller backs node 0 off toward the floor (its NIC is busy and
//!   the comm is exposed) while the idle fast peers hold the cap.
//!
//! The claim under test is water-filling: with per-node rates the gate
//! is `max(slow_rate/slow_bw, fast_rate/fast_bw)`, which the controller
//! drives below what ANY uniform rate can reach — a uniform rate pays
//! `rate/slow_bw` on the slow NIC. Asserted here (deterministic,
//! seeded): the `aimd` arm's per-step simulated time is strictly below
//! every fixed arm's, its tail loss stays within `LOSS_BAND`x the
//! uncontrolled `fixed8` baseline (compression error feedback keeps the
//! residual), and `--compress-control off` (plus the band/window knobs)
//! is bit-identical to a config that never mentions the controller. The
//! same invariants are written into `BENCH_adaptive.json` (schema:
//! docs/BENCHMARKS.md) and enforced by `scripts/bench_gate.py`.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::runtime;
use detonation::metrics::RunMetrics;
use detonation::util::fmt_secs;
use detonation::util::json::Json;

const NODES: usize = 4;
/// Tail window for the loss comparisons (steps).
const TAIL: usize = 4;
/// The aimd arm's tail loss may cost at most this multiple of the
/// uncontrolled fixed-1/8 baseline's.
const LOSS_BAND: f64 = 1.5;
/// Steps per controller window (short, so --quick still retunes).
const WINDOW: u64 = 2;

const FIXED: [u64; 3] = [8, 16, 32];

fn base_cfg(steps: u64) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig {
        model: "synthetic-lm".into(),
        nodes: NODES,
        accels_per_node: 1,
        steps,
        lr: 0.02,
        seed: 47,
        val_every: steps, // validate once, at the end
        val_batches: 4,
        ..Default::default()
    };
    // Comm-exposed for the whole cluster, with node 0 at a quarter of
    // its peers' NIC bandwidth — the profile the controller exploits.
    c.apply_arg("inter-mbps", "100")?;
    c.apply_arg("node-mbps", "0:25")?;
    c.apply_arg("repl", "random:1/8")?;
    Ok(c)
}

fn run(c: ExperimentConfig) -> Result<RunMetrics> {
    let rt = runtime()?;
    let mut t = detonation::train::Trainer::new(&rt, c)?;
    let m = t.run()?;
    anyhow::ensure!(
        m.steps.iter().all(|r| r.loss.is_finite()),
        "non-finite loss"
    );
    Ok(m)
}

fn row(label: &str, m: &RunMetrics) -> Json {
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("sim_time_s", Json::Num(m.total_sim_time())),
        ("sim_step_s", Json::Num(m.mean_step_time())),
        ("inter_bytes", Json::Num(m.total_inter_bytes() as f64)),
        (
            "tail_loss",
            m.tail_loss(TAIL).map(Json::Num).unwrap_or(Json::Null),
        ),
    ])
}

/// Bit-level fingerprint of a run: per-step losses and sim times.
fn bits(m: &RunMetrics) -> (Vec<u64>, Vec<u64>) {
    (
        m.steps.iter().map(|r| r.loss.to_bits()).collect(),
        m.steps.iter().map(|r| r.sim_time.to_bits()).collect(),
    )
}

fn main() -> Result<()> {
    detonation::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    // Enough windows for the controller to settle past its transient
    // even under --quick (window = 2 -> 8 retunes minimum).
    let steps: u64 = if quick { 16 } else { 32 };

    println!(
        "{:<12} {:>12} {:>12} {:>14} {:>10} {:>22}",
        "arm", "t/step", "total", "inter", "tail", "final rates"
    );
    let print_row = |label: &str, m: &RunMetrics| {
        println!(
            "{:<12} {:>12} {:>12} {:>14} {:>10.4} {:>22}",
            label,
            fmt_secs(m.mean_step_time()),
            fmt_secs(m.total_sim_time()),
            m.total_inter_bytes(),
            m.tail_loss(TAIL).unwrap_or(f64::NAN),
            m.steps.last().map(|r| r.rate.clone()).unwrap_or_default(),
        );
    };

    // The bit-freeze anchor: explicit `--compress-control off` (with the
    // window/band knobs, which must be inert while off) against a config
    // that never mentions the controller.
    let absent = run(base_cfg(steps)?)?;
    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("compress-control", "off")?;
    cfg.apply_arg("control-window", &WINDOW.to_string())?;
    cfg.apply_arg("rate-min", "1/64")?;
    cfg.apply_arg("rate-max", "1/16")?;
    let off = run(cfg)?;
    let off_bit_identical = bits(&absent) == bits(&off);
    assert!(
        off_bit_identical,
        "--compress-control off diverged from the controller-free path"
    );
    assert!(
        off.steps.iter().all(|r| r.rate.is_empty()),
        "off-arm run populated the rate column"
    );

    let mut arms: Vec<Json> = Vec::new();
    let mut fixed_runs: Vec<(String, RunMetrics)> = Vec::new();
    for n in FIXED {
        let mut cfg = base_cfg(steps)?;
        cfg.apply_arg("repl", &format!("random:1/{n}"))?;
        let m = run(cfg)?;
        let label = format!("fixed{n}");
        print_row(&label, &m);
        arms.push(row(&label, &m));
        fixed_runs.push((label, m));
    }

    let mut cfg = base_cfg(steps)?;
    cfg.apply_arg("compress-control", "aimd")?;
    cfg.apply_arg("control-window", &WINDOW.to_string())?;
    cfg.apply_arg("rate-min", "1/64")?;
    cfg.apply_arg("rate-max", "1/16")?;
    let aimd = run(cfg)?;
    print_row("aimd", &aimd);
    arms.push(row("aimd", &aimd));
    assert!(
        aimd.steps.last().is_some_and(|r| !r.rate.is_empty()),
        "aimd arm never populated the rate column"
    );

    // Water-filling beats every uniform rate on the mixed profile.
    let mut controller_beats_fixed = true;
    for (label, m) in &fixed_runs {
        let ratio = aimd.mean_step_time() / m.mean_step_time();
        println!("aimd / {label} per-step ratio {ratio:.3}");
        if aimd.mean_step_time() >= m.mean_step_time() {
            controller_beats_fixed = false;
        }
    }
    assert!(
        controller_beats_fixed,
        "the controller arm did not beat every uniform fixed rate"
    );

    // ...without giving the convergence away: tail loss stays inside the
    // band around the uncontrolled spec-rate baseline.
    let base_tail = fixed_runs[0].1.tail_loss(TAIL).expect("fixed8 tail");
    let aimd_tail = aimd.tail_loss(TAIL).expect("aimd tail");
    let loss_within_band = aimd_tail <= base_tail * LOSS_BAND;
    assert!(
        loss_within_band,
        "aimd tail loss {aimd_tail:.4} outside {LOSS_BAND}x of the \
         fixed-1/8 baseline {base_tail:.4}"
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("adaptive".into())),
        ("model", Json::Str("synthetic-lm".into())),
        ("nodes", Json::Num(NODES as f64)),
        ("steps", Json::Num(steps as f64)),
        ("control_window", Json::Num(WINDOW as f64)),
        ("tail_window", Json::Num(TAIL as f64)),
        ("loss_band", Json::Num(LOSS_BAND)),
        ("quick", Json::Bool(quick)),
        ("off_bit_identical", Json::Bool(off_bit_identical)),
        ("controller_beats_fixed", Json::Bool(controller_beats_fixed)),
        ("loss_within_band", Json::Bool(loss_within_band)),
        ("arms", Json::Arr(arms)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_adaptive.json");
    detonation::util::atomic_write(&path, out.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
