//! Integration tests: the full three-layer stack (artifacts → PJRT →
//! FlexDeMo coordinator) on tiny models.
//!
//! Artifact-backed tests require `make artifacts` (they skip gracefully
//! when artifacts are absent so `cargo test` works in a fresh checkout).
//! The event-engine invariant suite at the bottom runs everywhere: it
//! drives the pure-Rust surrogate runtime on `synthetic-*` models.

use detonation::config::ExperimentConfig;
use detonation::optim::OptSpec;
use detonation::replicate::ReplSpec;
use detonation::runtime::Runtime;
use detonation::train::Trainer;

// PjRtClient is not Sync, so each test thread builds its own CPU client
// (cheap for the CPU plugin).
fn runtime() -> Runtime {
    Runtime::cpu().expect("pjrt cpu client")
}

fn have_artifacts() -> bool {
    // The artifact suite's learning-curve thresholds are calibrated for
    // the real PJRT-executed models: only run it when the xla backend is
    // actually compiled in (the surrogate backend has its own suite in
    // `engine_invariants` below).
    cfg!(feature = "xla") && std::path::Path::new("artifacts/lm-tiny.meta.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn cfg(model: &str) -> ExperimentConfig {
    ExperimentConfig {
        model: model.into(),
        nodes: 2,
        accels_per_node: 2,
        steps: 25,
        lr: 2e-3,
        seed: 77,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// end-to-end training across families and schemes
// ---------------------------------------------------------------------------

#[test]
fn lm_trains_and_loss_decreases() {
    require_artifacts!();
    let mut t = Trainer::new(&runtime(), cfg("lm-tiny")).unwrap();
    let m = t.run().unwrap();
    let first = m.steps.first().unwrap().loss;
    let last = m.tail_loss(5).unwrap();
    assert!(last < first - 0.1, "no learning: {first} -> {last}");
}

#[test]
fn seq2seq_trains() {
    require_artifacts!();
    let mut t = Trainer::new(&runtime(), cfg("seq2seq-tiny")).unwrap();
    let m = t.run().unwrap();
    assert!(m.tail_loss(5).unwrap() < m.steps[0].loss, "seq2seq no learning");
}

#[test]
fn vit_trains() {
    require_artifacts!();
    let mut c = cfg("vit-tiny");
    c.lr = 5e-4;
    let mut t = Trainer::new(&runtime(), c).unwrap();
    let m = t.run().unwrap();
    assert!(m.tail_loss(5).unwrap() < m.steps[0].loss + 0.05, "vit diverged");
}

#[test]
fn every_replicator_trains_without_error() {
    require_artifacts!();
    for repl in ["demo:1/8", "random:1/8", "striding:1/8", "diloco:4", "full"] {
        let mut c = cfg("lm-tiny");
        c.steps = 10;
        c.repl = ReplSpec::parse(repl).unwrap();
        let mut t = Trainer::new(&runtime(), c).unwrap();
        let m = t.run().unwrap();
        assert!(m.steps.iter().all(|r| r.loss.is_finite()), "{repl}");
    }
}

#[test]
fn every_optimizer_trains_without_error() {
    require_artifacts!();
    for opt in ["demo-sgd", "decoupled-adamw", "adamw", "sgd"] {
        let mut c = cfg("lm-tiny");
        c.steps = 10;
        c.opt = OptSpec::parse(opt).unwrap();
        if opt == "adamw" {
            c.repl = ReplSpec::parse("full").unwrap();
        }
        let mut t = Trainer::new(&runtime(), c).unwrap();
        let m = t.run().unwrap();
        assert!(m.steps.iter().all(|r| r.loss.is_finite()), "{opt}");
    }
}

// ---------------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn replicas_stay_in_sync_for_every_step_schemes() {
    // FlexDeMo applies the *averaged* decoded update on every node, so
    // parameter replicas must stay bit-identical across nodes.
    require_artifacts!();
    for repl in ["demo:1/8", "random:1/8", "striding:1/8", "full"] {
        let mut c = cfg("lm-tiny");
        c.steps = 8;
        c.repl = ReplSpec::parse(repl).unwrap();
        let mut t = Trainer::new(&runtime(), c).unwrap();
        for _ in 0..8 {
            t.step().unwrap();
        }
        assert_eq!(t.replica_drift(), 0.0, "{repl} drifted");
    }
}

#[test]
fn diloco_drifts_between_syncs_and_resyncs() {
    require_artifacts!();
    let mut c = cfg("lm-tiny");
    c.repl = ReplSpec::parse("diloco:4").unwrap();
    let mut t = Trainer::new(&runtime(), c).unwrap();
    // steps 0..2 are local-only: replicas must drift (distinct data).
    for _ in 0..3 {
        t.step().unwrap();
    }
    assert!(t.replica_drift() > 0.0, "diloco should drift between syncs");
    // step 3 is the sync step: drift collapses (exact for unsigned f32;
    // sign is on by default → approximately).
    t.step().unwrap();
    let drift = t.replica_drift();
    assert!(drift < 1e-5, "diloco failed to resync: {drift}");
}

#[test]
fn deterministic_given_seed() {
    require_artifacts!();
    let run = || {
        let mut c = cfg("lm-tiny");
        c.steps = 6;
        let mut t = Trainer::new(&runtime(), c).unwrap();
        t.run().unwrap().steps.iter().map(|r| r.loss).collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn different_seeds_differ() {
    require_artifacts!();
    let run = |seed| {
        let mut c = cfg("lm-tiny");
        c.steps = 4;
        c.seed = seed;
        let mut t = Trainer::new(&runtime(), c).unwrap();
        t.run().unwrap().final_loss().unwrap()
    };
    assert_ne!(run(1), run(2));
}

#[test]
fn degenerate_meshes_run() {
    // |R| = 1 → pure FSDP; |S| = 1 → DeMo-DDP; 1×1 → single accelerator.
    require_artifacts!();
    for (nodes, accels) in [(1usize, 4usize), (4, 1), (1, 1)] {
        let mut c = cfg("lm-tiny");
        c.nodes = nodes;
        c.accels_per_node = accels;
        c.steps = 5;
        let mut t = Trainer::new(&runtime(), c).unwrap();
        let m = t.run().unwrap();
        assert!(
            m.steps.iter().all(|r| r.loss.is_finite()),
            "{nodes}x{accels}"
        );
    }
}

#[test]
fn pure_fsdp_has_zero_inter_node_traffic() {
    require_artifacts!();
    let mut c = cfg("lm-tiny");
    c.nodes = 1;
    c.accels_per_node = 4;
    c.steps = 5;
    let mut t = Trainer::new(&runtime(), c).unwrap();
    let m = t.run().unwrap();
    assert_eq!(m.total_inter_bytes(), 0);
}

// ---------------------------------------------------------------------------
// bandwidth claims (paper arithmetic)
// ---------------------------------------------------------------------------

fn inter_bytes(repl: &str, steps: u64) -> u64 {
    let mut c = cfg("lm-tiny");
    c.steps = steps;
    c.repl = ReplSpec::parse(repl).unwrap();
    let mut t = Trainer::new(&runtime(), c).unwrap();
    t.run().unwrap().total_inter_bytes()
}

#[test]
fn demo_ships_twice_random_bytes_at_equal_rate() {
    // u32 index + f32 value vs f32 value only (paper §Replication Schemes).
    require_artifacts!();
    let demo = inter_bytes("demo:1/8:nosign", 4);
    let random = inter_bytes("random:1/8:nosign", 4);
    let ratio = demo as f64 / random as f64;
    assert!((ratio - 2.0).abs() < 0.1, "demo/random byte ratio {ratio}");
}

#[test]
fn compression_rate_scales_bytes() {
    require_artifacts!();
    let r8 = inter_bytes("random:1/8", 4);
    let r32 = inter_bytes("random:1/32", 4);
    let ratio = r8 as f64 / r32 as f64;
    assert!((ratio - 4.0).abs() < 0.3, "1/8 vs 1/32 ratio {ratio}");
}

#[test]
fn full_sync_dwarfs_compressed() {
    require_artifacts!();
    let full = inter_bytes("full", 4);
    let demo = inter_bytes("demo:1/8", 4);
    assert!(full > 3 * demo, "full {full} vs demo {demo}");
}

#[test]
fn packed_extension_shrinks_wire() {
    require_artifacts!();
    let plain = inter_bytes("random:1/8:sign", 4);
    let packed = inter_bytes("random:1/8:sign:packed", 4);
    let ratio = plain as f64 / packed as f64;
    assert!(ratio > 10.0, "packing gave only {ratio}x");
}

#[test]
fn diloco_amortizes_bandwidth() {
    require_artifacts!();
    // Over 8 steps, diloco:4 syncs twice with full payload ≈ 2/8 of the
    // per-step full scheme (sign dtype equal).
    let diloco = inter_bytes("diloco:4:nosign", 8);
    let full = inter_bytes("full", 8);
    let ratio = full as f64 / diloco as f64;
    assert!(
        // ring all-reduce (full) moves ~2x payload vs naive at g=2.
        (2.0..8.01).contains(&ratio),
        "full/diloco ratio {ratio}"
    );
}

// ---------------------------------------------------------------------------
// simulated-time claims
// ---------------------------------------------------------------------------

#[test]
fn throttled_bandwidth_slows_full_more_than_compressed() {
    require_artifacts!();
    let time_of = |repl: &str| {
        let mut c = cfg("lm-tiny");
        c.steps = 4;
        c.repl = ReplSpec::parse(repl).unwrap();
        c.net = detonation::net::NetModel::paper_scaled(135_488, 1.2e9).with_inter_mbps(10.0);
        let mut t = Trainer::new(&runtime(), c).unwrap();
        t.run().unwrap().mean_step_time()
    };
    let full = time_of("full");
    let demo = time_of("demo:1/32");
    let random = time_of("random:1/32");
    assert!(full > demo && demo > random, "{full} {demo} {random}");
}

#[test]
fn demo_gather_does_not_scale_with_nodes_but_ring_does() {
    require_artifacts!();
    let time_at = |nodes: usize, repl: &str| {
        let mut c = cfg("lm-tiny");
        c.nodes = nodes;
        c.accels_per_node = 2;
        c.steps = 2;
        c.compute_streams = 4;
        c.repl = ReplSpec::parse(repl).unwrap();
        c.net = detonation::net::NetModel::paper_scaled(135_488, 1.2e9);
        let mut t = Trainer::new(&runtime(), c).unwrap();
        t.run().unwrap().mean_step_time()
    };
    // DeMo naive gather grows ~linearly in node count (visible once the
    // gather term dominates compute — the paper sees it at 64 nodes too)...
    let demo_growth = time_at(64, "demo:1/32") / time_at(4, "demo:1/32");
    // ...while the ring full-sync stays near-flat.
    let ring_growth = time_at(64, "full") / time_at(4, "full");
    assert!(
        demo_growth > 3.0 * ring_growth,
        "demo growth {demo_growth} vs ring {ring_growth}"
    );
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn missing_artifact_fails_cleanly() {
    let mut c = cfg("no-such-model");
    c.steps = 1;
    let err = Trainer::new(&runtime(), c).err().expect("should fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts") || msg.contains("no-such-model"), "{msg}");
}

#[test]
fn malformed_manifest_fails_cleanly() {
    require_artifacts!();
    let dir = std::env::temp_dir().join("detonation-bad-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.meta.json"), "{\"name\": 42}").unwrap();
    let rt = runtime();
    let err = rt.load_model(&dir, "bad").err().expect("should fail");
    assert!(!format!("{err:#}").is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_batch_shape_rejected() {
    require_artifacts!();
    let rt = runtime();
    let model = rt
        .load_model(std::path::Path::new("artifacts"), "lm-tiny")
        .unwrap();
    let params = model.manifest.init_flat(0);
    // wrong length tokens
    let bad = vec![
        detonation::runtime::BatchData::I32(vec![0; 7]),
        detonation::runtime::BatchData::I32(vec![0; 512]),
    ];
    assert!(model.train_step(&params, &bad).is_err());
    // wrong dtype
    let bad = vec![
        detonation::runtime::BatchData::F32(vec![0.0; 512]),
        detonation::runtime::BatchData::I32(vec![0; 512]),
    ];
    assert!(model.train_step(&params, &bad).is_err());
}

// ---------------------------------------------------------------------------
// event-engine invariants (surrogate runtime; no artifacts needed)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
mod engine_invariants {
    use super::*;
    use detonation::metrics::RunMetrics;
    use detonation::net::{ClusterModel, NetModel};

    /// 2×2 mesh on a 100 Mbps inter-node link (the paper's Fig 10 regime)
    /// with the in-process synthetic LM.
    fn synth_cfg(repl: &str) -> ExperimentConfig {
        ExperimentConfig {
            model: "synthetic-lm".into(),
            nodes: 2,
            accels_per_node: 2,
            steps: 6,
            lr: 0.05,
            seed: 99,
            repl: ReplSpec::parse(repl).unwrap(),
            net: NetModel::throttled(100.0),
            ..Default::default()
        }
    }

    /// Run to completion; returns (trainer, metrics).
    fn run(cfg: ExperimentConfig) -> (Trainer, RunMetrics) {
        let mut t = Trainer::new(&runtime(), cfg).unwrap();
        let m = t.run().unwrap();
        (t, m)
    }

    #[test]
    fn no_overlap_bit_matches_the_serialized_clock() {
        // --no-overlap must reproduce the legacy SimClock totals exactly:
        // the engine's horizon and its serialized accumulator (the sum of
        // phase maxima in legacy order) are the same float chain.
        for repl in ["full", "demo:1/8", "diloco:4"] {
            let mut cfg = synth_cfg(repl);
            cfg.overlap = false;
            let (t, m) = run(cfg);
            assert_eq!(
                t.engine.now(),
                t.engine.serialized_time(),
                "{repl}: serialized engine diverged from barrier clock"
            );
            assert_eq!(m.total_sim_time(), t.engine.now(), "{repl}");
        }
    }

    #[test]
    fn overlapped_step_time_never_exceeds_serialized() {
        for repl in ["full", "demo:1/8", "random:1/8", "diloco:4"] {
            let (t_ovl, m_ovl) = run(synth_cfg(repl));
            let mut cfg = synth_cfg(repl);
            cfg.overlap = false;
            let (_, m_ser) = run(cfg);
            // within one run, the engine's own serialized bound holds...
            assert!(
                t_ovl.engine.now() <= t_ovl.engine.serialized_time() * (1.0 + 1e-12),
                "{repl}: overlap exceeded its serialized bound"
            );
            // ...and it matches an actual --no-overlap run of the same cfg
            assert!(
                m_ovl.total_sim_time() <= m_ser.total_sim_time() * (1.0 + 1e-12),
                "{repl}: overlap slower than serialized"
            );
            // scheduling must never change numerics
            let l_ovl: Vec<f64> = m_ovl.steps.iter().map(|r| r.loss).collect();
            let l_ser: Vec<f64> = m_ser.steps.iter().map(|r| r.loss).collect();
            assert_eq!(l_ovl, l_ser, "{repl}: overlap changed the numerics");
        }
    }

    #[test]
    fn per_rank_timelines_are_monotone() {
        let mut t = Trainer::new(&runtime(), synth_cfg("demo:1/8")).unwrap();
        let world = t.cfg.world_size();
        let mut prev = vec![0.0f64; world];
        for _ in 0..8 {
            t.step().unwrap();
            let (compute, fabric, nic) = t.engine.timelines();
            for r in 0..world {
                let now = compute.now(r).max(fabric.now(r)).max(nic.now(r));
                assert!(now >= prev[r], "rank {r} timeline went backwards");
                prev[r] = now;
            }
        }
    }

    /// The PR's acceptance criterion: on a ≤100 Mbps inter-node link,
    /// overlap makes DeMo/FlexDeMo strictly faster per step, while the
    /// Full all-reduce baseline stays communication-bound — the paper's
    /// "FlexDeMo is substantially faster" ordering.
    #[test]
    fn flexdemo_overlap_is_strictly_faster_and_full_stays_comm_bound() {
        let time_of = |repl: &str, overlap: bool| {
            let mut cfg = synth_cfg(repl);
            cfg.overlap = overlap;
            run(cfg)
        };
        for repl in ["demo:1/8", "demo:1/32"] {
            let (_, m_ovl) = time_of(repl, true);
            let (_, m_ser) = time_of(repl, false);
            assert!(
                m_ovl.mean_step_time() < m_ser.mean_step_time(),
                "{repl}: overlap not strictly faster: {} vs {}",
                m_ovl.mean_step_time(),
                m_ser.mean_step_time()
            );
            assert!(m_ovl.total_hidden_comm() > 0.0, "{repl}: nothing hidden");
        }
        // Full replication: the ring all-reduce dwarfs compute at
        // 100 Mbps, so even overlapped it remains comm-bound...
        let (_, m_full) = time_of("full", true);
        assert!(
            m_full.total_exposed_comm() > 0.5 * m_full.total_sim_time(),
            "full should be comm-bound: exposed {} of {}",
            m_full.total_exposed_comm(),
            m_full.total_sim_time()
        );
        // ...and FlexDeMo is substantially faster than Full per step.
        let (_, m_demo) = time_of("demo:1/8", true);
        assert!(
            m_full.mean_step_time() > 3.0 * m_demo.mean_step_time(),
            "paper ordering violated: full {} vs demo {}",
            m_full.mean_step_time(),
            m_demo.mean_step_time()
        );
    }

    /// Satellite + acceptance: with `--bucket-mb` the engine splits
    /// reduce-scatter/gather into per-bucket events. Numerics must be
    /// bit-identical to whole-phase scheduling, `--no-overlap` totals
    /// must reproduce exactly, and on a comm-exposed config the bucketed
    /// schedule's `exposed_comm` must not exceed the whole-phase one.
    #[test]
    fn bucketed_schedule_matches_numerics_and_shrinks_exposed_comm() {
        let mk = |bucket_mb: f64, overlap: bool| {
            let mut cfg = synth_cfg("demo:1/8");
            // A compute-rich regime (backward window ≫ per-bucket α) so
            // the gather tail is the exposed term bucketing attacks.
            cfg.net.device_flops = 5e10;
            cfg.steps = 8;
            cfg.bucket_mb = bucket_mb;
            cfg.overlap = overlap;
            run(cfg)
        };
        let (_, whole) = mk(0.0, true);
        let (t_bucketed, bucketed) = mk(0.01, true);
        // bucketing reschedules traffic, it never touches data
        let lw: Vec<f64> = whole.steps.iter().map(|r| r.loss).collect();
        let lb: Vec<f64> = bucketed.steps.iter().map(|r| r.loss).collect();
        assert_eq!(lw, lb, "bucketing changed the numerics");
        // acceptance: bucketed exposure never exceeds whole-phase …
        assert!(
            bucketed.total_exposed_comm() <= whole.total_exposed_comm() * (1.0 + 1e-9),
            "bucketed exposed {} > whole-phase {}",
            bucketed.total_exposed_comm(),
            whole.total_exposed_comm()
        );
        // … and on this config it strictly helps: the first gather
        // bucket crosses the link during the backward window.
        assert!(
            bucketed.total_sim_time() < whole.total_sim_time(),
            "bucketing did not shorten the run: {} vs {}",
            bucketed.total_sim_time(),
            whole.total_sim_time()
        );
        assert!(
            bucketed.steps[1].comm_events > whole.steps[1].comm_events,
            "no per-bucket events emitted"
        );
        // the overlapped horizon still respects its serialized bound
        assert!(
            t_bucketed.engine.now() <= t_bucketed.engine.serialized_time() * (1.0 + 1e-12),
            "bucketed overlap exceeded serialized bound"
        );
        // --no-overlap ignores bucketing: serialized totals reproduce
        let (_, ser_whole) = mk(0.0, false);
        let (_, ser_bucket) = mk(0.01, false);
        assert_eq!(ser_whole.total_sim_time(), ser_bucket.total_sim_time());
        assert_eq!(
            ser_whole.total_exposed_comm(),
            ser_bucket.total_exposed_comm()
        );
        let lsw: Vec<f64> = ser_whole.steps.iter().map(|r| r.loss).collect();
        let lsb: Vec<f64> = ser_bucket.steps.iter().map(|r| r.loss).collect();
        assert_eq!(lsw, lsb);
    }

    #[test]
    fn prop_bucketed_numerics_identical_across_schedules() {
        // Proptest satellite: any bucket size on any small mesh leaves
        // the loss trajectory bit-identical to whole-phase scheduling
        // and reproduces --no-overlap serialized totals.
        detonation::util::proptest::proptest(8, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "random:1/8", "full", "diloco:2"]);
            let bucket_mb = *g.choose(&[0.001, 0.005, 0.02, 0.1]);
            let mk = |bucket: f64, overlap: bool| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 3;
                cfg.bucket_mb = bucket;
                cfg.overlap = overlap;
                run(cfg).1
            };
            let whole = mk(0.0, true);
            let bucketed = mk(bucket_mb, true);
            let lw: Vec<f64> = whole.steps.iter().map(|r| r.loss).collect();
            let lb: Vec<f64> = bucketed.steps.iter().map(|r| r.loss).collect();
            detonation::util::proptest::prop_assert(
                lw == lb,
                format!("{nodes}x{accels} {repl} @{bucket_mb}MiB: numerics diverged"),
            );
            let ser_whole = mk(0.0, false);
            let ser_bucket = mk(bucket_mb, false);
            detonation::util::proptest::prop_assert(
                ser_whole.total_sim_time() == ser_bucket.total_sim_time(),
                format!("{nodes}x{accels} {repl}: serialized totals diverged"),
            );
        });
    }

    /// Tentpole acceptance: `--staleness 0` routes DiLoCo through the
    /// async replicator and the deferred-finalize plumbing with S = 0,
    /// and must reproduce the synchronous scheme bit-for-bit — losses,
    /// validation, sim-time, and final parameters — across meshes,
    /// periods, and worker-pool widths.
    #[test]
    fn prop_staleness_zero_bit_identical_to_sync_diloco() {
        detonation::util::proptest::proptest(8, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let period = g.usize(2, 5) as u64;
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |staleness: Option<&str>| {
                let mut cfg = synth_cfg(&format!("diloco:{period}"));
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 2 * period + 1;
                cfg.threads = threads;
                cfg.val_every = period;
                cfg.val_batches = 2;
                if let Some(s) = staleness {
                    cfg.apply_arg("staleness", s).unwrap();
                }
                let (t, m) = run(cfg);
                let loss_bits: Vec<u64> = m.steps.iter().map(|r| r.loss.to_bits()).collect();
                let val_bits: Vec<u64> = m.val.iter().map(|r| r.loss.to_bits()).collect();
                let time_bits = m.total_sim_time().to_bits();
                let param_bits: Vec<u32> =
                    t.params_node0().iter().map(|p| p.to_bits()).collect();
                (loss_bits, val_bits, time_bits, param_bits)
            };
            let sync = fingerprint(None);
            let async0 = fingerprint(Some("0"));
            detonation::util::proptest::prop_assert(
                sync == async0,
                format!("{nodes}x{accels} diloco:{period} t{threads}: staleness 0 changed bits"),
            );
        });
    }

    /// Tentpole acceptance: on a comm-exposed link, letting local steps
    /// run under the in-flight sync makes async DiLoCo strictly faster
    /// per simulated step than synchronous DiLoCo for every S ≥ 1, and
    /// the new metrics columns surface the knob and the in-flight
    /// window.
    #[test]
    fn async_diloco_strictly_faster_per_step_on_comm_exposed_link() {
        let mk = |staleness: u64| {
            let mut cfg = synth_cfg("diloco:4");
            cfg.steps = 12;
            if staleness > 0 {
                cfg.apply_arg("staleness", &staleness.to_string()).unwrap();
            }
            run(cfg)
        };
        let (_, sync) = mk(0);
        assert!(sync.steps.iter().all(|r| r.sync_in_flight == 0));
        for s in [1u64, 2, 3] {
            let (t, asy) = mk(s);
            assert!(asy.steps.iter().all(|r| r.loss.is_finite()), "S={s} diverged");
            assert!(
                asy.mean_step_time() < sync.mean_step_time(),
                "S={s} not faster per step: {} vs {}",
                asy.mean_step_time(),
                sync.mean_step_time()
            );
            // the engine still respects its serialized upper bound
            assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
            // metrics: the knob is echoed, and each launch keeps both
            // shards' syncs in flight for S steps (2 shards on the 2x2
            // mesh; the last launch at step 11 is cut off by the end of
            // the run after one step).
            assert!(asy.steps.iter().all(|r| r.staleness == s));
            let in_flight: u64 = asy.steps.iter().map(|r| r.sync_in_flight).sum();
            assert_eq!(in_flight, 2 * (2 * s + 1), "S={s}: in-flight step count");
        }
    }

    /// Satellite engine invariant: under `--no-overlap` the deferred
    /// lane changes nothing about time — async DiLoCo reproduces the
    /// synchronous scheme's barrier totals bit-for-bit (staleness is a
    /// pure numerics knob there), and the engine still matches its
    /// serialized accumulator exactly.
    #[test]
    fn no_overlap_totals_unchanged_by_async_diloco() {
        let mk = |staleness: Option<&str>| {
            let mut cfg = synth_cfg("diloco:4");
            cfg.steps = 10;
            cfg.overlap = false;
            if let Some(s) = staleness {
                cfg.apply_arg("staleness", s).unwrap();
            }
            run(cfg)
        };
        let (ts, sync) = mk(None);
        let (ta, asy) = mk(Some("2"));
        assert_eq!(sync.total_sim_time(), asy.total_sim_time());
        assert_eq!(sync.total_exposed_comm(), asy.total_exposed_comm());
        assert_eq!(ta.engine.now(), ta.engine.serialized_time());
        assert_eq!(ts.engine.now(), ta.engine.now());
        // the trajectories themselves differ — the averaged delta lands
        // two steps late
        let ls: Vec<f64> = sync.steps.iter().map(|r| r.loss).collect();
        let la: Vec<f64> = asy.steps.iter().map(|r| r.loss).collect();
        assert_ne!(ls, la);
    }

    /// Satellite acceptance: `--late-policy wait` with a *uniform*
    /// staleness table — whether it arrives as the global `--staleness S`
    /// or as an all-equal `--node-staleness` table — must route through
    /// the PR 4 whole-group window and reproduce it bit-for-bit (losses,
    /// validation, sim time, final parameters), across meshes, periods,
    /// and `--threads {1, 2, 4}`.
    #[test]
    fn prop_late_policy_wait_uniform_bit_identical_to_global_staleness() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(2, 3);
            let accels = g.usize(1, 2);
            let period = g.usize(2, 5) as u64;
            let staleness = g.usize(1, period as usize - 1) as u64;
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |via_table: bool| {
                let mut cfg = synth_cfg(&format!("diloco:{period}"));
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 2 * period + 1;
                cfg.threads = threads;
                cfg.val_every = period;
                cfg.val_batches = 2;
                if via_table {
                    let table: Vec<String> =
                        (0..nodes).map(|n| format!("{n}:{staleness}")).collect();
                    cfg.apply_arg("node-staleness", &table.join(",")).unwrap();
                    cfg.apply_arg("late-policy", "wait").unwrap();
                } else {
                    cfg.apply_arg("staleness", &staleness.to_string()).unwrap();
                }
                let (t, m) = run(cfg);
                let loss_bits: Vec<u64> = m.steps.iter().map(|r| r.loss.to_bits()).collect();
                let val_bits: Vec<u64> = m.val.iter().map(|r| r.loss.to_bits()).collect();
                let time_bits = m.total_sim_time().to_bits();
                let param_bits: Vec<u32> =
                    t.params_node0().iter().map(|p| p.to_bits()).collect();
                (loss_bits, val_bits, time_bits, param_bits)
            };
            detonation::util::proptest::prop_assert(
                fingerprint(false) == fingerprint(true),
                format!(
                    "{nodes}x{accels} diloco:{period} S={staleness} t{threads}: \
                     uniform node table + wait diverged from the global path"
                ),
            );
        });
    }

    /// Tentpole acceptance: under a 4× compute straggler on a
    /// comm-exposed link, `drop` and `partial` finish strictly faster
    /// than `wait` (nobody stalls on an admitted contribution by
    /// construction, while `wait` blocks every arrival on the
    /// straggler's launch + full send queue), and the per-node
    /// `dropped_syncs` column records the late contributions.
    #[test]
    fn drop_and_partial_beat_wait_under_compute_straggler() {
        let mk = |policy: &str| {
            let mut cfg = synth_cfg("diloco:4");
            cfg.steps = 16;
            cfg.cluster = ClusterModel {
                slowdown: ClusterModel::parse_slowdown("1:4.0").unwrap(),
                node_inter_bw: vec![],
            };
            cfg.apply_arg("staleness", "2").unwrap();
            cfg.apply_arg("late-policy", policy).unwrap();
            run(cfg)
        };
        let (_, wait) = mk("wait");
        let (_, drop) = mk("drop");
        let (_, partial) = mk("partial");
        assert!(
            drop.total_sim_time() < wait.total_sim_time(),
            "drop not faster: {} vs wait {}",
            drop.total_sim_time(),
            wait.total_sim_time()
        );
        assert!(
            partial.total_sim_time() < wait.total_sim_time(),
            "partial not faster: {} vs wait {}",
            partial.total_sim_time(),
            wait.total_sim_time()
        );
        // losses stay finite under both tolerant policies
        assert!(drop.steps.iter().all(|r| r.loss.is_finite()));
        assert!(partial.steps.iter().all(|r| r.loss.is_finite()));
        // the wait window never drops; the tolerant ones record the
        // straggler's late contributions per node
        assert_eq!(wait.total_dropped_syncs(), 0);
        assert!(drop.total_dropped_syncs() > 0, "drop recorded no late peers");
        assert!(partial.total_dropped_syncs() > 0);
        // the resolved table is surfaced in the steps CSV columns
        assert!(drop.steps.iter().all(|r| r.node_staleness == "2;2"));
        assert!(drop.steps.iter().all(|r| r.staleness == 2));
    }

    /// `--staleness auto` resolves a per-node table from the cluster
    /// profile: a NIC-throttled node gets more slack than a nominal one,
    /// the run stays finite, and the table lands in the CSV column.
    #[test]
    fn auto_staleness_derives_per_node_windows() {
        let mut cfg = synth_cfg("diloco:8");
        cfg.steps = 18;
        cfg.cluster = ClusterModel {
            slowdown: ClusterModel::parse_slowdown("1:2.0").unwrap(),
            node_inter_bw: vec![],
        };
        cfg.apply_arg("staleness", "auto").unwrap();
        cfg.apply_arg("late-policy", "drop").unwrap();
        let (t, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        let table = &m.steps[0].node_staleness;
        let parts: Vec<u64> = table.split(';').map(|s| s.parse().unwrap()).collect();
        assert_eq!(parts.len(), 2, "one entry per node: {table:?}");
        assert!(parts.iter().all(|&s| (1..8).contains(&s)), "{table:?}");
        // the compute straggler's long steps absorb the transfer in
        // fewer of them
        assert!(parts[1] <= parts[0], "{table:?}");
        // the engine still respects its serialized upper bound
        assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
    }

    /// Explicit per-node overrides go through end to end, including a
    /// node pinned back to S = 0 (aggregate at launch from whatever has
    /// landed — its own delta at minimum).
    #[test]
    fn node_staleness_overrides_run_end_to_end() {
        let mut cfg = synth_cfg("diloco:4");
        // launches at steps 3/7/11; node 1's last arrival is step 13
        cfg.steps = 14;
        cfg.apply_arg("node-staleness", "0:0,1:2").unwrap();
        cfg.apply_arg("late-policy", "partial").unwrap();
        let (_, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        assert!(m.steps.iter().all(|r| r.node_staleness == "0;2"));
        assert!(m.steps.iter().all(|r| r.staleness == 2));
        // windows fully retire: nothing left in flight at the end of a
        // non-launch step run tail
        assert_eq!(m.steps.last().unwrap().sync_in_flight, 0);
    }

    #[test]
    fn straggler_node_dominates_critical_path() {
        let mut cfg = synth_cfg("demo:1/8");
        // make compute dominant so the straggler is the long pole
        cfg.net.device_flops = 1e9;
        cfg.cluster = ClusterModel {
            slowdown: ClusterModel::parse_slowdown("1:3.0").unwrap(),
            node_inter_bw: vec![],
        };
        let (t_strag, m_strag) = run(cfg);
        let crit = t_strag.engine.critical_rank();
        assert_eq!(
            crit / t_strag.cfg.accels_per_node,
            1,
            "critical rank {crit} not on the straggler node"
        );

        let mut uni = synth_cfg("demo:1/8");
        uni.net.device_flops = 1e9;
        let (_, m_uni) = run(uni);
        // a 3× straggler on compute-dominant steps costs ≈3×; demand >2×
        // to keep the assertion robust yet strict.
        assert!(
            m_strag.total_sim_time() > 2.0 * m_uni.total_sim_time(),
            "straggler did not dominate: {} vs {}",
            m_strag.total_sim_time(),
            m_uni.total_sim_time()
        );
    }

    #[test]
    fn heterogeneous_nic_slows_replication() {
        let mut cfg = synth_cfg("full");
        cfg.cluster.node_inter_bw = ClusterModel::parse_node_mbps("0:10").unwrap();
        let (_, m_het) = run(cfg);
        let (_, m_uni) = run(synth_cfg("full"));
        assert!(
            m_het.total_sim_time() > m_uni.total_sim_time() * 2.0,
            "10 Mbps NIC on node 0 should throttle the gather: {} vs {}",
            m_het.total_sim_time(),
            m_uni.total_sim_time()
        );
    }

    #[test]
    fn worker_threads_do_not_change_numerics() {
        let losses = |threads: usize| {
            let mut cfg = synth_cfg("demo:1/8");
            cfg.threads = threads;
            run(cfg).1.steps.iter().map(|r| r.loss).collect::<Vec<_>>()
        };
        let serial = losses(1);
        assert_eq!(serial, losses(4));
        assert_eq!(serial, losses(0)); // one pool slot per hardware thread
    }

    /// Tentpole acceptance: the persistent pool's chunk-parallel kernels
    /// — now running on the unrolled `parallel::lanes` primitives —
    /// (stream fan-out, collectives, optimizer sweeps, DCT batches,
    /// eval) keep every bit identical for any `--threads N`, across
    /// meshes, replication schemes, and optimizers — training losses,
    /// per-step simulated time, validation losses, and final parameters
    /// alike.
    #[test]
    fn prop_thread_count_bit_identical_across_meshes_and_schemes() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "random:1/8", "striding:1/8", "diloco:2", "full"]);
            let opt = *g.choose(&["demo-sgd", "decoupled-adamw", "adamw", "sgd"]);
            let fingerprint = |threads: usize| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 3;
                cfg.threads = threads;
                cfg.val_every = 2;
                cfg.val_batches = 2;
                cfg.opt = OptSpec::parse(opt).unwrap();
                if opt == "adamw" {
                    cfg.repl = ReplSpec::parse("full").unwrap();
                }
                let (t, m) = run(cfg);
                let loss_bits: Vec<u64> = m.steps.iter().map(|r| r.loss.to_bits()).collect();
                let time_bits: Vec<u64> =
                    m.steps.iter().map(|r| r.sim_time.to_bits()).collect();
                let val_bits: Vec<u64> = m.val.iter().map(|r| r.loss.to_bits()).collect();
                let param_bits: Vec<u32> =
                    t.params_node0().iter().map(|p| p.to_bits()).collect();
                (loss_bits, time_bits, val_bits, param_bits)
            };
            let serial = fingerprint(1);
            for threads in [2usize, 4, 8] {
                let parallel = fingerprint(threads);
                detonation::util::proptest::prop_assert(
                    serial == parallel,
                    format!("{nodes}x{accels} {repl}/{opt}: --threads {threads} changed bits"),
                );
            }
        });
    }

    /// Satellite: `--trace-out` dumps the engine's scheduled comm events
    /// as Chrome-trace JSON (per-rank lanes, ts/dur in sim-µs).
    #[test]
    fn trace_out_writes_chrome_trace_json() {
        let path = std::env::temp_dir().join("detonation-trace-test.json");
        let _ = std::fs::remove_file(&path);
        let mut cfg = synth_cfg("demo:1/8");
        cfg.steps = 3;
        cfg.trace_out = Some(path.clone());
        let _ = run(cfg);
        let text = std::fs::read_to_string(&path).expect("trace file written");
        let doc = detonation::util::json::parse(&text).expect("valid JSON");
        let evs = doc
            .get("traceEvents")
            .and_then(|j| j.as_arr())
            .expect("traceEvents array");
        assert!(!evs.is_empty(), "trace has no events");
        let names: Vec<&str> = evs
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"reduce-scatter"), "{names:?}");
        assert!(names.contains(&"naive-gather"), "{names:?}");
        // per-rank lanes: a 2x2 mesh uses tids 0..4
        let tids: std::collections::BTreeSet<u64> = evs
            .iter()
            .filter_map(|e| e.get("tid").and_then(|t| t.as_u64()))
            .collect();
        assert_eq!(tids.into_iter().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // every lane row names its node (2×2 mesh: node = tid / 2), so
        // in-flight gathers are attributable in the timeline view
        for e in evs {
            if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
                let tid = e.get("tid").and_then(|t| t.as_u64()).unwrap();
                let node = e.get("args").and_then(|a| a.get("node")).and_then(|n| n.as_u64());
                assert_eq!(node, Some(tid / 2));
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replicas_stay_in_sync_on_surrogate() {
        for repl in ["demo:1/8", "random:1/8", "full"] {
            let mut t = Trainer::new(&runtime(), synth_cfg(repl)).unwrap();
            for _ in 0..4 {
                t.step().unwrap();
            }
            assert_eq!(t.replica_drift(), 0.0, "{repl} drifted");
        }
    }

    /// Bit-level fingerprint of a finished run: per-step losses, sim
    /// times, validation losses, and node-0 parameters.
    fn run_fingerprint(t: &Trainer, m: &RunMetrics) -> (Vec<u64>, Vec<u64>, Vec<u64>, Vec<u32>) {
        (
            m.steps.iter().map(|r| r.loss.to_bits()).collect(),
            m.steps.iter().map(|r| r.sim_time.to_bits()).collect(),
            m.val.iter().map(|r| r.loss.to_bits()).collect(),
            t.params_node0().iter().map(|p| p.to_bits()).collect(),
        )
    }

    /// Tentpole pin: an **empty** membership timeline — even with
    /// `--checkpoint-dir` publishing a checkpoint every step — is
    /// bit-identical to the pre-elastic fixed-group trainer at every
    /// worker-pool width, across meshes and schemes. The elastic
    /// machinery must be pure control flow when unused.
    #[test]
    fn prop_empty_timeline_and_checkpoint_dir_bit_inert() {
        let ckpt_root = std::env::temp_dir().join("detonation-ckpt-inert");
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "full", "diloco:2", "diloco:3:async=1"]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |ckpt: Option<std::path::PathBuf>| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.val_every = 2;
                cfg.val_batches = 2;
                cfg.checkpoint_dir = ckpt;
                let (t, m) = run(cfg);
                assert!(m.steps.iter().all(|r| r.membership.is_empty()));
                run_fingerprint(&t, &m)
            };
            let dir = ckpt_root.join(format!("{nodes}x{accels}-t{threads}"));
            let plain = fingerprint(None);
            let with_ckpt = fingerprint(Some(dir.clone()));
            detonation::util::proptest::prop_assert(
                plain == with_ckpt,
                format!("{nodes}x{accels} {repl} t{threads}: checkpoint-dir changed bits"),
            );
            // the checkpoint actually got published
            detonation::util::proptest::prop_assert(
                dir.join("latest.ckpt").exists(),
                format!("{}: latest.ckpt missing", dir.display()),
            );
        });
        std::fs::remove_dir_all(&ckpt_root).ok();
    }

    /// Tentpole acceptance: save → restore → continue is bit-identical
    /// to the uninterrupted run — losses, simulated clock, and final
    /// parameters — across schemes (including async DiLoCo snapshotted
    /// with windows in flight), meshes, and thread counts.
    #[test]
    fn prop_checkpoint_restore_continues_bit_identically() {
        let ckpt_root = std::env::temp_dir().join("detonation-ckpt-resume");
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&[
                "demo:1/8",
                "full",
                "diloco:2",
                "diloco:3:async=2",
                "striding:1/8",
            ]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let steps = 6u64;
            let cut = g.usize(1, steps as usize - 1) as u64;
            let mk_cfg = || {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = steps;
                cfg.threads = threads;
                cfg
            };
            // Uninterrupted reference.
            let mut a = Trainer::new(&runtime(), mk_cfg()).unwrap();
            let mut loss_a = Vec::new();
            for _ in 0..steps {
                loss_a.push(a.step().unwrap().to_bits());
            }
            // Interrupted: run to `cut`, checkpoint (possibly with async
            // windows in flight), restore into a FRESH trainer, continue.
            let dir = ckpt_root.join(format!("{nodes}x{accels}-t{threads}-c{cut}"));
            let mut b = Trainer::new(&runtime(), mk_cfg()).unwrap();
            let mut loss_b = Vec::new();
            for _ in 0..cut {
                loss_b.push(b.step().unwrap().to_bits());
            }
            let path = b.save_checkpoint(&dir).unwrap();
            drop(b);
            let mut c = Trainer::new(&runtime(), mk_cfg()).unwrap();
            c.restore_checkpoint(&path).unwrap();
            detonation::util::proptest::prop_assert(
                c.current_step() == cut,
                format!("restored step {} != {cut}", c.current_step()),
            );
            for _ in cut..steps {
                loss_b.push(c.step().unwrap().to_bits());
            }
            let tag = format!("{nodes}x{accels} {repl} t{threads} cut@{cut}");
            detonation::util::proptest::prop_assert(
                loss_a == loss_b,
                format!("{tag}: losses diverged after restore"),
            );
            detonation::util::proptest::prop_assert(
                a.sim_now().to_bits() == c.sim_now().to_bits(),
                format!("{tag}: simulated clock diverged after restore"),
            );
            let pa: Vec<u32> = a.params_node0().iter().map(|p| p.to_bits()).collect();
            let pc: Vec<u32> = c.params_node0().iter().map(|p| p.to_bits()).collect();
            detonation::util::proptest::prop_assert(
                pa == pc,
                format!("{tag}: parameters diverged after restore"),
            );
            std::fs::remove_dir_all(&dir).ok();
        });
        std::fs::remove_dir_all(&ckpt_root).ok();
    }

    /// A checkpoint refuses to restore onto a different experiment.
    #[test]
    fn checkpoint_rejects_mismatched_experiment() {
        let dir = std::env::temp_dir().join("detonation-ckpt-mismatch");
        let mut t = Trainer::new(&runtime(), synth_cfg("diloco:2")).unwrap();
        t.step().unwrap();
        let path = t.save_checkpoint(&dir).unwrap();
        let mut other_cfg = synth_cfg("diloco:2");
        other_cfg.seed += 1;
        let mut other = Trainer::new(&runtime(), other_cfg).unwrap();
        let err = other.restore_checkpoint(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("different experiment"),
            "unexpected error: {err:#}"
        );
        // truncated file errors instead of panicking
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let mut same = Trainer::new(&runtime(), synth_cfg("diloco:2")).unwrap();
        assert!(same.restore_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite: the v2 checkpoint's trailing CRC-32 catches silent
    /// bit-rot — a single flipped byte anywhere in the body is rejected
    /// with an actionable error, and restoring the intact file still
    /// works afterwards.
    #[test]
    fn checkpoint_crc_rejects_single_flipped_byte() {
        let dir = std::env::temp_dir().join("detonation-ckpt-bitflip");
        let mut t = Trainer::new(&runtime(), synth_cfg("diloco:2")).unwrap();
        t.step().unwrap();
        let path = t.save_checkpoint(&dir).unwrap();
        let good = std::fs::read(&path).unwrap();
        // flip one bit in the middle of the body
        let mut bad = good.clone();
        let ix = good.len() / 2;
        bad[ix] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        let mut same = Trainer::new(&runtime(), synth_cfg("diloco:2")).unwrap();
        let err = same.restore_checkpoint(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("CRC-32 mismatch") && msg.contains("corrupt"),
            "unexpected error: {msg}"
        );
        // the intact bytes still restore
        std::fs::write(&path, &good).unwrap();
        same.restore_checkpoint(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Tentpole behavior: a leave/join timeline re-forms the replication
    /// groups each window — inter-node traffic collapses while the node
    /// is away, the join broadcast brings it back in sync from node 0,
    /// and the steps CSV carries the membership mask.
    #[test]
    fn churn_timeline_reforms_groups_and_rejoins() {
        let mut cfg = synth_cfg("demo:1/8");
        cfg.steps = 6;
        cfg.apply_arg("churn", "leave:1@2,join:1@4").unwrap();
        let (t, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        let masks: Vec<&str> = m.steps.iter().map(|r| r.membership.as_str()).collect();
        assert_eq!(masks, ["11", "11", "10", "10", "11", "11"]);
        // away: the every-step gather loses its only inter-node peer
        assert!(
            m.steps[3].inter_bytes < m.steps[1].inter_bytes,
            "departed node still drove inter-node traffic: {} vs {}",
            m.steps[3].inter_bytes,
            m.steps[1].inter_bytes
        );
        // rejoin: the step-4 join broadcast ships the full parameter
        // buffer from node 0 on top of resumed gather traffic
        assert!(
            m.steps[4].inter_bytes > m.steps[3].inter_bytes,
            "join broadcast missing from the traffic: {} vs {}",
            m.steps[4].inter_bytes,
            m.steps[3].inter_bytes
        );
        assert_eq!(t.active_nodes(), &[true, true]);
        // an event mid-run leaves the engine's serialized bound intact
        assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
    }

    /// Crash without a checkpoint dir: the node rejoins with fresh
    /// optimizer/replicator state and the run completes; with a
    /// checkpoint dir, the crash stashes the last published checkpoint
    /// and the rejoin restores from it.
    #[test]
    fn crash_and_checkpointed_rejoin_complete() {
        // fresh-state rejoin
        let mut cfg = synth_cfg("diloco:2");
        cfg.steps = 8;
        cfg.apply_arg("crash", "1@3:5").unwrap();
        let (_, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        let masks: Vec<&str> = m.steps.iter().map(|r| r.membership.as_str()).collect();
        assert_eq!(masks, ["11", "11", "11", "10", "10", "11", "11", "11"]);

        // checkpointed rejoin
        let dir = std::env::temp_dir().join("detonation-crash-rejoin");
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = synth_cfg("diloco:2");
        cfg.steps = 8;
        cfg.apply_arg("crash", "1@3:5").unwrap();
        cfg.checkpoint_dir = Some(dir.clone());
        let (_, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        assert!(
            dir.join("crash-node1.ckpt").exists(),
            "crash did not stash a checkpoint"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Elastic validation surfaces actionable errors at trainer build.
    #[test]
    fn elastic_misconfigurations_rejected_at_build() {
        // quorum larger than the replication group
        let mut cfg = synth_cfg("diloco:2");
        cfg.quorum = 3; // 2 nodes
        assert!(Trainer::new(&runtime(), cfg).is_err());
        // churn on the anchor node
        let mut cfg = synth_cfg("demo:1/8");
        cfg.apply_arg("churn", "leave:0@2").unwrap();
        let err = Trainer::new(&runtime(), cfg).unwrap_err();
        assert!(format!("{err:#}").contains("node 0"), "{err:#}");
        // state-machine violations (join while up)
        let mut cfg = synth_cfg("demo:1/8");
        cfg.apply_arg("churn", "join:1@2").unwrap();
        assert!(Trainer::new(&runtime(), cfg).is_err());
    }

    /// Satellite: `--quorum` caps how long an arrival waits. With K
    /// equal to the group size every contribution is awaited — on a
    /// non-uniform staleness table that is bit-identical to the `wait`
    /// policy's whole-peer admission (same set, same gate). With K = 1
    /// the member never waits on a late peer, so the simulated clock can
    /// only improve.
    #[test]
    fn quorum_full_matches_wait_and_quorum_one_never_slower() {
        let mk = |quorum: usize| {
            let mut cfg = synth_cfg("diloco:3");
            cfg.steps = 10;
            cfg.apply_arg("staleness", "1").unwrap();
            cfg.apply_arg("node-staleness", "1:2").unwrap(); // non-uniform
            cfg.apply_arg("straggler", "1:4").unwrap();
            cfg.quorum = quorum;
            let (t, m) = run(cfg);
            let fp = run_fingerprint(&t, &m);
            (fp, m)
        };
        let (fp_wait, m_wait) = mk(0);
        let (fp_full, m_full) = mk(2); // K = group size
        assert_eq!(fp_wait, fp_full, "quorum=|R| diverged from wait");
        let (_, m_one) = mk(1);
        assert!(m_one.steps.iter().all(|r| r.loss.is_finite()));
        assert!(
            m_one.total_sim_time() <= m_wait.total_sim_time() * (1.0 + 1e-12),
            "quorum=1 slower than wait: {} vs {}",
            m_one.total_sim_time(),
            m_wait.total_sim_time()
        );
        let _ = m_full;
    }

    /// Tentpole pin: an **empty** `--link-fault` timeline — even with
    /// every retry knob moved off its default — is bit-identical to the
    /// pre-fault trainer across meshes, schemes, and worker-pool
    /// widths. The self-healing machinery must be pure control flow
    /// when no fault can ever fire.
    #[test]
    fn prop_empty_link_fault_bit_inert() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "full", "diloco:2", "diloco:3:async=1"]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |tweak: bool| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.val_every = 2;
                cfg.val_batches = 2;
                if tweak {
                    cfg.apply_arg("link-fault", "").unwrap(); // explicit empty spec
                    cfg.apply_arg("max-retries", "7").unwrap();
                    cfg.apply_arg("retry-timeout", "0.9").unwrap();
                    cfg.apply_arg("retry-backoff", "0.4").unwrap();
                }
                let (t, m) = run(cfg);
                assert!(m.steps.iter().all(|r| {
                    r.retries == 0 && r.corrupt_detected == 0 && r.faulted_links == 0
                }));
                run_fingerprint(&t, &m)
            };
            detonation::util::proptest::prop_assert(
                fingerprint(false) == fingerprint(true),
                format!("{nodes}x{accels} {repl} t{threads}: empty link-fault changed bits"),
            );
        });
    }

    /// Tentpole acceptance: a faulted run is a pure function of the
    /// config — fixed seed and fixed `--link-fault` spec reproduce the
    /// run bit-for-bit (fault decisions are hashes, not RNG draws).
    #[test]
    fn prop_faulted_runs_bit_reproducible() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(2, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "diloco:2", "full"]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let spec = *g.choose(&[
                "drop:*-*@p0.3",
                "corrupt:*-*@p0.4",
                "drop:1-*@p0.5,degrade:*-1@0.5x",
                "flap:1-0@1..3",
            ]);
            let fingerprint = || {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.apply_arg("link-fault", spec).unwrap();
                let (t, m) = run(cfg);
                assert!(m.steps.iter().all(|r| r.loss.is_finite()));
                run_fingerprint(&t, &m)
            };
            detonation::util::proptest::prop_assert(
                fingerprint() == fingerprint(),
                format!("{nodes}x{accels} {repl} t{threads} {spec}: faulted run not reproducible"),
            );
        });
    }

    /// Lossy links surface in the new metrics columns and the Chrome
    /// trace: drops drive `retries` > 0, corruption is caught by the
    /// payload checksum (`corrupt_detected` > 0), `faulted_links`
    /// counts the spec's active directed links, and retry attempts are
    /// labelled `retry-gather` in `--trace-out`.
    #[test]
    fn link_faults_surface_in_metrics_and_trace() {
        let trace = std::env::temp_dir().join("detonation-fault-trace.json");
        let _ = std::fs::remove_file(&trace);
        let mut cfg = synth_cfg("diloco:2");
        cfg.steps = 8;
        cfg.trace_out = Some(trace.clone());
        cfg.apply_arg("link-fault", "drop:*-*@p0.4,corrupt:*-*@p0.4")
            .unwrap();
        let (t, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        assert!(m.total_retries() > 0, "40% loss never retried");
        assert!(
            m.total_corrupt_detected() > 0,
            "40% corruption never detected at decode"
        );
        // 2 nodes, both directions wildcarded
        assert!(m.steps.iter().all(|r| r.faulted_links == 2));
        assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
        let text = std::fs::read_to_string(&trace).expect("trace written");
        let doc = detonation::util::json::parse(&text).expect("valid JSON");
        let names: Vec<&str> = doc
            .get("traceEvents")
            .and_then(|j| j.as_arr())
            .expect("traceEvents array")
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"retry-gather"), "{names:?}");
        std::fs::remove_file(&trace).ok();
    }

    /// Acceptance: a link that is down for the whole run (a persistent
    /// partition) exhausts `--max-retries` and falls back through the
    /// existing machinery — the run completes with finite losses under
    /// the default wait policy, under `--late-policy drop`, and under a
    /// `--quorum` that the unreachable node can no longer satisfy.
    /// Nothing deadlocks on a transfer that will never arrive.
    #[test]
    fn full_partition_falls_back_without_deadlock() {
        let mk = |tune: &dyn Fn(&mut ExperimentConfig)| {
            let mut cfg = synth_cfg("diloco:2");
            cfg.steps = 8;
            cfg.apply_arg("link-fault", "flap:1-*@0..99").unwrap();
            tune(&mut cfg);
            let (t, m) = run(cfg);
            assert!(m.steps.iter().all(|r| r.loss.is_finite()));
            assert_eq!(m.steps.len(), 8);
            assert!(m.total_sim_time().is_finite());
            assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
            m
        };
        let wait = mk(&|_| {});
        assert!(wait.total_retries() > 0);
        let drop = mk(&|cfg| cfg.apply_arg("late-policy", "drop").unwrap());
        assert!(
            drop.total_dropped_syncs() > 0,
            "partitioned sender never recorded as dropped"
        );
        let _quorum = mk(&|cfg| cfg.quorum = 2);
    }

    /// Satellite: `--quorum` × `--churn`. A quorum sized for the full
    /// group is re-evaluated against the *re-formed* group after a
    /// leave: K larger than the shrunken group clamps (with a warning)
    /// instead of deadlocking, and the run completes.
    #[test]
    fn quorum_clamps_to_shrunken_churn_group() {
        let mut cfg = synth_cfg("diloco:2");
        cfg.steps = 8;
        cfg.quorum = 2; // == full group, valid at build
        cfg.apply_arg("churn", "leave:1@2").unwrap();
        let (t, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        let masks: Vec<&str> = m.steps.iter().map(|r| r.membership.as_str()).collect();
        assert_eq!(masks, ["11", "11", "10", "10", "10", "10", "10", "10"]);
        assert_eq!(m.steps.len(), 8, "quorum > group size deadlocked the run");
        assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
    }

    // -----------------------------------------------------------------
    // sync topology (gossip / partial connectivity)
    // -----------------------------------------------------------------

    /// Tentpole pin: `--topology full` is the bit-frozen pre-topology
    /// path. An explicit `full` must reproduce the default config
    /// exactly — losses, sim times, validation, final parameters —
    /// across schemes (hitting all three dispatch paths: synchronous,
    /// whole-group window, per-member window), meshes, and
    /// `--threads {1, 2, 4}`.
    #[test]
    fn prop_topology_full_bit_identical_to_default() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "full", "diloco:2", "diloco:3:async=1"]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |explicit: bool| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.val_every = 2;
                cfg.val_batches = 2;
                if explicit {
                    cfg.apply_arg("topology", "full").unwrap();
                }
                let (t, m) = run(cfg);
                // full never populates the peer-set column
                assert!(m.steps.iter().all(|r| r.peer_set.is_empty()));
                run_fingerprint(&t, &m)
            };
            detonation::util::proptest::prop_assert(
                fingerprint(false) == fingerprint(true),
                format!("{nodes}x{accels} {repl} t{threads}: explicit --topology full changed bits"),
            );
        });
    }

    /// Tentpole pin: `--compress-control off` — explicit or absent — is
    /// bit-identical across schemes, meshes, and thread counts. The
    /// controller's off path must be pure control flow: no sel hints on
    /// the wire, no dispatch change, no retunes, empty `rate` column.
    #[test]
    fn prop_compress_control_off_bit_identical_to_absent() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&[
                "demo:1/8",
                "random:1/8",
                "striding:1/8",
                "diloco:2",
                "full",
            ]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let fingerprint = |explicit: bool| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.val_every = 2;
                cfg.val_batches = 2;
                if explicit {
                    cfg.apply_arg("compress-control", "off").unwrap();
                    cfg.apply_arg("control-window", "4").unwrap();
                }
                let (t, m) = run(cfg);
                // off never populates the rate column
                assert!(m.steps.iter().all(|r| r.rate.is_empty()));
                run_fingerprint(&t, &m)
            };
            detonation::util::proptest::prop_assert(
                fingerprint(false) == fingerprint(true),
                format!(
                    "{nodes}x{accels} {repl} t{threads}: explicit --compress-control off changed bits"
                ),
            );
        });
    }

    /// Tentpole end-to-end: under AIMD control on a 4-node mesh whose
    /// node 0 has a 100x slower NIC, the controller backs node 0's rate
    /// off below the spec's 1/8 while the idle fast peers rise above it
    /// (water-filling), the steps CSV `rate` column tracks the table,
    /// and a rerun reproduces the run bit-for-bit.
    #[test]
    fn aimd_controller_backs_off_congested_node_end_to_end() {
        let mk = || {
            let mut cfg = synth_cfg("random:1/8");
            cfg.nodes = 4;
            cfg.accels_per_node = 1;
            cfg.steps = 32;
            cfg.apply_arg("node-mbps", "0:1").unwrap();
            cfg.apply_arg("compress-control", "aimd").unwrap();
            cfg.apply_arg("control-window", "4").unwrap();
            cfg.apply_arg("rate-min", "1/64").unwrap();
            cfg.apply_arg("rate-max", "1/4").unwrap();
            run(cfg)
        };
        let (t, m) = mk();
        let last = m.steps.last().unwrap();
        let rates: Vec<f64> = last
            .rate
            .split(';')
            .map(|s| s.parse().unwrap())
            .collect();
        assert_eq!(rates.len(), 4, "rate column: {:?}", last.rate);
        assert!(
            rates[0] < 0.125 && rates.iter().skip(1).all(|&r| r > 0.125),
            "congested node 0 should settle below the spec rate and idle \
             peers above it: {:?}",
            last.rate
        );
        let (t2, m2) = mk();
        assert_eq!(
            run_fingerprint(&t, &m),
            run_fingerprint(&t2, &m2),
            "controller-on run is not deterministic"
        );
    }

    /// Tentpole acceptance: a random-pair run is a pure function of
    /// the config — the per-window matching is a hash of
    /// (seed, step, shard), not an RNG draw — so a fixed seed
    /// reproduces the run bit-for-bit across reruns and
    /// `--threads {1, 2, 4}`, and every launch step's peer-set column
    /// records a perfect matching (everyone paired on even groups, one
    /// self-paired member on odd ones).
    #[test]
    fn prop_random_pair_bit_reproducible_across_reruns_and_threads() {
        detonation::util::proptest::proptest(6, |g| {
            let nodes = g.usize(2, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["demo:1/8", "diloco:2", "diloco:3:async=1"]);
            let fingerprint = |threads: usize| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 5;
                cfg.threads = threads;
                cfg.apply_arg("topology", "random-pair").unwrap();
                let (t, m) = run(cfg);
                assert!(m.steps.iter().all(|r| r.loss.is_finite()));
                let mut launches = 0;
                for r in &m.steps {
                    if r.peer_set.is_empty() {
                        continue;
                    }
                    launches += 1;
                    let sizes: Vec<usize> =
                        r.peer_set.split(';').map(|s| s.parse().unwrap()).collect();
                    assert_eq!(sizes.len(), nodes, "step {}: {:?}", r.step, r.peer_set);
                    assert!(sizes.iter().all(|&s| s <= 1), "{:?}", r.peer_set);
                    assert_eq!(
                        sizes.iter().sum::<usize>(),
                        2 * (nodes / 2),
                        "step {}: not a perfect matching: {:?}",
                        r.step,
                        r.peer_set
                    );
                }
                assert!(launches > 0, "no per-member window ever launched");
                run_fingerprint(&t, &m)
            };
            let serial = fingerprint(1);
            detonation::util::proptest::prop_assert(
                serial == fingerprint(1),
                format!("{nodes}x{accels} {repl}: random-pair rerun changed bits"),
            );
            for threads in [2usize, 4] {
                detonation::util::proptest::prop_assert(
                    serial == fingerprint(threads),
                    format!("{nodes}x{accels} {repl}: --threads {threads} changed bits"),
                );
            }
        });
    }

    /// Satellite: `--topology ring` composes with `--churn`. A 4-node
    /// ring loses a member mid-run: the window re-forms around the
    /// departed node, ring peer sets are recomputed over the re-formed
    /// group (3 members → both neighbors = everyone else), and the run
    /// completes with finite losses and the engine bound intact.
    #[test]
    fn ring_topology_composes_with_churn() {
        let mut cfg = synth_cfg("diloco:2");
        cfg.nodes = 4;
        cfg.accels_per_node = 1;
        cfg.steps = 8;
        cfg.apply_arg("topology", "ring").unwrap();
        cfg.apply_arg("churn", "leave:2@3,join:2@6").unwrap();
        let (t, m) = run(cfg);
        assert!(m.steps.iter().all(|r| r.loss.is_finite()));
        assert_eq!(m.steps.len(), 8, "churned ring did not complete");
        let masks: Vec<&str> = m.steps.iter().map(|r| r.membership.as_str()).collect();
        assert_eq!(
            masks,
            ["1111", "1111", "1111", "1101", "1101", "1101", "1111", "1111"]
        );
        // launch steps carry the peer-set sizes: 2 neighbors each on
        // the full ring, and still 2 each on the re-formed 3-group
        for r in &m.steps {
            if !r.peer_set.is_empty() {
                let sizes: Vec<usize> =
                    r.peer_set.split(';').map(|s| s.parse().unwrap()).collect();
                assert!(
                    sizes == vec![2; 4] || sizes == vec![2; 3],
                    "step {}: unexpected ring peer sets {:?}",
                    r.step,
                    r.peer_set
                );
            }
        }
        assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
    }

    /// Satellite pin: a gossip window's averaging denominator is the
    /// contributing set, not the group size — `mean_decoded_refs` over
    /// a ring member's {self, 2 neighbors} divides by 3, and over a
    /// churn-shrunken {self, 1 peer} set by 2, bit-for-bit the float
    /// chain of averaging a group of that size.
    #[test]
    fn gossip_window_mean_divides_by_the_peer_set() {
        use detonation::compress::Scratch;
        use detonation::replicate::{mean_decoded_refs, DiLoCoReplicator, ReplCtx, Replicator};
        use detonation::tensor::Dtype;
        let len = 5;
        let ctx = ReplCtx {
            step: 0,
            shard: 0,
            seed: 3,
        };
        let mut scratch = Scratch::new();
        let mut payloads = Vec::new();
        for delta in [1.0f32, 3.0, 8.0, 100.0] {
            let mut r = DiLoCoReplicator::new(1, false, Dtype::F32, len);
            let mut buf = vec![delta; len];
            let (_, p) = r.extract(&ctx, &mut buf, &mut scratch);
            payloads.push(p.expect("period-1 diloco emits every step"));
        }
        let decoder = DiLoCoReplicator::new(1, false, Dtype::F32, len);
        let [pa, pb, pc, pd] = &payloads[..] else {
            unreachable!()
        };
        // ring member: itself plus its two neighbors → /3, the
        // 100-delta outsider never enters the mean
        let ring = mean_decoded_refs(&decoder, &ctx, &[pa, pb, pc], len, &mut scratch);
        assert!(
            ring.iter().all(|&x| (x - (1.0 + 3.0 + 8.0) / 3.0).abs() < 1e-5),
            "{ring:?}"
        );
        scratch.put_f32(ring);
        // churn-shrunken pair → /2
        let pair = mean_decoded_refs(&decoder, &ctx, &[pa, pb], len, &mut scratch);
        assert_eq!(pair, vec![(1.0f32 + 3.0) * 0.5; len]);
        scratch.put_f32(pair);
        let _ = pd;
    }

    /// Satellite: `--topology random-pair` × a persistent partition.
    /// The matching keeps drawing the dead link (2 nodes pair with
    /// each other every window); retries exhaust and the sender falls
    /// back through each `--late-policy` without deadlock.
    #[test]
    fn random_pair_full_partition_completes_under_every_late_policy() {
        for policy in ["wait", "drop", "partial"] {
            let mut cfg = synth_cfg("diloco:2");
            cfg.steps = 8;
            cfg.apply_arg("topology", "random-pair").unwrap();
            cfg.apply_arg("link-fault", "flap:1-*@0..99").unwrap();
            cfg.apply_arg("late-policy", policy).unwrap();
            let (t, m) = run(cfg);
            assert!(m.steps.iter().all(|r| r.loss.is_finite()), "{policy}");
            assert_eq!(m.steps.len(), 8, "{policy}: partitioned gossip deadlocked");
            assert!(m.total_sim_time().is_finite(), "{policy}");
            assert!(
                m.total_retries() > 0,
                "{policy}: the paired transfer never hit the dead link"
            );
            assert!(t.engine.now() <= t.engine.serialized_time() * (1.0 + 1e-12));
        }
    }

    /// Gossip ships O(degree), not O(group): at 8 nodes a ring window
    /// moves strictly fewer inter-node bytes than the full-group
    /// window with identical payloads, and the sparse exchange can
    /// only shorten the simulated clock.
    #[test]
    fn ring_ships_fewer_bytes_than_full_at_eight_nodes() {
        let mk = |topo: &str| {
            let mut cfg = synth_cfg("diloco:2");
            cfg.nodes = 8;
            cfg.accels_per_node = 1;
            cfg.steps = 6;
            cfg.apply_arg("topology", topo).unwrap();
            run(cfg).1
        };
        let full = mk("full");
        let ring = mk("ring");
        assert!(
            ring.total_inter_bytes() < full.total_inter_bytes(),
            "ring {} >= full {}",
            ring.total_inter_bytes(),
            full.total_inter_bytes()
        );
        assert!(
            ring.total_sim_time() <= full.total_sim_time() * (1.0 + 1e-12),
            "sparse exchange slower than full: {} vs {}",
            ring.total_sim_time(),
            full.total_sim_time()
        );
    }

    #[test]
    fn prop_overlap_bounded_across_random_meshes() {
        detonation::util::proptest::proptest(10, |g| {
            let nodes = g.usize(1, 3);
            let accels = g.usize(1, 2);
            let repl = *g.choose(&["full", "demo:1/8", "diloco:2"]);
            let mbps = g.f64(10.0, 1000.0);
            let mk = |overlap: bool| {
                let mut cfg = synth_cfg(repl);
                cfg.nodes = nodes;
                cfg.accels_per_node = accels;
                cfg.steps = 2;
                cfg.net = NetModel::throttled(mbps);
                cfg.overlap = overlap;
                run(cfg).1.total_sim_time()
            };
            let (ovl, ser) = (mk(true), mk(false));
            detonation::util::proptest::prop_assert(
                ovl <= ser * (1.0 + 1e-12),
                format!("{nodes}x{accels} {repl} @{mbps:.0}Mbps: {ovl} > {ser}"),
            );
        });
    }
}

// ---------------------------------------------------------------------------
// L1↔L3 cross-validation (Rust DCT vs Pallas artifact)
// ---------------------------------------------------------------------------

#[test]
fn rust_extraction_matches_pallas_artifact() {
    require_artifacts!();
    let path = std::path::Path::new("artifacts/dct_extract_16384_c64_k8_sign.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: extraction artifact missing");
        return;
    }
    let rt = runtime();
    let art = rt.load_hlo(path).unwrap();
    let mut rng = detonation::util::rng::Rng::new(1234);
    let m: Vec<f32> = (0..16384).map(|_| rng.normal_f32(1.0)).collect();
    let outs = art.execute_vec(&m).unwrap();

    use detonation::replicate::{DemoReplicator, ReplCtx, Replicator};
    let mut buf = m.clone();
    let mut repl = DemoReplicator::new(64, 8, true, detonation::tensor::Dtype::F32);
    let (q, _) = repl.extract(
        &ReplCtx {
            step: 0,
            shard: 0,
            seed: 0,
        },
        &mut buf,
        &mut detonation::compress::Scratch::new(),
    );
    for (a, b) in outs[0].iter().zip(&q) {
        assert!((a - b).abs() < 2e-3, "q mismatch {a} vs {b}");
    }
    for (a, b) in outs[1].iter().zip(&buf) {
        assert!((a - b).abs() < 2e-3, "residual mismatch {a} vs {b}");
    }
}
