//! The FlexDeMo training loop — Algorithm 1 of the paper, end to end.
//!
//! Per step, over the hybrid mesh (S = intra-node sharding groups,
//! R = inter-node replication groups):
//!
//! 1. every rank runs fwd+bwd on its own microbatch (deduplicated by
//!    gradient stream and fanned out onto the persistent
//!    [`crate::parallel::WorkerPool`] — full parameters, full gradient,
//!    `p.grad` in the paper's framing);
//! 2. `GradReduceScatter(θ_t, S)`: ring reduce-scatter averages gradients
//!    intra-node; each rank keeps its shard;
//! 3. the optimizer folds the gradient shard into the decoupled buffer
//!    (`m ← βm + Δ`);
//! 4. the replicator extracts the fast components `q` (buffer keeps the
//!    residual) and, on sync steps, the compressed payloads cross R via
//!    the naive blocking all-gather (ring all-reduce for the Full
//!    baseline); decoded payloads are averaged;
//! 5. `θ ← θ − η·Q` on the shard; intra-node all-gather unshards the
//!    updated parameters for the next forward pass.
//!
//! **Numerics vs time are decoupled.** The data movement above always
//! executes in program order, bit-identically whatever the schedule; the
//! *clock* is the discrete-event [`engine::StepEngine`], which either
//! serializes the phases (`--no-overlap`, legacy `SimClock` parity) or
//! overlaps phase 0/2 intra-node traffic with backward compute and the
//! replication gather with the next step's forward. With `--bucket-mb`
//! set the reduce-scatter and gather further split into per-bucket
//! events so the first bucket's communication overlaps the remaining
//! buckets' compression. See `engine` for the dependency model.
//!
//! **Async DiLoCo (`--staleness S`).** With a non-zero staleness window
//! (the trainer resolves one per node and builds each rank's
//! [`crate::replicate::AsyncDiLoCoReplicator`] with its node's value,
//! which [`crate::replicate::Replicator::sync_delay`] echoes back), the
//! periodic sync is *deferred*: the launch step ships the payloads and
//! charges the NIC on the engine's deferred lane
//! ([`engine::StepEngine::gather_deferred`]), the step loop parks the
//! gathered payloads in [`Trainer`]'s per-shard pending slot and keeps
//! taking local steps, and S steps later the decoded mean is handed to
//! `finalize` while [`engine::StepEngine::sync_arrival`] lets the
//! completion gate the *next* backward. Data still moves in program
//! order — staleness is a numerics knob (how late the averaged delta
//! lands), and `S = 0` is bit-identical to the synchronous scheme
//! (prop-tested).
//!
//! **Straggler-tolerant async DiLoCo (`--staleness auto`,
//! `--node-staleness`, `--late-policy`).** On heterogeneous clusters one
//! global S lets the slowest node gate every window, so the staleness
//! table is resolved *per node*
//! ([`crate::config::ExperimentConfig::resolve_node_staleness`]) and the
//! window switches to per-member machinery: the launch charges one NIC
//! event per member
//! ([`engine::StepEngine::gather_deferred_per_member`] — each member's
//! send starts at its own reduce-scatter completion), the parked
//! `PendingSync` carries per-member arrival steps and contribution
//! completion times, and each member aggregates at its own arrival with
//! the contributions that met its deadline. Peer deltas that missed it
//! follow `--late-policy`: `wait` admits them anyway and lets the
//! slowest transfer gate the next backward (with a *uniform* table this
//! routes through the PR 4 whole-group window, kept bit-frozen), `drop`
//! discards them with the averaging denominator corrected to the
//! contributing set (NoLoCo-style gossip), and `partial` folds each —
//! once its transfer has landed — into one of that node's later window
//! means. This is the one place where *numerics follow the
//! simulated schedule* — which contributions a node aggregates depends
//! on simulated arrival times (deterministic, and still independent of
//! `--threads`), because tolerating stragglers is inherently a
//! scheduling decision. Group members may therefore average different
//! quorums; DiLoCo's periodic windows keep the divergence bounded
//! exactly as they bound replica drift between syncs.
//!
//! Edge cases degrade exactly as the paper states: |R|=1 → pure FSDP,
//! |S|=1 → DeMo-style DDP, |S|=|R|=1 → single-accelerator training.
//!
//! Everything is deterministic: data streams, init, and the Random/
//! Striding index sets all derive from `config.seed` — and the worker
//! pool only parallelizes *independent* work over fixed, thread-count-
//! independent chunk boundaries (stream computations, grid chunks of
//! the collectives/optimizer/eval kernels, DCT block batches), so
//! `--threads N` never changes a single bit of the result (prop-tested
//! across meshes and schemes in `tests/integration.rs`).

pub mod engine;
mod checkpoint;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::collectives::{self, CollCtx, CollScratch, CommEvent};
use crate::compress::{Payload, Scratch, WireStats};
use crate::config::ExperimentConfig;
use crate::data::{task_for, Task};
use crate::metrics::{RunMetrics, StepRow, ValRow};
use crate::net::{
    membership_label, MembershipEvent, MembershipTimeline, SimTime, Topology, TrafficMatrix,
};
use crate::optim::Optimizer;
use crate::parallel::{PoolHandle, SlicePtr, WorkerPool};
use crate::replicate::{
    mean_decoded, mean_decoded_refs, ControlSpec, LatePolicy, RateController, ReplBuildCtx,
    ReplCtx, Replicator, ReplSpec,
};
use crate::runtime::{ModelRuntime, Runtime};
use crate::shard::{FlatLayout, HybridMesh};

use engine::{FaultLane, MemberFault, StepEngine, StepTiming};

/// Per-rank state (optimizer + replicator own shard-sized buffers, plus
/// the per-worker compression scratch arena reused across steps — the
/// steady-state extract path allocates nothing).
struct RankState {
    opt: Box<dyn Optimizer>,
    repl: Box<dyn Replicator>,
    scratch: Scratch,
    /// Peer deltas that missed this rank's arrival deadline under
    /// `--late-policy partial`, carried (with their wire completion
    /// times) into a later window's mean: a carried delta is only
    /// admitted once its transfer has actually landed, and its
    /// completion still gates the backward that follows the aggregation.
    /// Empty outside the straggler-tolerant path.
    carried: Vec<(Payload, SimTime)>,
}

/// A deferred (async DiLoCo) sync parked between its launch step and its
/// arrival: the gathered payloads of one R-group, decoded and finalized
/// after the gather was charged on the wire.
enum PendingSync {
    /// The PR 4 uniform-staleness window (`--late-policy wait` with one
    /// global S): a single arrival step for the whole group, gated by
    /// the whole-group gather event. Kept bit-frozen.
    Uniform {
        /// Step at which the averaged delta is applied.
        arrival: u64,
        /// One payload per R-group member (group order).
        payloads: Vec<Payload>,
    },
    /// A straggler-tolerant window (per-node staleness and/or a
    /// non-`wait` late policy): every member aggregates at its own
    /// arrival step from the contributions that met its own deadline.
    PerNode {
        /// The ranks that launched this window, in launch order. Under
        /// churn the *current* replication group can differ from this
        /// one by the time the window arrives, so each arriving member
        /// maps itself into the window by rank, not by position.
        group: Vec<usize>,
        /// One payload per window member (group order); kept until
        /// every member has applied, then recycled.
        payloads: Vec<Payload>,
        /// Per-member contribution completion times on the wire
        /// (engine's per-member async-gather lanes).
        contrib_end: Vec<SimTime>,
        /// Per-member arrival step (`launch + S_node`).
        arrival: Vec<u64>,
        /// Which members have aggregated already.
        applied: Vec<bool>,
        /// Per-member admissible peer positions within the window
        /// ([`crate::replicate::SyncTopology::peer_sets`] at launch): a
        /// member aggregates only itself plus these. Under `--topology
        /// full` every other position is listed, reproducing the
        /// whole-group mean.
        peers: Vec<Vec<usize>>,
    },
}

/// The assembled training system.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub model: ModelRuntime,
    pub layout: FlatLayout,
    pub mesh: HybridMesh,
    task: Box<dyn Task>,
    /// Per-node padded flat parameter buffer (nodes may diverge under
    /// DiLoCo between syncs; otherwise they stay bit-identical — tested).
    params: Vec<Vec<f32>>,
    /// Per-rank gradient buffers (padded).
    grads: Vec<Vec<f32>>,
    ranks: Vec<RankState>,
    /// The persistent data-plane worker pool (`--threads` slots): stream
    /// fan-out and every chunk-parallel kernel dispatch here. Built once
    /// — no per-step thread spawns.
    pool: Arc<WorkerPool>,
    /// Collectives' staging arena (zero-alloc steady state).
    coll_scratch: CollScratch,
    /// Deferred syncs in flight, one slot per shard (async DiLoCo):
    /// payloads parked between the launch step and `arrival`.
    pending: Vec<Option<PendingSync>>,
    /// Resolved per-node staleness table (node → S); uniform unless
    /// `--staleness auto` / `--node-staleness` differentiated it.
    node_delay: Vec<u64>,
    /// `;`-joined `node_delay` for the steps CSV (empty when the async
    /// machinery is unarmed).
    node_staleness_label: String,
    /// Closed-loop AIMD rate controller (`--compress-control aimd`):
    /// per `--control-window`, each node's compression rate is retuned
    /// from that node's NIC-occupancy tap ([`engine::StepEngine::nic_busy`])
    /// and the window's exposed-comm ratio. `None` = `off`, the
    /// bit-frozen fixed-rate path (prop-tested identical to no flag).
    controller: Option<RateController>,
    /// `;`-joined per-node rates for the steps CSV `rate` column (empty
    /// while the controller is off — fixed-rate runs keep it blank).
    rate_label: String,
    /// Per-node late-contribution counts this step (`dropped_syncs`).
    dropped_step: Vec<u64>,
    /// `;`-joined per-member peer-set sizes of the last sync window
    /// launched this step (the `peer_set` CSV column; empty under
    /// `--topology full` or on steps without a launch).
    peer_set_step: String,
    /// The discrete-event clock (per-rank compute + NIC timelines).
    pub engine: StepEngine,
    pub traffic: TrafficMatrix,
    /// Timing summary of the most recent step.
    pub last_timing: StepTiming,
    /// Cumulative inter/intra byte counters at the last step boundary.
    last_inter: u64,
    last_intra: u64,
    step: u64,
    /// Deterministic churn timeline (cloned from the config); empty for
    /// a fixed group, in which case every elastic branch below is dead
    /// and the step is bit-identical to the pre-churn trainer (pinned).
    membership: MembershipTimeline,
    /// Per-node liveness mask (all `true` without churn).
    active: Vec<bool>,
    /// Nodes currently down *because of a crash*: unlike a graceful
    /// leave, the node's in-memory state is lost, and a later join
    /// restores its private state from the stashed checkpoint.
    crashed: Vec<bool>,
    /// Per-node checkpoint stashed at crash time (`--checkpoint-dir`).
    crash_ckpt: Vec<Option<PathBuf>>,
    /// Corrupt transfers detected (checksum-verified) this step — the
    /// `corrupt_detected` CSV column.
    corrupt_detected_step: u64,
    /// Retry attempts charged on the NIC this step (engine counter,
    /// captured at `end_step`) — the `retries` CSV column.
    last_retries: u64,
    /// Emit the quorum-clamp warning only once per run.
    quorum_clamp_warned: bool,
}

impl Trainer {
    pub fn new(rt: &Runtime, mut cfg: ExperimentConfig) -> Result<Trainer> {
        cfg.validate()?;
        let model = rt
            .load_model(&cfg.artifacts_dir, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let topo = Topology::new(cfg.nodes, cfg.accels_per_node);
        let layout = FlatLayout::new(&model.manifest.flat_params()).pad_for(cfg.accels_per_node);
        let mesh = HybridMesh::new(topo, &layout);
        let task = task_for(&model.manifest, cfg.seed);

        // Identical init on every node (FSDP replicas start in sync).
        let mut flat = model.manifest.init_flat(cfg.seed);
        flat.resize(layout.padded_len, 0.0);
        let params = vec![flat; cfg.nodes];
        let grads = vec![vec![0.0f32; layout.padded_len]; topo.world_size()];

        // One persistent pool for the whole data plane. The PJRT client
        // is not Sync, so the xla build stays fully inline.
        let threads = if cfg!(feature = "xla") {
            if cfg.threads != 1 {
                log::warn!(
                    "--threads {} ignored: the PJRT (xla) backend is not Sync; \
                     the data plane runs inline",
                    cfg.threads
                );
            }
            1
        } else {
            cfg.threads
        };
        let pool = WorkerPool::new(threads);

        let shard_len = mesh.shards.shard_len();
        // Straggler-tolerant staleness: resolve one S per node from the
        // global knob / the cluster profile / explicit overrides. The
        // gather-volume estimate feeds `--staleness auto`: a full-buffer
        // DiLoCo payload — at the spec's actual wire format (sign/dtype/
        // packing), not a flat 4 B/element — to every replication peer.
        let wire_est = match cfg.repl {
            ReplSpec::DiLoCo {
                sign,
                dtype,
                packed,
                ..
            } => {
                let p = Payload::new(None, vec![0.0; shard_len], dtype, sign);
                let p = if packed && sign { p.with_packing() } else { p };
                p.wire_bytes()
            }
            _ => (shard_len * 4) as u64,
        };
        let gather_est = wire_est * cfg.nodes.saturating_sub(1).max(1) as u64;
        let node_delay = cfg.resolve_node_staleness(model.manifest.step_flops(), gather_est)?;
        // Any `Some` staleness on the spec (set by --staleness,
        // --staleness auto, --node-staleness, or :async=S) arms the async
        // replicator; each rank gets its node's window.
        let async_armed = matches!(cfg.repl, ReplSpec::DiLoCo { staleness: Some(_), .. });
        let node_staleness_label = if async_armed {
            node_delay
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(";")
        } else {
            String::new()
        };
        // Closed-loop rate control: one rate slot per node, seeded from
        // the spec's configured rate. `validate()` already pinned the
        // armed controller to sparse schemes, so `base_rate` is Some.
        let controller = match cfg.compress_control {
            ControlSpec::Aimd(params) => {
                let init = cfg
                    .repl
                    .base_rate()
                    .context("--compress-control needs a sparse scheme")?;
                Some(RateController::new(
                    params,
                    cfg.rate_min,
                    cfg.rate_max,
                    cfg.nodes,
                    init,
                )?)
            }
            ControlSpec::Off => None,
        };
        let rate_label = controller.as_ref().map(|c| c.label()).unwrap_or_default();
        let bctx = ReplBuildCtx {
            shard_len,
            accels: cfg.accels_per_node,
            staleness: if async_armed { Some(&node_delay) } else { None },
            rates: controller.as_ref().map(|c| c.rates()),
            adaptive: controller.is_some(),
        };
        let ranks = (0..topo.world_size())
            .map(|r| {
                let mut opt = cfg.opt.build(shard_len);
                opt.attach_pool(PoolHandle::new(Arc::clone(&pool)));
                let repl = cfg.repl.build_for_node(r, &bctx)?;
                Ok(RankState {
                    opt,
                    repl,
                    scratch: Scratch::with_pool(PoolHandle::new(Arc::clone(&pool))),
                    carried: Vec::new(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let traffic = TrafficMatrix::new(cfg.nodes);
        let engine = StepEngine::new(topo, cfg.net, cfg.cluster.clone(), cfg.overlap)
            .with_buckets(cfg.bucket_bytes())
            .with_faults(FaultLane {
                timeline: cfg.link_fault.clone(),
                seed: cfg.seed,
                max_retries: cfg.max_retries,
                retry_timeout: cfg.retry_timeout,
                retry_backoff: cfg.retry_backoff,
            });
        Ok(Trainer {
            model,
            layout,
            mesh,
            task,
            params,
            grads,
            ranks,
            pool,
            coll_scratch: CollScratch::new(),
            pending: (0..cfg.accels_per_node).map(|_| None).collect(),
            node_delay,
            node_staleness_label,
            controller,
            rate_label,
            dropped_step: vec![0; cfg.nodes],
            peer_set_step: String::new(),
            engine,
            traffic,
            last_timing: StepTiming::default(),
            last_inter: 0,
            last_intra: 0,
            membership: cfg.membership.clone(),
            active: vec![true; cfg.nodes],
            crashed: vec![false; cfg.nodes],
            crash_ckpt: (0..cfg.nodes).map(|_| None).collect(),
            corrupt_detected_step: 0,
            last_retries: 0,
            quorum_clamp_warned: false,
            cfg,
            step: 0,
        })
    }

    /// Per-node liveness mask (all `true` unless a churn timeline is
    /// active).
    pub fn active_nodes(&self) -> &[bool] {
        &self.active
    }

    /// The per-node construction context [`Trainer::new`] built the
    /// ranks with, rebuilt from the trainer's own tables — so the crash
    /// path's rebuilds see the same staleness windows and the
    /// controller's *current* rates.
    fn build_ctx(&self) -> ReplBuildCtx<'_> {
        let async_armed = matches!(self.cfg.repl, ReplSpec::DiLoCo { staleness: Some(_), .. });
        ReplBuildCtx {
            shard_len: self.mesh.shards.shard_len(),
            accels: self.cfg.accels_per_node,
            staleness: if async_armed { Some(&self.node_delay) } else { None },
            rates: self.controller.as_ref().map(|c| c.rates()),
            adaptive: self.controller.is_some(),
        }
    }

    /// Rebuild one rank's replicator exactly as [`Trainer::new`] did —
    /// the crash path wipes the node's in-memory state with this.
    fn build_rank_repl(&self, rank: usize) -> Result<Box<dyn Replicator>> {
        self.cfg.repl.build_for_node(rank, &self.build_ctx())
    }

    /// Fire this step's membership events. Runs right after
    /// [`StepEngine::begin_step`] (which clears the per-step event
    /// trace), so a join broadcast shows up in *this* step's events and
    /// its completion gates the joiner's backward.
    fn apply_membership_events(&mut self) -> Result<()> {
        for (node, ev) in self.membership.events_at(self.step) {
            match ev {
                MembershipEvent::Leave => self.node_depart(node, false)?,
                MembershipEvent::Crash => self.node_depart(node, true)?,
                MembershipEvent::Join => self.node_join(node)?,
            }
        }
        Ok(())
    }

    /// Remove a node from the active set. Its arrivals in every
    /// in-flight window are cancelled (the survivors re-form the group
    /// without it; its already-launched payload stays admissible — the
    /// bytes were on the wire before it went down). A *crash*
    /// additionally loses the node's in-memory state: the optimizer and
    /// replicator are rebuilt fresh, carried deltas are dropped, and
    /// the last published checkpoint is stashed for the rejoin.
    fn node_depart(&mut self, node: usize, crash: bool) -> Result<()> {
        log::info!(
            "step {}: node {node} {}",
            self.step,
            if crash { "crashed" } else { "left" }
        );
        self.active[node] = false;
        self.engine.set_active(&self.active);
        for shard in 0..self.pending.len() {
            let done = match self.pending[shard].as_mut() {
                Some(PendingSync::PerNode { group, applied, .. }) => {
                    for (wi, &r) in group.iter().enumerate() {
                        if self.mesh.topo.node_of(r) == node {
                            applied[wi] = true;
                        }
                    }
                    applied.iter().all(|&x| x)
                }
                // The uniform (PR 4) window only launches when the
                // timeline is empty, so churn can never catch one.
                Some(PendingSync::Uniform { .. }) => anyhow::bail!(
                    "step {}: membership event with a uniform async window in flight",
                    self.step
                ),
                None => false,
            };
            if done {
                let Some(PendingSync::PerNode { group, payloads, .. }) =
                    self.pending[shard].take()
                else {
                    unreachable!("matched above");
                };
                for (wi, p) in payloads.into_iter().enumerate() {
                    self.ranks[group[wi]].scratch.recycle_payload(p);
                }
            }
        }
        if crash {
            self.crashed[node] = true;
            self.crash_ckpt[node] = None;
            if let Some(dir) = &self.cfg.checkpoint_dir {
                let latest = dir.join("latest.ckpt");
                if latest.exists() {
                    let stash = dir.join(format!("crash-node{node}.ckpt"));
                    std::fs::copy(&latest, &stash).with_context(|| {
                        format!("stashing crash checkpoint for node {node}")
                    })?;
                    self.crash_ckpt[node] = Some(stash);
                }
            }
            let shard_len = self.mesh.shards.shard_len();
            for r in 0..self.mesh.topo.world_size() {
                if self.mesh.topo.node_of(r) != node {
                    continue;
                }
                let mut opt = self.cfg.opt.build(shard_len);
                opt.attach_pool(PoolHandle::new(Arc::clone(&self.pool)));
                let repl = self.build_rank_repl(r)?;
                let st = &mut self.ranks[r];
                st.opt = opt;
                st.repl = repl;
                st.carried.clear();
            }
        }
        Ok(())
    }

    /// Re-admit a node. A crashed node first restores its private state
    /// (optimizer moments, replicator accumulators, carried deltas)
    /// from the checkpoint stashed when it went down; either way the
    /// joiner receives the cluster's *current* parameters from node 0
    /// over the inter-node link ([`StepEngine::join_broadcast`]), and
    /// its next backward waits for that transfer.
    fn node_join(&mut self, node: usize) -> Result<()> {
        log::info!("step {}: node {node} joined", self.step);
        if self.crashed[node] {
            if let Some(path) = self.crash_ckpt[node].take() {
                self.restore_node_from_checkpoint(node, &path)?;
            }
            self.crashed[node] = false;
        }
        self.active[node] = true;
        self.engine.set_active(&self.active);
        self.engine
            .join_broadcast(node, (self.layout.padded_len * 4) as u64, &self.traffic);
        // Node 0 anchors the group (the timeline validator rejects
        // events on it), so its replica is always current.
        let (node0, rest) = self.params.split_first_mut().expect("nodes >= 1");
        rest[node - 1].copy_from_slice(node0);
        Ok(())
    }

    /// Number of distinct gradient streams (DESIGN.md §2 scaling rule).
    fn n_streams(&self) -> usize {
        let world = self.mesh.topo.world_size();
        if self.cfg.compute_streams == 0 {
            world
        } else {
            self.cfg.compute_streams.min(world)
        }
    }

    /// Run the deduplicated per-stream fwd/bwd calls on the persistent
    /// worker pool. Stream `s` trains on node `node_of(s)`'s replica and
    /// each stream's computation depends only on `(s, step)` — the same
    /// assignment the sequential loop has always used — so the results
    /// are bit-identical at any pool width.
    #[cfg(not(feature = "xla"))]
    fn run_streams(&self, n_streams: usize) -> Result<Vec<(f32, Vec<f32>)>> {
        let step = self.step;
        if self.pool.width() <= 1 {
            return (0..n_streams)
                .map(|s| {
                    let node = self.mesh.topo.node_of(s);
                    let batch = self.task.train_batch(s as u64, step);
                    self.model
                        .train_step(&self.params[node], &batch)
                        .with_context(|| format!("stream {s} step {step}"))
                })
                .collect();
        }
        let mut results: Vec<Option<Result<(f32, Vec<f32>)>>> =
            (0..n_streams).map(|_| None).collect();
        {
            let slots = SlicePtr::new(&mut results);
            let (model, task, params) = (&self.model, &self.task, &self.params);
            let topo = self.mesh.topo;
            self.pool.run(n_streams, |_w, s| {
                let node = topo.node_of(s);
                let batch = task.train_batch(s as u64, step);
                let r = model
                    .train_step(&params[node], &batch)
                    .with_context(|| format!("stream {s} step {step}"));
                // Safety: one slot per stream, disjoint per task.
                unsafe { slots.range(s, s + 1) }[0] = Some(r);
            });
        }
        results
            .into_iter()
            .map(|r| r.expect("stream not computed"))
            .collect()
    }

    #[cfg(feature = "xla")]
    fn run_streams(&self, n_streams: usize) -> Result<Vec<(f32, Vec<f32>)>> {
        let step = self.step;
        (0..n_streams)
            .map(|s| {
                let node = self.mesh.topo.node_of(s);
                let batch = self.task.train_batch(s as u64, step);
                self.model
                    .train_step(&self.params[node], &batch)
                    .with_context(|| format!("stream {s} step {step}"))
            })
            .collect()
    }

    /// Decode the gathered payloads into each rank's mean, finalize it
    /// against that rank's local update, apply, and recycle the consumed
    /// payloads — one R-group's sync landing, shared by the synchronous
    /// sync step and the async arrival.
    fn apply_mean(
        &mut self,
        group: &[usize],
        rctx: &ReplCtx,
        payloads: Vec<Payload>,
        locals: &mut [Vec<f32>],
        (lo, hi): (usize, usize),
        lr: f32,
    ) {
        for (gi, &rank) in group.iter().enumerate() {
            let st = &mut self.ranks[rank];
            let mean = mean_decoded(st.repl.as_ref(), rctx, &payloads, hi - lo, &mut st.scratch);
            let q = st.repl.finalize(
                rctx,
                std::mem::take(&mut locals[gi]),
                Some(mean),
                &mut st.scratch,
            );
            let node = self.mesh.topo.node_of(rank);
            st.opt.apply(&mut self.params[node][lo..hi], &q, lr);
            st.scratch.put_f32(q);
        }
        // Consumed payloads return their buffers to the ranks that
        // produced them — the next step reuses the capacity.
        for (gi, p) in payloads.into_iter().enumerate() {
            self.ranks[group[gi]].scratch.recycle_payload(p);
        }
    }

    /// One rank's local-only update (no mean lands this step):
    /// `finalize(None)`, then the optimizer step — the single float
    /// chain every local step shares, whichever path invokes it.
    fn apply_local_one(
        &mut self,
        rank: usize,
        rctx: &ReplCtx,
        local: Vec<f32>,
        (lo, hi): (usize, usize),
        lr: f32,
    ) {
        let st = &mut self.ranks[rank];
        let q = st.repl.finalize(rctx, local, None, &mut st.scratch);
        let node = self.mesh.topo.node_of(rank);
        st.opt.apply(&mut self.params[node][lo..hi], &q, lr);
        st.scratch.put_f32(q);
    }

    /// Apply each rank's local-only update for one shard.
    fn apply_local(
        &mut self,
        group: &[usize],
        rctx: &ReplCtx,
        locals: &mut [Vec<f32>],
        lo: usize,
        hi: usize,
        lr: f32,
    ) {
        for (gi, &rank) in group.iter().enumerate() {
            self.apply_local_one(rank, rctx, std::mem::take(&mut locals[gi]), (lo, hi), lr);
        }
    }

    /// One pass over a straggler-tolerant window: every group member
    /// whose arrival step is `rctx.step` aggregates the contributions
    /// that met its arrival deadline — its own payload always (it never
    /// crossed the wire), a peer's iff the peer's send completed by the
    /// end of this member's backward ([`StepEngine::arrival_deadline`]),
    /// plus any earlier-window deltas carried under `--late-policy
    /// partial` whose transfers have landed by now (admitted carried
    /// deltas come ahead of this window's quorum, in a deterministic
    /// order). The averaging denominator is the contributing count
    /// ([`mean_decoded_refs`]). Under `wait` every peer is admitted
    /// regardless of the deadline and the gate carries the slowest
    /// transfer's completion — the per-member rendition of the
    /// whole-group window, for non-uniform staleness tables. Otherwise
    /// late peers count into the per-node `dropped_syncs` column and are
    /// discarded (`drop`) or carried — payload plus completion time — to
    /// one of this member's later windows (`partial`). Every other
    /// member takes a plain local step. The window's payloads are
    /// recycled once the last member has applied.
    fn arrival_scan(
        &mut self,
        group: &[usize],
        rctx: &ReplCtx,
        shard: usize,
        locals: &mut [Vec<f32>],
        (lo, hi): (usize, usize),
        lr: f32,
    ) -> Result<()> {
        let step = rctx.step;
        let policy = self.cfg.late_policy();
        // Take the window out of its slot so its payload borrows cannot
        // alias the rank/engine/param field borrows below.
        let mut pending = self.pending[shard].take();
        let done = {
            let Some(PendingSync::PerNode {
                group: wgroup,
                payloads,
                contrib_end,
                arrival,
                applied,
                peers,
            }) = pending.as_mut()
            else {
                anyhow::bail!("step {step} shard {shard}: arrival scan without a per-node window");
            };
            // `--quorum` is evaluated against the *window's* (re-formed)
            // group: churn between the static validation and this window
            // can shrink the group below K, in which case K clamps to
            // what exists instead of waiting on contributions that can
            // never come.
            let mut quorum_k = self.cfg.quorum;
            if quorum_k > wgroup.len() {
                if !self.quorum_clamp_warned {
                    log::warn!(
                        "step {step}: --quorum {} exceeds the re-formed group size {}; \
                         clamping to the group",
                        quorum_k,
                        wgroup.len()
                    );
                    self.quorum_clamp_warned = true;
                }
                quorum_k = wgroup.len();
            }
            for (gi, &rank) in group.iter().enumerate() {
                let node = self.mesh.topo.node_of(rank);
                // Map this member into the *window's* group by rank:
                // under churn the current group can differ from the one
                // that launched the window. A member with no slot
                // (joined after the launch), a slot whose arrival is not
                // now, or one already applied takes a plain local step.
                let wi = wgroup
                    .iter()
                    .position(|&r| r == rank)
                    .filter(|&wi| arrival[wi] == step && !applied[wi]);
                let Some(wi) = wi else {
                    self.apply_local_one(rank, rctx, std::mem::take(&mut locals[gi]), (lo, hi), lr);
                    continue;
                };
                applied[wi] = true;
                let deadline = self.engine.arrival_deadline(rank);
                // Deltas carried from the previous window join ahead of
                // this window's quorum once their transfer has landed;
                // pulled out of the rank first so the borrows stay
                // disjoint. A carried delta still in flight stays
                // carried (it was already counted late once).
                let carried = std::mem::take(&mut self.ranks[rank].carried);
                let mut next_carried: Vec<(Payload, SimTime)> = Vec::new();
                let mut admitted = vec![false; carried.len()];
                for (ci, (_, end)) in carried.iter().enumerate() {
                    if *end <= deadline {
                        admitted[ci] = true;
                    }
                }
                // Peer admission: own delta always (it never crossed the
                // wire); a peer's if `wait` admits everything (the
                // whole-group semantics, only without `--quorum`) or its
                // send landed by the deadline. A +∞ completion is a
                // transfer that exhausted its retries — it can *never*
                // land, so not even `wait` admits it (gating on it would
                // freeze the clock); it falls through to the late
                // handling below.
                // A position outside this member's topology peer set is
                // not part of its exchange at all: never admitted, never
                // late, never counted — the member's mean is over its
                // peer set only. `--topology full` lists every other
                // position, so in_scope is always true there and the
                // decisions below are bit-identical to the pre-topology
                // scan.
                let in_scope = |wj: usize| wj == wi || peers[wi].contains(&wj);
                let mut admit_peer = vec![false; wgroup.len()];
                let mut late_idx: Vec<usize> = Vec::new();
                for wj in 0..wgroup.len() {
                    if !in_scope(wj) {
                        continue;
                    }
                    if wj == wi
                        || (quorum_k == 0
                            && policy == LatePolicy::Wait
                            && contrib_end[wj].is_finite())
                        || contrib_end[wj] <= deadline
                    {
                        admit_peer[wj] = true;
                    } else {
                        late_idx.push(wj);
                    }
                }
                // `--quorum K`: the member finalizes once at least K of
                // the window's contributions are in. If fewer landed on
                // time, the earliest late transfers are admitted until
                // the quorum is met — the gate then waits for them.
                // Whatever is still left over follows the late policy.
                if quorum_k > 0 {
                    let mut n_admit = admit_peer.iter().filter(|&&x| x).count();
                    if n_admit < quorum_k && !late_idx.is_empty() {
                        late_idx.sort_by(|&x, &y| {
                            contrib_end[x]
                                .partial_cmp(&contrib_end[y])
                                .unwrap_or(std::cmp::Ordering::Equal)
                                .then(x.cmp(&y))
                        });
                        for &wj in &late_idx {
                            if n_admit >= quorum_k {
                                break;
                            }
                            // A permanently partitioned sender can't top
                            // up the quorum — waiting on it would be the
                            // deadlock this fallback exists to prevent.
                            if !contrib_end[wj].is_finite() {
                                continue;
                            }
                            admit_peer[wj] = true;
                            n_admit += 1;
                        }
                    }
                }
                let mut quorum: Vec<&Payload> = Vec::new();
                let mut gate: SimTime = 0.0;
                for (ci, (p, end)) in carried.iter().enumerate() {
                    if admitted[ci] {
                        gate = gate.max(*end);
                        quorum.push(p);
                    }
                }
                let mut late = 0u64;
                for (wj, p) in payloads.iter().enumerate() {
                    if admit_peer[wj] {
                        if wj != wi {
                            // An admitted peer send gates the next
                            // backward — under `wait` (or a quorum
                            // top-up) that deliberately includes
                            // transfers completing after the deadline.
                            gate = gate.max(contrib_end[wj]);
                        }
                        quorum.push(p);
                    } else if in_scope(wj) {
                        late += 1;
                        if policy == LatePolicy::Partial && contrib_end[wj].is_finite() {
                            next_carried.push((p.clone(), contrib_end[wj]));
                        }
                        // An exhausted (+∞) transfer degrades to drop
                        // under every policy: the bytes never arrive, so
                        // carrying or waiting on them is meaningless. The
                        // denominator-corrected mean already handles the
                        // missing contribution.
                    }
                }
                self.dropped_step[node] += late;
                // Only admitted peer sends gate the next backward.
                // Under drop/partial every admitted contribution landed
                // before this backward's end, so the gate can never
                // stall its admitter; under wait the gate deliberately
                // carries the slowest transfer and stalls.
                self.engine.sync_arrival_member(rank, gate);
                let st = &mut self.ranks[rank];
                let mean =
                    mean_decoded_refs(st.repl.as_ref(), rctx, &quorum, hi - lo, &mut st.scratch);
                drop(quorum);
                let q = st.repl.finalize(
                    rctx,
                    std::mem::take(&mut locals[gi]),
                    Some(mean),
                    &mut st.scratch,
                );
                st.opt.apply(&mut self.params[node][lo..hi], &q, lr);
                st.scratch.put_f32(q);
                for (ci, (p, end)) in carried.into_iter().enumerate() {
                    if admitted[ci] {
                        st.scratch.recycle_payload(p);
                    } else {
                        next_carried.push((p, end));
                    }
                }
                self.ranks[rank].carried = next_carried;
            }
            applied.iter().all(|&x| x)
        };
        if done {
            let Some(PendingSync::PerNode {
                group: wgroup,
                payloads,
                ..
            }) = pending
            else {
                unreachable!("checked above");
            };
            // Consumed payloads return their buffers to the ranks that
            // produced them — the next window reuses the capacity.
            for (wi, p) in payloads.into_iter().enumerate() {
                self.ranks[wgroup[wi]].scratch.recycle_payload(p);
            }
        } else {
            self.pending[shard] = pending;
        }
        Ok(())
    }

    /// Number of deferred syncs currently in flight (shards whose
    /// launched gather has not arrived yet) — the `sync_in_flight`
    /// metrics column.
    pub fn syncs_in_flight(&self) -> u64 {
        self.pending.iter().filter(|p| p.is_some()).count() as u64
    }

    /// One full FlexDeMo step. Returns the mean train loss across ranks.
    pub fn step(&mut self) -> Result<f64> {
        let world = self.mesh.topo.world_size();
        let accels = self.cfg.accels_per_node;
        let step = self.step;
        self.engine.begin_step();
        self.engine.set_fault_step(step);
        self.dropped_step.fill(0);
        self.peer_set_step.clear();
        self.corrupt_detected_step = 0;
        if !self.membership.is_empty() {
            self.apply_membership_events()?;
        }

        // -- 0. FSDP unshard: within each node, updated parameters are
        // all-gathered from shards before they are next used. Data-wise
        // the node buffer is already whole; the engine charges the wire
        // time and traffic (overlapped behind backward compute when
        // overlap is on).
        let shard_bytes = (self.mesh.shards.shard_len() * 4) as u64;
        self.engine.unshard(shard_bytes, &self.traffic);

        // -- 1. fwd/bwd per rank (deduplicated by gradient stream, fanned
        // out onto the persistent worker pool).
        let n_streams = self.n_streams();
        let stream_results = self.run_streams(n_streams)?;
        let mut loss_sum = 0.0f64;
        let mut active_world = 0usize;
        for rank in 0..world {
            // A departed node computes nothing; its stale gradient
            // buffers are never read (every phase below skips it).
            if !self.active[self.mesh.topo.node_of(rank)] {
                continue;
            }
            active_world += 1;
            let (loss, grads) = &stream_results[rank % n_streams];
            loss_sum += *loss as f64;
            let g = &mut self.grads[rank];
            g[..grads.len()].copy_from_slice(grads);
            g[grads.len()..].fill(0.0); // pad region carries no gradient
        }
        self.engine.compute(self.model.manifest.step_flops());

        // -- 2. intra-node reduce-scatter (S groups run in parallel; the
        // engine streams the event behind the backward). The data plane
        // runs chunk-parallel on the pool, staged through coll_scratch.
        let mut ctx = CollCtx {
            topo: &self.mesh.topo,
            model: &self.cfg.net,
            traffic: &self.traffic,
            pool: &*self.pool,
            scratch: &mut self.coll_scratch,
        };
        for node in 0..self.cfg.nodes {
            if !self.active[node] {
                continue;
            }
            let group = ctx.topo.shard_group(ctx.topo.rank(node, 0));
            let shards: Vec<(usize, usize)> =
                (0..accels).map(|a| self.mesh.shards.range(a)).collect();
            let (_, tail) = self.grads.split_at_mut(node * accels);
            let bufs_vec = &mut tail[..accels];
            let mut bufs: Vec<&mut [f32]> =
                bufs_vec.iter_mut().map(|v| v.as_mut_slice()).collect();
            let _ = collectives::ring_reduce_scatter_avg(&mut ctx, &group, &mut bufs, &shards);
        }
        self.engine.reduce_scatter(shard_bytes);

        // -- 3+4. decoupled accumulate, extract, replicate per R-group.
        for a in 0..accels {
            let (lo, hi) = self.mesh.shards.range(a);
            let rctx = ReplCtx {
                step,
                shard: a,
                seed: self.cfg.seed,
            };
            let mut group = self.mesh.repl_group_of_shard(a);
            // Group re-formation under churn: departed nodes drop out of
            // the gather and the averaging denominator follows the group
            // size. Node 0 anchors every group, so it is never empty.
            // `retain` on the all-active mask is a no-op (bit-identity
            // with the fixed-group path is pinned by proptest).
            group.retain(|&r| self.active[self.mesh.topo.node_of(r)]);
            debug_assert!(!group.is_empty(), "node 0 anchors every repl group");

            // accumulate + extract on every rank of the group
            let mut locals: Vec<Vec<f32>> = Vec::with_capacity(group.len());
            let mut payloads = Vec::with_capacity(group.len());
            let mut any_payload = false;
            for &rank in &group {
                let grad_shard = &self.grads[rank][lo..hi];
                let st = &mut self.ranks[rank];
                st.opt.accumulate(grad_shard);
                let (q_local, payload) =
                    st.repl.extract(&rctx, st.opt.buffer_mut(), &mut st.scratch);
                any_payload |= payload.is_some();
                locals.push(q_local);
                payloads.push(payload);
            }

            // gather + decode + finalize + apply
            let lr = self.cfg.lr_at(step);
            if any_payload {
                anyhow::ensure!(
                    payloads.iter().all(|p| p.is_some()),
                    "ranks disagree on sync step {step} shard {a}"
                );
                let payloads: Vec<Payload> = payloads.into_iter().map(|p| p.unwrap()).collect();
                let mode = self.ranks[group[0]].repl.gather_mode();
                let sizes: Vec<u64> = payloads.iter().map(|p| p.wire_bytes()).collect();
                let delays: Vec<u64> = group
                    .iter()
                    .map(|&r| self.node_delay[self.mesh.topo.node_of(r)])
                    .collect();
                let uniform = delays.iter().all(|&d| d == delays[0]);
                // Any non-empty link-fault timeline routes through the
                // per-member path below: faults act on individual NIC
                // transfers, which only exist as per-member lanes (the
                // same trick the membership timeline uses). A non-full
                // sync topology does the same: a gossip exchange only
                // exists as per-member peer-set lanes.
                let faultless = self.cfg.link_fault.is_empty();
                let topo_full = self.cfg.topology.is_full();
                // An armed rate controller also routes per-member: rates
                // may diverge across nodes mid-run, and the controller's
                // occupancy taps need each member's send on its own NIC
                // lane. With delays all 0 and `wait` the scan below
                // admits everything in this same step — the whole-group
                // mean, charged per member.
                let ctl_armed = self.controller.is_some();
                if topo_full
                    && uniform
                    && delays[0] == 0
                    && self.cfg.quorum == 0
                    && faultless
                    && !ctl_armed
                {
                    // Synchronous replication: the mean lands this step.
                    self.engine.gather(&group, mode, &sizes, &self.traffic);
                    self.apply_mean(&group, &rctx, payloads, &mut locals, (lo, hi), lr);
                } else if topo_full
                    && uniform
                    && self.cfg.late_policy() == LatePolicy::Wait
                    && self.cfg.quorum == 0
                    && self.membership.is_empty()
                    && faultless
                    && !ctl_armed
                {
                    // PR 4 async launch (bit-frozen whole-group window):
                    // charge the wire on the deferred lane, park the
                    // payloads, and apply only this step's local update —
                    // the averaged delta lands `delay` steps from now.
                    anyhow::ensure!(
                        self.pending[a].is_none(),
                        "step {step} shard {a}: deferred sync launched with one still in flight"
                    );
                    self.engine.gather_deferred(&group, mode, &sizes, &self.traffic);
                    self.pending[a] = Some(PendingSync::Uniform {
                        arrival: step + delays[0],
                        payloads,
                    });
                    self.apply_local(&group, &rctx, &mut locals, lo, hi, lr);
                } else {
                    // Straggler-tolerant launch: one NIC lane per member
                    // (each send starts at its own reduce-scatter), one
                    // arrival step per node. Members with S = 0 aggregate
                    // in this same step's arrival scan below.
                    anyhow::ensure!(
                        self.pending[a].is_none(),
                        "step {step} shard {a}: deferred sync launched with one still in flight"
                    );
                    // The window's exchange sets, computed once at
                    // launch over the (re-formed) group's positions: a
                    // pure hash of (seed, step, shard), identical on
                    // every rank and rerun. Full lists every other
                    // position — the whole-group mean, bit-identical
                    // admission decisions to the pre-topology scan.
                    let peers =
                        self.cfg
                            .topology
                            .peer_sets(self.cfg.seed, step, a as u64, group.len());
                    let contrib_end = self.engine.gather_deferred_per_member(
                        &group,
                        mode,
                        &sizes,
                        &self.traffic,
                        if topo_full { None } else { Some(&peers) },
                    );
                    if !topo_full {
                        self.peer_set_step = peers
                            .iter()
                            .map(|p| p.len().to_string())
                            .collect::<Vec<_>>()
                            .join(";");
                    }
                    // Fault bookkeeping: every corrupt delivery is
                    // checked against the payload's real checksum (the
                    // detection the retry was predicated on), and an
                    // exhausted sender is logged — its +∞ completion
                    // falls back through the late-arrival machinery.
                    if !faultless {
                        let reports: Vec<MemberFault> =
                            self.engine.last_member_faults().to_vec();
                        for (i, mf) in reports.iter().enumerate() {
                            if mf.corrupt > 0 {
                                self.corrupt_detected_step += Self::verify_corrupt_detected(
                                    &payloads[i],
                                    self.cfg.seed,
                                    step,
                                    mf.corrupt,
                                );
                            }
                            if !mf.delivered {
                                log::warn!(
                                    "step {step} shard {a}: node {} transfer failed after \
                                     {} retries; sender treated as late ({})",
                                    self.mesh.topo.node_of(group[i]),
                                    mf.retries,
                                    self.cfg.late_policy().label()
                                );
                            }
                        }
                    }
                    self.pending[a] = Some(PendingSync::PerNode {
                        group: group.clone(),
                        payloads,
                        contrib_end,
                        arrival: delays.iter().map(|&d| step + d).collect(),
                        applied: vec![false; group.len()],
                        peers,
                    });
                    self.arrival_scan(&group, &rctx, a, &mut locals, (lo, hi), lr)?;
                }
            } else if matches!(
                self.pending[a],
                Some(PendingSync::Uniform { arrival, .. }) if arrival == step
            ) {
                // Async arrival: the in-flight gather's mean is applied
                // alongside this step's local update, and its completion
                // starts gating the next backward.
                let Some(PendingSync::Uniform { payloads, .. }) = self.pending[a].take() else {
                    unreachable!("guarded by the match above");
                };
                self.engine.sync_arrival(&group);
                self.apply_mean(&group, &rctx, payloads, &mut locals, (lo, hi), lr);
            } else if matches!(self.pending[a], Some(PendingSync::PerNode { .. })) {
                // Straggler-tolerant window in flight: members whose
                // arrival step is now aggregate their on-time quorum,
                // the rest take a local step.
                self.arrival_scan(&group, &rctx, a, &mut locals, (lo, hi), lr)?;
            } else {
                // Local-only step (DiLoCo between syncs).
                self.apply_local(&group, &rctx, &mut locals, lo, hi, lr);
            }
        }
        self.last_timing = self.engine.end_step();
        self.last_retries = self.engine.step_fault_counts().0;

        // Controller window: accumulate this step's exposed comm and, at
        // the window boundary, retune each node's rate from its NIC
        // lanes' busy deltas (a node's accels share its NIC — their lane
        // totals sum; `retune` clamps the fraction to [0, 1]) before
        // pushing the new rates into every rank's replicator.
        if let Some(ctl) = self.controller.as_mut() {
            ctl.note_step(self.last_timing.exposed_comm);
            if (step + 1) % self.cfg.control_window == 0 {
                let mut busy = vec![0.0f64; self.cfg.nodes];
                for r in 0..world {
                    busy[self.mesh.topo.node_of(r)] += self.engine.nic_busy(r);
                }
                if ctl.retune(&busy, self.engine.now()) {
                    for r in 0..world {
                        let rate = ctl.rates()[self.mesh.topo.node_of(r)];
                        self.ranks[r].repl.set_rate(rate);
                    }
                    self.rate_label = ctl.label();
                }
            }
        }

        self.step += 1;
        Ok(loss_sum / active_world.max(1) as f64)
    }

    /// Verify that corruption is *detected*, not absorbed: flip one
    /// deterministic bit of the payload's wire image per corrupt attempt
    /// and count the flips the checksum catches. CRC-32 guarantees every
    /// single-bit flip is caught, so this returns `attempts` — but it
    /// returns the checked count rather than assuming it, which is the
    /// point of shipping a checksum instead of a boolean.
    fn verify_corrupt_detected(p: &Payload, seed: u64, step: u64, attempts: u32) -> u64 {
        let expected = p.checksum();
        let mut img = p.wire_image();
        if img.is_empty() {
            return 0;
        }
        let bits = img.len() as u64 * 8;
        let mut detected = 0u64;
        for a in 0..attempts {
            let mut h = crate::util::rng::SplitMix64::new(
                seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (a as u64 + 1),
            );
            let bit = h.next_u64() % bits;
            let (byte, mask) = ((bit / 8) as usize, 1u8 << (bit % 8));
            img[byte] ^= mask;
            if crate::util::crc32(&img) != expected {
                detected += 1;
            }
            img[byte] ^= mask; // restore for the next attempt's flip
        }
        detected
    }

    /// Current simulated time (the event horizon across all ranks).
    pub fn sim_now(&self) -> f64 {
        self.engine.now()
    }

    /// Validation loss on the held-out split (node-0 parameters); the
    /// eval sweep runs chunk-parallel on the worker pool.
    pub fn validate(&self, batches: u64) -> Result<f64> {
        let mut total = 0.0f64;
        for i in 0..batches {
            let batch = self.task.val_batch(i);
            total += self
                .model
                .eval_step_pooled(&self.params[0], &batch, &self.pool)? as f64;
        }
        Ok(total / batches.max(1) as f64)
    }

    /// Drift between node parameter replicas (max |θ_0 − θ_n|∞); zero for
    /// every-step schemes, bounded for DiLoCo between syncs.
    pub fn replica_drift(&self) -> f32 {
        let mut worst = 0.0f32;
        for n in 1..self.params.len() {
            for (a, b) in self.params[0].iter().zip(&self.params[n]) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }

    /// Wire stats of a hypothetical payload from rank 0's current state
    /// (used by the bandwidth figures without running a gather). Runs a
    /// throwaway replicator instance so stateful schemes (DiLoCo's
    /// displacement accumulator) never absorb the probed buffer.
    pub fn probe_payload(&mut self) -> Option<WireStats> {
        let rctx = ReplCtx {
            step: self.step,
            shard: 0,
            seed: self.cfg.seed,
        };
        let mut probe = self.cfg.repl.build_for_node(0, &self.build_ctx()).ok()?;
        let st = &mut self.ranks[0];
        // Stage the optimizer buffer through a scratch-pooled vector
        // instead of a fresh `to_vec` clone per probe — the next probe
        // reuses the capacity.
        let mut buf = st.scratch.take_f32();
        buf.extend_from_slice(st.opt.buffer_mut());
        let (q, p) = probe.extract(&rctx, &mut buf, &mut st.scratch);
        st.scratch.put_f32(buf);
        st.scratch.put_f32(q);
        let stats = p.as_ref().map(WireStats::of);
        if let Some(p) = p {
            st.scratch.recycle_payload(p);
        }
        stats
    }

    /// Run the configured number of steps, collecting metrics.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let label = format!(
            "{}-{}-{}",
            self.cfg.model,
            self.cfg.opt.label(),
            self.cfg.repl.label()
        );
        let mut metrics = RunMetrics::new(label);
        // `--trace-out`: accumulate every step's scheduled comm events
        // (the engine clears them per step) for the Chrome-trace dump.
        let mut trace: Vec<(u64, CommEvent)> = Vec::new();
        for _ in 0..self.cfg.steps {
            let wall0 = Instant::now();
            let loss = self.step()?;
            if self.cfg.trace_out.is_some() {
                trace.extend(self.engine.events.iter().map(|ev| (self.step - 1, ev.clone())));
            }
            let inter = self.traffic.inter_node_bytes();
            let intra = self.traffic.intra_node_bytes();
            metrics.steps.push(StepRow {
                step: self.step - 1,
                sim_time: self.sim_now(),
                loss,
                inter_bytes: inter - self.last_inter,
                intra_bytes: intra - self.last_intra,
                compute_time: self.last_timing.compute_time,
                exposed_comm: self.last_timing.exposed_comm,
                hidden_comm: self.last_timing.hidden_comm,
                comm_events: self.engine.events.len() as u64,
                staleness: self.node_delay.iter().copied().max().unwrap_or(0),
                node_staleness: self.node_staleness_label.clone(),
                rate: self.rate_label.clone(),
                sync_in_flight: self.syncs_in_flight(),
                dropped_syncs: if self.node_staleness_label.is_empty() {
                    String::new()
                } else {
                    self.dropped_step
                        .iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(";")
                },
                peer_set: self.peer_set_step.clone(),
                membership: if self.membership.is_empty() {
                    String::new()
                } else {
                    membership_label(&self.active)
                },
                retries: self.last_retries,
                corrupt_detected: self.corrupt_detected_step,
                faulted_links: self
                    .cfg
                    .link_fault
                    .active_link_count(self.step - 1, self.cfg.nodes),
                wall_time: wall0.elapsed().as_secs_f64(),
            });
            self.last_inter = inter;
            self.last_intra = intra;

            // `--checkpoint-dir`: publish a checkpoint at every
            // window-quiescent step boundary, so a crash always has a
            // "last completed sync window" to rejoin from. (Parking a
            // window and crashing before its arrival would otherwise
            // lose contributions that exist nowhere else.)
            if self.cfg.checkpoint_dir.is_some() && self.syncs_in_flight() == 0 {
                let dir = self
                    .cfg
                    .checkpoint_dir
                    .clone()
                    .expect("checked is_some above");
                self.save_checkpoint(&dir)?;
            }

            if self.cfg.val_every > 0 && self.step % self.cfg.val_every == 0 {
                let vloss = self.validate(self.cfg.val_batches)?;
                log::info!(
                    "step {:>5}  loss {:.4}  val {:.4}  sim {}",
                    self.step,
                    loss,
                    vloss,
                    crate::util::fmt_secs(self.sim_now())
                );
                metrics.val.push(ValRow {
                    step: self.step,
                    sim_time: self.sim_now(),
                    loss: vloss,
                });
            } else if self.step % 50 == 0 {
                log::debug!("step {:>5}  loss {loss:.4}", self.step);
            }
        }
        if let Some(path) = &self.cfg.trace_out {
            let doc = engine::chrome_trace_json(&trace, self.cfg.accels_per_node);
            std::fs::write(path, doc.to_string_pretty())
                .with_context(|| format!("writing schedule trace to {path:?}"))?;
            log::info!(
                "wrote Chrome-trace schedule ({} events) to {}",
                trace.len(),
                path.display()
            );
        }
        Ok(metrics)
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Parameters of node 0 (inspection / examples).
    pub fn params_node0(&self) -> &[f32] {
        &self.params[0]
    }
}
