//! The FlexDeMo training loop — Algorithm 1 of the paper, end to end.
//!
//! Per step, over the hybrid mesh (S = intra-node sharding groups,
//! R = inter-node replication groups):
//!
//! 1. every rank runs fwd+bwd on its own microbatch through the AOT HLO
//!    artifact (`runtime::ModelRuntime::train_step`) — full parameters,
//!    full gradient (`p.grad` in the paper's PyTorch framing);
//! 2. `GradReduceScatter(θ_t, S)`: ring reduce-scatter averages gradients
//!    intra-node; each rank keeps its shard;
//! 3. the optimizer folds the gradient shard into the decoupled buffer
//!    (`m ← βm + Δ`);
//! 4. the replicator extracts the fast components `q` (buffer keeps the
//!    residual) and, on sync steps, the compressed payloads cross R via
//!    the naive blocking all-gather (ring all-reduce for the Full
//!    baseline); decoded payloads are averaged;
//! 5. `θ ← θ − η·Q` on the shard; intra-node all-gather unshards the
//!    updated parameters for the next forward pass.
//!
//! Edge cases degrade exactly as the paper states: |R|=1 → pure FSDP,
//! |S|=1 → DeMo-style DDP, |S|=|R|=1 → single-accelerator training.
//!
//! Everything is deterministic: data streams, init, and the Random/
//! Striding index sets all derive from `config.seed`.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::collectives::{self, CollCtx};
use crate::compress::WireStats;
use crate::config::ExperimentConfig;
use crate::data::{task_for, Task};
use crate::metrics::{RunMetrics, StepRow, ValRow};
use crate::net::{SimClock, Topology, TrafficMatrix};
use crate::optim::Optimizer;
use crate::replicate::{mean_decoded, GatherMode, ReplCtx, Replicator};
use crate::runtime::{ModelRuntime, Runtime};
use crate::shard::{FlatLayout, HybridMesh};

/// Per-rank state (optimizer + replicator own shard-sized buffers).
struct RankState {
    opt: Box<dyn Optimizer>,
    repl: Box<dyn Replicator>,
}

/// The assembled training system.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub model: ModelRuntime,
    pub layout: FlatLayout,
    pub mesh: HybridMesh,
    task: Box<dyn Task>,
    /// Per-node padded flat parameter buffer (nodes may diverge under
    /// DiLoCo between syncs; otherwise they stay bit-identical — tested).
    params: Vec<Vec<f32>>,
    /// Per-rank gradient buffers (padded).
    grads: Vec<Vec<f32>>,
    ranks: Vec<RankState>,
    pub clock: SimClock,
    pub traffic: TrafficMatrix,
    /// Cumulative inter/intra byte counters at the last step boundary.
    last_inter: u64,
    last_intra: u64,
    step: u64,
}

impl Trainer {
    pub fn new(rt: &Runtime, cfg: ExperimentConfig) -> Result<Trainer> {
        let model = rt
            .load_model(&cfg.artifacts_dir, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let topo = Topology::new(cfg.nodes, cfg.accels_per_node);
        let layout = FlatLayout::new(&model.manifest.flat_params()).pad_for(cfg.accels_per_node);
        let mesh = HybridMesh::new(topo, &layout);
        let task = task_for(&model.manifest, cfg.seed);

        // Identical init on every node (FSDP replicas start in sync).
        let mut flat = model.manifest.init_flat(cfg.seed);
        flat.resize(layout.padded_len, 0.0);
        let params = vec![flat; cfg.nodes];
        let grads = vec![vec![0.0f32; layout.padded_len]; topo.world_size()];

        let shard_len = mesh.shards.shard_len();
        let ranks = (0..topo.world_size())
            .map(|_| RankState {
                opt: cfg.opt.build(shard_len),
                repl: cfg.repl.build(shard_len),
            })
            .collect();

        let traffic = TrafficMatrix::new(cfg.nodes);
        Ok(Trainer {
            model,
            layout,
            mesh,
            task,
            params,
            grads,
            ranks,
            clock: SimClock::new(),
            traffic,
            last_inter: 0,
            last_intra: 0,
            cfg,
            step: 0,
        })
    }

    /// Number of distinct gradient streams (DESIGN.md §2 scaling rule).
    fn n_streams(&self) -> usize {
        let world = self.mesh.topo.world_size();
        if self.cfg.compute_streams == 0 {
            world
        } else {
            self.cfg.compute_streams.min(world)
        }
    }

    /// One full FlexDeMo step. Returns the mean train loss across ranks.
    pub fn step(&mut self) -> Result<f64> {
        let world = self.mesh.topo.world_size();
        let accels = self.cfg.accels_per_node;
        let step = self.step;
        let ctx = CollCtx {
            topo: &self.mesh.topo,
            model: &self.cfg.net,
            traffic: &self.traffic,
        };

        // -- 0. FSDP unshard accounting: within each node, parameters are
        // all-gathered from shards before the forward pass. Data-wise the
        // node buffer is already whole; charge the wire time.
        let shard_bytes = (self.mesh.shards.shard_len() * 4) as u64;
        if accels > 1 {
            for node in 0..self.cfg.nodes {
                for a in 0..accels {
                    for b in 0..accels {
                        if a != b {
                            // ring all-gather neighbor traffic, recorded once
                            let _ = (a, b);
                        }
                    }
                }
                self.traffic
                    .record(node, node, (accels - 1) as u64 * shard_bytes * accels as u64);
            }
            let t_unshard = (accels as f64 - 1.0)
                * self
                    .cfg
                    .net
                    .xfer_time(crate::net::LinkClass::IntraNode, shard_bytes);
            self.clock.advance(t_unshard);
        }

        // -- 1. fwd/bwd per rank (deduplicated by gradient stream).
        let n_streams = self.n_streams();
        let mut stream_results: Vec<Option<(f32, Vec<f32>)>> = vec![None; n_streams];
        let mut loss_sum = 0.0f64;
        for rank in 0..world {
            let node = self.mesh.topo.node_of(rank);
            let stream = rank % n_streams;
            if stream_results[stream].is_none() {
                let batch = self.task.train_batch(stream as u64, step);
                let out = self
                    .model
                    .train_step(&self.params[node], &batch)
                    .with_context(|| format!("rank {rank} step {step}"))?;
                stream_results[stream] = Some(out);
            }
            let (loss, grads) = stream_results[stream].as_ref().unwrap();
            loss_sum += *loss as f64;
            let g = &mut self.grads[rank];
            g[..grads.len()].copy_from_slice(grads);
            g[grads.len()..].fill(0.0); // pad region carries no gradient
        }
        // Compute time: all ranks run in parallel; advance once.
        self.clock
            .advance(self.cfg.net.compute_time(self.model.manifest.step_flops()));

        // -- 2. intra-node reduce-scatter (S groups run in parallel).
        let mut t_rs_max = 0.0f64;
        for node in 0..self.cfg.nodes {
            let group = self.mesh.topo.shard_group(self.mesh.topo.rank(node, 0));
            let shards: Vec<(usize, usize)> =
                (0..accels).map(|a| self.mesh.shards.range(a)).collect();
            let (head, tail) = self.grads.split_at_mut(node * accels);
            let _ = head;
            let bufs_vec = &mut tail[..accels];
            let mut bufs: Vec<&mut [f32]> =
                bufs_vec.iter_mut().map(|v| v.as_mut_slice()).collect();
            let t = collectives::ring_reduce_scatter_avg(&ctx, &group, &mut bufs, &shards);
            t_rs_max = t_rs_max.max(t);
        }
        self.clock.advance(t_rs_max);

        // -- 3+4. decoupled accumulate, extract, replicate per R-group.
        let mut t_repl_max = 0.0f64;
        for a in 0..accels {
            let (lo, hi) = self.mesh.shards.range(a);
            let rctx = ReplCtx {
                step,
                shard: a,
                seed: self.cfg.seed,
            };
            let group = self.mesh.repl_group_of_shard(a);

            // accumulate + extract on every rank of the group
            let mut locals: Vec<Vec<f32>> = Vec::with_capacity(group.len());
            let mut payloads = Vec::with_capacity(group.len());
            let mut any_payload = false;
            for &rank in &group {
                let grad_shard = &self.grads[rank][lo..hi];
                let st = &mut self.ranks[rank];
                st.opt.accumulate(grad_shard);
                let (q_local, payload) = st.repl.extract(&rctx, st.opt.buffer_mut());
                any_payload |= payload.is_some();
                locals.push(q_local);
                payloads.push(payload);
            }

            // gather + decode + finalize + apply
            if any_payload {
                anyhow::ensure!(
                    payloads.iter().all(|p| p.is_some()),
                    "ranks disagree on sync step {step} shard {a}"
                );
                let payloads: Vec<crate::compress::Payload> =
                    payloads.into_iter().map(|p| p.unwrap()).collect();
                let mode = self.ranks[group[0]].repl.gather_mode();
                let t = match mode {
                    GatherMode::NaiveAllGather => {
                        let sized: Vec<((), u64)> =
                            payloads.iter().map(|p| ((), p.wire_bytes())).collect();
                        let (_, t) = collectives::naive_all_gather_bytes(&ctx, &group, &sized);
                        t
                    }
                    GatherMode::RingAllReduce => {
                        // Dense ring over the payload bytes; record ring traffic.
                        let g = group.len();
                        let bytes = payloads[0].wire_bytes();
                        if g > 1 {
                            let chunk = bytes / g as u64;
                            for sidx in 0..g {
                                for _ in 0..2 * (g - 1) {
                                    ctx.traffic.record(
                                        self.mesh.topo.node_of(group[sidx]),
                                        self.mesh.topo.node_of(group[(sidx + 1) % g]),
                                        chunk,
                                    );
                                }
                            }
                            2.0 * (g as f64 - 1.0)
                                * self.cfg.net.xfer_time(
                                    self.mesh.topo.group_link_class(&group),
                                    chunk,
                                )
                        } else {
                            0.0
                        }
                    }
                };
                t_repl_max = t_repl_max.max(t);

                let lr = self.cfg.lr_at(step);
                for (gi, &rank) in group.iter().enumerate() {
                    let st = &mut self.ranks[rank];
                    let mean = mean_decoded(st.repl.as_ref(), &rctx, &payloads, hi - lo);
                    let q = st
                        .repl
                        .finalize(&rctx, std::mem::take(&mut locals[gi]), Some(mean));
                    let node = self.mesh.topo.node_of(rank);
                    st.opt.apply(&mut self.params[node][lo..hi], &q, lr);
                }
            } else {
                // Local-only step (DiLoCo between syncs).
                let lr = self.cfg.lr_at(step);
                for (gi, &rank) in group.iter().enumerate() {
                    let st = &mut self.ranks[rank];
                    let q = st
                        .repl
                        .finalize(&rctx, std::mem::take(&mut locals[gi]), None);
                    let node = self.mesh.topo.node_of(rank);
                    st.opt.apply(&mut self.params[node][lo..hi], &q, lr);
                }
            }
        }
        self.clock.advance(t_repl_max);

        self.step += 1;
        Ok(loss_sum / world as f64)
    }

    /// Validation loss on the held-out split (node-0 parameters).
    pub fn validate(&self, batches: u64) -> Result<f64> {
        let mut total = 0.0f64;
        for i in 0..batches {
            let batch = self.task.val_batch(i);
            total += self.model.eval_step(&self.params[0], &batch)? as f64;
        }
        Ok(total / batches.max(1) as f64)
    }

    /// Drift between node parameter replicas (max |θ_0 − θ_n|∞); zero for
    /// every-step schemes, bounded for DiLoCo between syncs.
    pub fn replica_drift(&self) -> f32 {
        let mut worst = 0.0f32;
        for n in 1..self.params.len() {
            for (a, b) in self.params[0].iter().zip(&self.params[n]) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }

    /// Wire stats of a hypothetical payload from rank 0's current state
    /// (used by the bandwidth figures without running a gather).
    pub fn probe_payload(&mut self) -> Option<WireStats> {
        let rctx = ReplCtx {
            step: self.step,
            shard: 0,
            seed: self.cfg.seed,
        };
        let st = &mut self.ranks[0];
        let mut buf = st.opt.buffer_mut().to_vec();
        let (_, p) = st.repl.extract(&rctx, &mut buf);
        p.map(|p| WireStats::of(&p))
    }

    /// Run the configured number of steps, collecting metrics.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let label = format!(
            "{}-{}-{}",
            self.cfg.model,
            self.cfg.opt.label(),
            self.cfg.repl.label()
        );
        let mut metrics = RunMetrics::new(label);
        for _ in 0..self.cfg.steps {
            let wall0 = Instant::now();
            let loss = self.step()?;
            let inter = self.traffic.inter_node_bytes();
            let intra = self.traffic.intra_node_bytes();
            metrics.steps.push(StepRow {
                step: self.step - 1,
                sim_time: self.clock.now(),
                loss,
                inter_bytes: inter - self.last_inter,
                intra_bytes: intra - self.last_intra,
                wall_time: wall0.elapsed().as_secs_f64(),
            });
            self.last_inter = inter;
            self.last_intra = intra;

            if self.cfg.val_every > 0 && self.step % self.cfg.val_every == 0 {
                let vloss = self.validate(self.cfg.val_batches)?;
                log::info!(
                    "step {:>5}  loss {:.4}  val {:.4}  sim {}",
                    self.step,
                    loss,
                    vloss,
                    crate::util::fmt_secs(self.clock.now())
                );
                metrics.val.push(ValRow {
                    step: self.step,
                    sim_time: self.clock.now(),
                    loss: vloss,
                });
            } else if self.step % 50 == 0 {
                log::debug!("step {:>5}  loss {loss:.4}", self.step);
            }
        }
        Ok(metrics)
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Parameters of node 0 (inspection / examples).
    pub fn params_node0(&self) -> &[f32] {
        &self.params[0]
    }
}
