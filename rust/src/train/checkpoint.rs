//! Checkpoint/restore of the full trainer state (elastic membership's
//! crash-recovery half).
//!
//! A checkpoint is a single versioned binary file capturing everything a
//! run needs to continue **bit-identically**: the per-node parameter
//! replicas, every rank's optimizer moments ([`OptState`]), replicator
//! accumulators ([`ReplState`], including an async gather in flight at
//! the snapshot), carried late deltas, the parked [`PendingSync`]
//! windows, the discrete-event engine's lanes ([`EngineState`]), the
//! traffic matrix, and the step cursor. Data streams and the membership
//! timeline are derived from `(config, step)`, so no RNG state needs to
//! be stored — the config *fingerprint* is embedded instead and restores
//! onto a mismatched experiment are rejected with both strings shown.
//!
//! The encoding is deliberately boring: little-endian fixed-width
//! primitives behind tiny bounds-checked writer/reader helpers (floats
//! travel as raw IEEE bits — quantized payload values must not be
//! re-quantized on the way back in). Version 2 appends a CRC-32 of
//! everything before it, verified up front at decode, so a truncated or
//! bit-flipped file is rejected with one actionable error instead of a
//! parse failure deep in the body; version 3 adds each parked window's
//! per-member peer sets (the sync-topology selection the window was
//! launched under) and folds the topology into the config fingerprint;
//! version 4 adds the payload `sel` rate hint and the adaptive rate
//! controller's mid-window state ([`ControlState`]), with the control
//! spec folded into the fingerprint. Saves are atomic
//! ([`crate::util::atomic_write`]: temp file + rename), so a crash
//! mid-save never corrupts the previous checkpoint — which is exactly
//! the file a crashed node's rejoin reads
//! ([`Trainer::restore_node_from_checkpoint`]).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::compress::Payload;
use crate::config::ExperimentConfig;
use crate::net::SimTime;
use crate::optim::OptState;
use crate::replicate::control::ControlState;
use crate::replicate::ReplState;
use crate::tensor::Dtype;

use super::engine::EngineState;
use super::{PendingSync, Trainer};

const MAGIC: &[u8; 8] = b"DTNCKPT1";
const VERSION: u32 = 4;

/// The config facets a checkpoint must agree on to be restorable: the
/// state vectors below are only meaningful on the same model/mesh/
/// optimizer/replicator/seed/schedule.
fn fingerprint(cfg: &ExperimentConfig) -> String {
    format!(
        "{}|{}x{}|{}|{}|topo={}|ctl={}|seed={}|steps={}|lr={}",
        cfg.model,
        cfg.nodes,
        cfg.accels_per_node,
        cfg.opt.label(),
        cfg.repl.label(),
        cfg.topology.label(),
        cfg.compress_control.label(),
        cfg.seed,
        cfg.steps,
        cfg.lr,
    )
}

// ---------------------------------------------------------------------
// little-endian writer / bounds-checked reader

struct W {
    buf: Vec<u8>,
}

impl W {
    fn new() -> W {
        W { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn len(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn boolean(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn string(&mut self, s: &str) {
        self.len(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.len(v.len());
        for &x in v {
            self.u32(x.to_bits());
        }
    }

    fn u32s(&mut self, v: &[u32]) {
        self.len(v.len());
        for &x in v {
            self.u32(x);
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.len(v.len());
        for &x in v {
            self.u64(x);
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.len(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    fn bools(&mut self, v: &[bool]) {
        self.len(v.len());
        for &x in v {
            self.boolean(x);
        }
    }
}

struct R<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.b.len() - self.pos,
            "checkpoint truncated at byte {} ({} more wanted, {} left)",
            self.pos,
            n,
            self.b.len() - self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Element count followed by `elem_bytes`-sized elements: the count
    /// is validated against the bytes actually left, so a corrupt length
    /// field errors instead of attempting a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        anyhow::ensure!(
            n.saturating_mul(elem_bytes) <= self.b.len() - self.pos,
            "checkpoint corrupt: length {n} at byte {} exceeds the {} bytes left",
            self.pos - 8,
            self.b.len() - self.pos
        );
        Ok(n)
    }

    fn boolean(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.count(1)?;
        String::from_utf8(self.take(n)?.to_vec()).context("checkpoint string not utf-8")
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        (0..n).map(|_| Ok(f32::from_bits(self.u32()?))).collect()
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.count(1)?;
        (0..n).map(|_| self.boolean()).collect()
    }

    fn done(&self) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.b.len(),
            "checkpoint has {} trailing bytes",
            self.b.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// component codecs

fn write_payload(w: &mut W, p: &Payload) {
    match &p.indices {
        None => w.boolean(false),
        Some(ix) => {
            w.boolean(true);
            w.u32s(ix);
        }
    }
    w.f32s(&p.values);
    w.u8(match p.dtype {
        Dtype::F32 => 0,
        Dtype::Bf16 => 1,
        Dtype::F16 => 2,
    });
    w.boolean(p.sign);
    w.boolean(p.packed);
    match p.sel {
        None => w.boolean(false),
        Some(s) => {
            w.boolean(true);
            w.u32(s);
        }
    }
}

fn read_payload(r: &mut R) -> Result<Payload> {
    let indices = if r.boolean()? { Some(r.u32s()?) } else { None };
    let values = r.f32s()?;
    let dtype = match r.u8()? {
        0 => Dtype::F32,
        1 => Dtype::Bf16,
        2 => Dtype::F16,
        t => anyhow::bail!("checkpoint payload has unknown dtype tag {t}"),
    };
    let sign = r.boolean()?;
    let packed = r.boolean()?;
    let sel = if r.boolean()? { Some(r.u32()?) } else { None };
    // Field-literal reconstruction: the stored values already went
    // through sign/dtype quantization at extraction time, and
    // `Payload::new` would run that pass again.
    Ok(Payload {
        indices,
        values,
        dtype,
        sign,
        packed,
        sel,
    })
}

fn write_opt_state(w: &mut W, st: &OptState) {
    w.len(st.vecs.len());
    for v in &st.vecs {
        w.f32s(v);
    }
    w.u64(st.t);
}

fn read_opt_state(r: &mut R) -> Result<OptState> {
    let n = r.count(8)?;
    let vecs = (0..n).map(|_| r.f32s()).collect::<Result<Vec<_>>>()?;
    let t = r.u64()?;
    Ok(OptState { vecs, t })
}

fn write_repl_state(w: &mut W, st: &ReplState) {
    w.f32s(&st.delta_acc);
    match &st.in_flight {
        None => w.boolean(false),
        Some(v) => {
            w.boolean(true);
            w.f32s(v);
        }
    }
}

fn read_repl_state(r: &mut R) -> Result<ReplState> {
    let delta_acc = r.f32s()?;
    let in_flight = if r.boolean()? { Some(r.f32s()?) } else { None };
    Ok(ReplState {
        delta_acc,
        in_flight,
    })
}

fn write_control_state(w: &mut W, st: &ControlState) {
    w.f64s(&st.rates);
    w.f64(st.exposed_acc);
    w.f64(st.sim0);
    w.f64s(&st.busy0);
}

fn read_control_state(r: &mut R) -> Result<ControlState> {
    Ok(ControlState {
        rates: r.f64s()?,
        exposed_acc: r.f64()?,
        sim0: r.f64()?,
        busy0: r.f64s()?,
    })
}

fn write_carried(w: &mut W, carried: &[(Payload, SimTime)]) {
    w.len(carried.len());
    for (p, end) in carried {
        write_payload(w, p);
        w.f64(*end);
    }
}

fn read_carried(r: &mut R) -> Result<Vec<(Payload, SimTime)>> {
    let n = r.count(8)?;
    (0..n).map(|_| Ok((read_payload(r)?, r.f64()?))).collect()
}

fn write_pending(w: &mut W, slot: &Option<PendingSync>) {
    match slot {
        None => w.u8(0),
        Some(PendingSync::Uniform { arrival, payloads }) => {
            w.u8(1);
            w.u64(*arrival);
            w.len(payloads.len());
            for p in payloads {
                write_payload(w, p);
            }
        }
        Some(PendingSync::PerNode {
            group,
            payloads,
            contrib_end,
            arrival,
            applied,
            peers,
        }) => {
            w.u8(2);
            w.u64s(&group.iter().map(|&r| r as u64).collect::<Vec<u64>>());
            w.len(payloads.len());
            for p in payloads {
                write_payload(w, p);
            }
            w.f64s(contrib_end);
            w.u64s(arrival);
            w.bools(applied);
            w.len(peers.len());
            for p in peers {
                w.u64s(&p.iter().map(|&j| j as u64).collect::<Vec<u64>>());
            }
        }
    }
}

fn read_pending(r: &mut R, world: usize) -> Result<Option<PendingSync>> {
    match r.u8()? {
        0 => Ok(None),
        1 => {
            let arrival = r.u64()?;
            let n = r.count(8)?;
            let payloads = (0..n).map(|_| read_payload(r)).collect::<Result<Vec<_>>>()?;
            Ok(Some(PendingSync::Uniform { arrival, payloads }))
        }
        2 => {
            let group: Vec<usize> = r.u64s()?.into_iter().map(|x| x as usize).collect();
            anyhow::ensure!(
                group.iter().all(|&rank| rank < world),
                "checkpoint pending window names a rank outside world size {world}"
            );
            let n = r.count(8)?;
            let payloads = (0..n).map(|_| read_payload(r)).collect::<Result<Vec<_>>>()?;
            let contrib_end = r.f64s()?;
            let arrival = r.u64s()?;
            let applied = r.bools()?;
            let np = r.count(8)?;
            let peers = (0..np)
                .map(|_| Ok(r.u64s()?.into_iter().map(|x| x as usize).collect::<Vec<usize>>()))
                .collect::<Result<Vec<_>>>()?;
            let g = group.len();
            anyhow::ensure!(
                payloads.len() == g
                    && contrib_end.len() == g
                    && arrival.len() == g
                    && applied.len() == g
                    && peers.len() == g,
                "checkpoint pending window has inconsistent member counts"
            );
            anyhow::ensure!(
                peers.iter().all(|p| p.iter().all(|&j| j < g)),
                "checkpoint pending window peer set names a member outside the group"
            );
            Ok(Some(PendingSync::PerNode {
                group,
                payloads,
                contrib_end,
                arrival,
                applied,
                peers,
            }))
        }
        t => anyhow::bail!("checkpoint pending slot has unknown tag {t}"),
    }
}

fn write_engine_state(w: &mut W, st: &EngineState) {
    for lane in [&st.compute, &st.fabric, &st.nic] {
        w.f64s(&lane.0);
        w.f64s(&lane.1);
    }
    w.f64s(&st.update_visible);
    w.f64s(&st.deferred_end);
    w.f64s(&st.rs_done);
    w.f64s(&st.bwd_start);
    w.f64s(&st.bwd_end);
    w.f64(st.serialized);
    w.u64(st.next_event_id);
}

fn read_engine_state(r: &mut R) -> Result<EngineState> {
    let mut lanes = Vec::with_capacity(3);
    for _ in 0..3 {
        let ready = r.f64s()?;
        let busy = r.f64s()?;
        lanes.push((ready, busy));
    }
    let nic = lanes.pop().unwrap();
    let fabric = lanes.pop().unwrap();
    let compute = lanes.pop().unwrap();
    Ok(EngineState {
        compute,
        fabric,
        nic,
        update_visible: r.f64s()?,
        deferred_end: r.f64s()?,
        rs_done: r.f64s()?,
        bwd_start: r.f64s()?,
        bwd_end: r.f64s()?,
        serialized: r.f64()?,
        next_event_id: r.u64()?,
    })
}

/// A fully-decoded checkpoint, ready to apply (wholesale or per node).
struct CkptData {
    step: u64,
    active: Vec<bool>,
    crashed: Vec<bool>,
    params: Vec<Vec<f32>>,
    /// Per rank: optimizer, replicator, carried late deltas.
    ranks: Vec<(OptState, ReplState, Vec<(Payload, SimTime)>)>,
    pending: Vec<Option<PendingSync>>,
    engine: EngineState,
    traffic: Vec<u64>,
    last_inter: u64,
    last_intra: u64,
    /// Rate-controller snapshot (`Some` iff the run was controller-on;
    /// the fingerprint's `ctl=` facet already pins the spec).
    control: Option<ControlState>,
}

fn decode(bytes: &[u8], expect_fp: &str, world: usize) -> Result<CkptData> {
    let mut r = R::new(bytes);
    let magic = r.take(MAGIC.len())?;
    anyhow::ensure!(
        magic == MAGIC,
        "not a detonation checkpoint (bad magic {magic:?})"
    );
    let version = r.u32()?;
    anyhow::ensure!(
        version == VERSION,
        "checkpoint version {version} not supported (this build reads {VERSION})"
    );
    // Magic + version parse first so a genuinely-old file gets the
    // version error above; everything after them is only trusted once
    // the trailing CRC-32 (over all preceding bytes) checks out.
    anyhow::ensure!(
        bytes.len() >= r.pos + 4,
        "checkpoint truncated: no room for the trailing CRC-32"
    );
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    let computed = crate::util::crc32(body);
    anyhow::ensure!(
        stored == computed,
        "checkpoint corrupt or truncated: CRC-32 mismatch (file says \
         {stored:#010x}, contents hash to {computed:#010x}) — the file \
         was damaged after it was written; restore from an older \
         checkpoint or re-copy it"
    );
    let mut r = R { b: body, pos: r.pos };
    let fp = r.string()?;
    anyhow::ensure!(
        fp == expect_fp,
        "checkpoint was written by a different experiment:\n  checkpoint: {fp}\n  current:    {expect_fp}"
    );
    let step = r.u64()?;
    let active = r.bools()?;
    let crashed = r.bools()?;
    let n_params = r.count(8)?;
    let params = (0..n_params).map(|_| r.f32s()).collect::<Result<Vec<_>>>()?;
    let n_ranks = r.count(8)?;
    let ranks = (0..n_ranks)
        .map(|_| Ok((read_opt_state(&mut r)?, read_repl_state(&mut r)?, read_carried(&mut r)?)))
        .collect::<Result<Vec<_>>>()?;
    let n_pending = r.count(1)?;
    let pending = (0..n_pending)
        .map(|_| read_pending(&mut r, world))
        .collect::<Result<Vec<_>>>()?;
    let engine = read_engine_state(&mut r)?;
    let traffic = r.u64s()?;
    let last_inter = r.u64()?;
    let last_intra = r.u64()?;
    let control = if r.boolean()? {
        Some(read_control_state(&mut r)?)
    } else {
        None
    };
    r.done()?;
    Ok(CkptData {
        step,
        active,
        crashed,
        params,
        ranks,
        pending,
        engine,
        traffic,
        last_inter,
        last_intra,
        control,
    })
}

impl Trainer {
    /// Serialize the full trainer state into `dir/latest.ckpt`
    /// (atomically: temp file + rename), with a trailing CRC-32 over
    /// the whole encoding. Returns the written path.
    pub fn save_checkpoint(&self, dir: &Path) -> Result<PathBuf> {
        let mut w = W::new();
        w.buf.extend_from_slice(MAGIC);
        w.u32(VERSION);
        w.string(&fingerprint(&self.cfg));
        w.u64(self.step);
        w.bools(&self.active);
        w.bools(&self.crashed);
        w.len(self.params.len());
        for p in &self.params {
            w.f32s(p);
        }
        w.len(self.ranks.len());
        for st in &self.ranks {
            write_opt_state(&mut w, &st.opt.export_state());
            write_repl_state(&mut w, &st.repl.export_state());
            write_carried(&mut w, &st.carried);
        }
        w.len(self.pending.len());
        for slot in &self.pending {
            write_pending(&mut w, slot);
        }
        write_engine_state(&mut w, &self.engine.export_state());
        w.u64s(&self.traffic.snapshot());
        w.u64(self.last_inter);
        w.u64(self.last_intra);
        match &self.controller {
            None => w.boolean(false),
            Some(c) => {
                w.boolean(true);
                write_control_state(&mut w, &c.export_state());
            }
        }
        let crc = crate::util::crc32(&w.buf);
        w.u32(crc);

        let path = dir.join("latest.ckpt");
        crate::util::atomic_write(&path, &w.buf)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(path)
    }

    /// Restore the **whole** trainer from a [`Trainer::save_checkpoint`]
    /// file: params, every rank's optimizer/replicator state, carried
    /// deltas, parked sync windows, engine lanes, traffic, and the step
    /// cursor. Continuation is bit-identical to the uninterrupted run
    /// (prop-tested in the integration suite). The trainer must have
    /// been built from the same config (fingerprint-checked).
    pub fn restore_checkpoint(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let world = self.mesh.topo.world_size();
        let data = decode(&bytes, &fingerprint(&self.cfg), world)
            .with_context(|| format!("restoring checkpoint {}", path.display()))?;
        anyhow::ensure!(
            data.active.len() == self.cfg.nodes && data.crashed.len() == self.cfg.nodes,
            "checkpoint membership masks cover {} nodes, cluster has {}",
            data.active.len(),
            self.cfg.nodes
        );
        anyhow::ensure!(
            data.params.len() == self.params.len(),
            "checkpoint has {} parameter replicas, trainer has {}",
            data.params.len(),
            self.params.len()
        );
        for (i, p) in data.params.iter().enumerate() {
            anyhow::ensure!(
                p.len() == self.params[i].len(),
                "checkpoint replica {i} has {} params, trainer has {}",
                p.len(),
                self.params[i].len()
            );
        }
        anyhow::ensure!(
            data.ranks.len() == self.ranks.len(),
            "checkpoint covers {} ranks, trainer has {}",
            data.ranks.len(),
            self.ranks.len()
        );
        anyhow::ensure!(
            data.pending.len() == self.pending.len(),
            "checkpoint has {} pending slots, trainer has {}",
            data.pending.len(),
            self.pending.len()
        );
        for (i, (opt, repl, carried)) in data.ranks.into_iter().enumerate() {
            let st = &mut self.ranks[i];
            st.opt
                .import_state(opt)
                .with_context(|| format!("rank {i} optimizer"))?;
            st.repl
                .import_state(repl)
                .with_context(|| format!("rank {i} replicator"))?;
            st.carried = carried;
        }
        self.params = data.params;
        self.pending = data.pending;
        self.engine.import_state(data.engine)?;
        self.traffic.restore(&data.traffic)?;
        self.step = data.step;
        self.active = data.active;
        self.crashed = data.crashed;
        self.engine.set_active(&self.active);
        self.last_inter = data.last_inter;
        self.last_intra = data.last_intra;
        // The fingerprint's `ctl=` facet guarantees both sides agree on
        // off vs aimd, so this match never crosses. Restored rates are
        // pushed back into every rank's replicator — the snapshot was
        // taken mid-window, possibly after retunes.
        let expects = self.controller.is_some();
        match (data.control, self.controller.as_mut()) {
            (None, None) => {}
            (Some(st), Some(ctl)) => {
                ctl.import_state(st)?;
                for r in 0..world {
                    let rate = ctl.rates()[self.mesh.topo.node_of(r)];
                    self.ranks[r].repl.set_rate(rate);
                }
                self.rate_label = ctl.label();
            }
            (have, _) => anyhow::bail!(
                "checkpoint {} a rate-controller snapshot but this run {} one",
                if have.is_some() { "carries" } else { "lacks" },
                if expects { "expects" } else { "does not run" }
            ),
        }
        Ok(())
    }

    /// Restore **one node's** rank-local state (optimizer moments,
    /// replicator accumulators, carried deltas) from a checkpoint — the
    /// crashed-node rejoin path. Parameters are *not* taken from the
    /// file: a rejoining node receives the cluster's current params via
    /// the node-0 join broadcast; only its private state comes off its
    /// own disk.
    pub fn restore_node_from_checkpoint(&mut self, node: usize, path: &Path) -> Result<()> {
        anyhow::ensure!(node < self.cfg.nodes, "node {node} out of range");
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let world = self.mesh.topo.world_size();
        let data = decode(&bytes, &fingerprint(&self.cfg), world)
            .with_context(|| format!("restoring node {node} from {}", path.display()))?;
        anyhow::ensure!(
            data.ranks.len() == self.ranks.len(),
            "checkpoint covers {} ranks, trainer has {}",
            data.ranks.len(),
            self.ranks.len()
        );
        for (i, (opt, repl, carried)) in data.ranks.into_iter().enumerate() {
            if self.mesh.topo.node_of(i) != node {
                continue;
            }
            let st = &mut self.ranks[i];
            st.opt
                .import_state(opt)
                .with_context(|| format!("rank {i} optimizer"))?;
            st.repl
                .import_state(repl)
                .with_context(|| format!("rank {i} replicator"))?;
            st.carried = carried;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_codec_roundtrip() {
        let mut w = W::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f64(-0.0);
        w.boolean(true);
        w.string("fingerprint|2x2");
        w.f32s(&[1.5, -0.0, f32::MIN_POSITIVE]);
        w.u32s(&[0, 1, u32::MAX]);
        w.u64s(&[42]);
        w.f64s(&[]);
        w.bools(&[true, false, true]);
        let mut r = R::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.boolean().unwrap());
        assert_eq!(r.string().unwrap(), "fingerprint|2x2");
        let f = r.f32s().unwrap();
        assert_eq!(f.len(), 3);
        assert_eq!(f[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.u32s().unwrap(), vec![0, 1, u32::MAX]);
        assert_eq!(r.u64s().unwrap(), vec![42]);
        assert!(r.f64s().unwrap().is_empty());
        assert_eq!(r.bools().unwrap(), vec![true, false, true]);
        r.done().unwrap();
        // truncation and corrupt lengths error instead of panicking
        let mut t = R::new(&w.buf[..3]);
        assert!(t.u32().is_err());
        let mut w2 = W::new();
        w2.u64(u64::MAX); // absurd element count
        assert!(R::new(&w2.buf).f32s().is_err());
    }

    #[test]
    fn payload_roundtrip_preserves_bits_without_requantizing() {
        // A packed sign payload and a dense bf16 payload survive exactly.
        let p1 = Payload::new(Some(vec![3, 9, 11]), vec![0.5, -2.0, 0.0], Dtype::F32, true)
            .with_packing();
        let p2 = Payload::new(None, vec![1.0 + 1e-3, -7.25], Dtype::Bf16, false);
        // An adaptive-striding payload carries its stride as a sel hint.
        let p3 = Payload::new(None, vec![0.5, 0.25], Dtype::F32, false).with_sel(16);
        for p in [&p1, &p2, &p3] {
            let mut w = W::new();
            write_payload(&mut w, p);
            let mut r = R::new(&w.buf);
            let q = read_payload(&mut r).unwrap();
            r.done().unwrap();
            assert_eq!(q.indices, p.indices);
            assert_eq!(
                q.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(q.dtype, p.dtype);
            assert_eq!(q.sign, p.sign);
            assert_eq!(q.packed, p.packed);
            assert_eq!(q.sel, p.sel);
        }
    }

    #[test]
    fn control_state_roundtrip() {
        let st = ControlState {
            rates: vec![0.125, 0.03125],
            exposed_acc: 1.5,
            sim0: 9.0,
            busy0: vec![4.0, 2.0],
        };
        let mut w = W::new();
        write_control_state(&mut w, &st);
        let mut r = R::new(&w.buf);
        assert_eq!(read_control_state(&mut r).unwrap(), st);
        r.done().unwrap();
    }

    #[test]
    fn pending_window_roundtrip_and_rank_bounds() {
        let mk_payload = || Payload::new(None, vec![1.0, -1.0], Dtype::F32, false);
        let slot = Some(PendingSync::PerNode {
            group: vec![0, 2],
            payloads: vec![mk_payload(), mk_payload()],
            contrib_end: vec![0.25, 1.5],
            arrival: vec![4, 6],
            applied: vec![true, false],
            peers: vec![vec![1], vec![0]],
        });
        let mut w = W::new();
        write_pending(&mut w, &slot);
        write_pending(&mut w, &None);
        write_pending(
            &mut w,
            &Some(PendingSync::Uniform {
                arrival: 9,
                payloads: vec![mk_payload()],
            }),
        );
        let mut r = R::new(&w.buf);
        match read_pending(&mut r, 4).unwrap() {
            Some(PendingSync::PerNode {
                group,
                contrib_end,
                arrival,
                applied,
                payloads,
                peers,
            }) => {
                assert_eq!(group, vec![0, 2]);
                assert_eq!(contrib_end, vec![0.25, 1.5]);
                assert_eq!(arrival, vec![4, 6]);
                assert_eq!(applied, vec![true, false]);
                assert_eq!(payloads.len(), 2);
                assert_eq!(peers, vec![vec![1], vec![0]]);
            }
            other => panic!("wrong variant: {:?}", other.is_some()),
        }
        assert!(read_pending(&mut r, 4).unwrap().is_none());
        assert!(matches!(
            read_pending(&mut r, 4).unwrap(),
            Some(PendingSync::Uniform { arrival: 9, .. })
        ));
        r.done().unwrap();
        // a window naming rank 2 is rejected in a 2-rank world
        let mut w2 = W::new();
        write_pending(&mut w2, &slot);
        assert!(read_pending(&mut R::new(&w2.buf), 2).is_err());
    }

    #[test]
    fn opt_and_repl_state_roundtrip() {
        let opt = OptState {
            vecs: vec![vec![1.0, 2.0], vec![], vec![-0.5]],
            t: 77,
        };
        let repl = ReplState {
            delta_acc: vec![0.125; 4],
            in_flight: Some(vec![9.0; 4]),
        };
        let mut w = W::new();
        write_opt_state(&mut w, &opt);
        write_repl_state(&mut w, &repl);
        write_repl_state(&mut w, &ReplState::default());
        let mut r = R::new(&w.buf);
        assert_eq!(read_opt_state(&mut r).unwrap(), opt);
        assert_eq!(read_repl_state(&mut r).unwrap(), repl);
        assert_eq!(read_repl_state(&mut r).unwrap(), ReplState::default());
        r.done().unwrap();
    }
}
