//! The discrete-event overlap engine — the simulator's spine.
//!
//! Each rank owns two resource lanes: a **compute** lane (the accelerator)
//! and a **NIC** lane. A training step is a DAG of reservations on those
//! lanes; [`StepEngine`] schedules them and the step's duration is
//! whatever the critical path says, instead of the old barrier-synchronous
//! sum of phase maxima.
//!
//! ## Dependency model (one FlexDeMo step)
//!
//! ```text
//! compute lane:   fwd(t) ──────────── bwd(t) ─────────────── fwd(t+1) …
//!                  │  (no comm dep:     ▲ needs update(t-1)
//!                  │   stale-params     │ visible = unshard end)
//! NIC lane:        │   pipelining)      │
//!   unshard(t) ────┘  [≥ gather(t-1)]───┘
//!   reduce-scatter(t)  [starts with bwd(t), ends ≥ bwd(t) end]
//!   gather(t)          [after reduce-scatter(t); overlaps fwd(t+1)]
//! ```
//!
//! * the **replication gather** of step *t* overlaps the next step's
//!   forward: the forward runs on parameters that receive the averaged
//!   update when the gather lands (DeMo's async `dist.all_gather`
//!   decoupling), and only the next *backward* requires the update to be
//!   visible;
//! * the **intra-node reduce-scatter** streams gradient buckets while the
//!   backward produces them: it may start with the backward but cannot
//!   finish before it;
//! * the **unshard all-gather** (phase 0) rides the NIC after the gather
//!   and likewise only gates the next backward.
//!
//! ## `--no-overlap` parity
//!
//! In serialized mode every phase is fenced by a global barrier and the
//! engine reproduces the legacy `SimClock` arithmetic *bit-for-bit*: the
//! horizon advances by (unshard + compute + max reduce-scatter +
//! max gather) per step, in that order, using the same duration formulas
//! (they live in `collectives::*_event`, shared by both paths). The
//! `serialized_time()` accumulator tracks that sum in *both* modes, so
//! `now() == serialized_time()` under `--no-overlap` and
//! `now() ≤ serialized_time()` with overlap on — both are asserted in the
//! integration tests.
//!
//! ## Scenario knobs
//!
//! [`ClusterModel`] supplies per-node straggler slowdowns (scaling that
//! node's compute reservations) and per-node NIC bandwidth overrides
//! (a replication group's link runs at its slowest member NIC).

use crate::collectives::{ring_all_gather_event, ring_reduce_scatter_event, CommEvent, Link};
use crate::net::{ClusterModel, LinkClass, NetModel, SimTime, Timeline, Topology, TrafficMatrix};
use crate::replicate::GatherMode;

/// Fraction of a step's compute spent in the forward pass (fwd:bwd ≈ 1:2,
/// the standard transformer estimate).
pub const FWD_FRACTION: f64 = 1.0 / 3.0;

/// Per-step timing summary for metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Global sim-time horizon after the step.
    pub sim_time: SimTime,
    /// Critical rank's compute busy-time this step.
    pub compute_time: f64,
    /// Communication the critical rank could not hide behind compute.
    pub exposed_comm: f64,
    /// Communication the critical rank overlapped with compute.
    pub hidden_comm: f64,
}

pub struct StepEngine {
    topo: Topology,
    net: NetModel,
    cluster: ClusterModel,
    overlap: bool,
    /// One lane per rank on each resource.
    compute: Timeline,
    nic: Timeline,
    /// When rank r's parameters carry the latest optimizer update
    /// (gather/unshard landing time) — the next backward's dependency.
    update_visible: Vec<SimTime>,
    /// End of this step's reduce-scatter per rank (gather dependency).
    rs_done: Vec<SimTime>,
    bwd_start: Vec<SimTime>,
    bwd_end: Vec<SimTime>,
    /// What the legacy barrier-synchronous clock would read.
    serialized: SimTime,
    /// Scheduled events of the current/last step (debug + tests).
    pub events: Vec<CommEvent>,
    next_event_id: u64,
    last_nic_event: Vec<Option<u64>>,
    // per-step bookkeeping
    step_start_horizon: SimTime,
    step_compute_busy0: Vec<f64>,
    step_nic_busy0: Vec<f64>,
    step_gather_max: f64,
    gather_phase_start: Option<SimTime>,
}

impl StepEngine {
    pub fn new(topo: Topology, net: NetModel, cluster: ClusterModel, overlap: bool) -> StepEngine {
        let world = topo.world_size();
        StepEngine {
            topo,
            net,
            cluster,
            overlap,
            compute: Timeline::new(world),
            nic: Timeline::new(world),
            update_visible: vec![0.0; world],
            rs_done: vec![0.0; world],
            bwd_start: vec![0.0; world],
            bwd_end: vec![0.0; world],
            serialized: 0.0,
            events: Vec::new(),
            next_event_id: 0,
            last_nic_event: vec![None; world],
            step_start_horizon: 0.0,
            step_compute_busy0: vec![0.0; world],
            step_nic_busy0: vec![0.0; world],
            step_gather_max: 0.0,
            gather_phase_start: None,
        }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Global sim-time horizon (latest lane across both resources).
    pub fn now(&self) -> SimTime {
        self.compute.horizon().max(self.nic.horizon())
    }

    /// What the legacy barrier clock would read for the same run — equals
    /// `now()` under `--no-overlap`, upper-bounds it with overlap on.
    pub fn serialized_time(&self) -> SimTime {
        self.serialized
    }

    /// Latest lane end of one rank.
    pub fn rank_end(&self, rank: usize) -> SimTime {
        self.compute.now(rank).max(self.nic.now(rank))
    }

    /// The rank on the step's critical path: latest end, ties broken by
    /// compute busy-time (so a barrier-fenced straggler still wins).
    pub fn critical_rank(&self) -> usize {
        let mut best = 0usize;
        for r in 1..self.topo.world_size() {
            let (e, b) = (self.rank_end(r), self.compute.busy(r));
            let (be, bb) = (self.rank_end(best), self.compute.busy(best));
            if e > be || (e == be && b > bb) {
                best = r;
            }
        }
        best
    }

    /// Per-rank compute/NIC timelines (read-only; invariants tested).
    pub fn timelines(&self) -> (&Timeline, &Timeline) {
        (&self.compute, &self.nic)
    }

    fn world(&self) -> usize {
        self.topo.world_size()
    }

    /// Fence every lane at the current horizon (serialized mode only).
    fn barrier(&mut self) -> SimTime {
        let h = self.now();
        for r in 0..self.world() {
            self.compute.stall_until(r, h);
            self.nic.stall_until(r, h);
        }
        h
    }

    fn push_event(&mut self, mut ev: CommEvent, members: &[usize]) -> u64 {
        let id = self.next_event_id;
        self.next_event_id += 1;
        ev.id = id;
        for &r in members {
            self.last_nic_event[r] = Some(id);
        }
        self.events.push(ev);
        id
    }

    fn nic_deps(&self, members: &[usize]) -> Vec<u64> {
        let mut deps: Vec<u64> = members
            .iter()
            .filter_map(|&r| self.last_nic_event[r])
            .collect();
        deps.sort_unstable();
        deps.dedup();
        deps
    }

    pub fn begin_step(&mut self) {
        self.events.clear();
        self.step_gather_max = 0.0;
        self.gather_phase_start = None;
        self.step_start_horizon = self.now();
        for r in 0..self.world() {
            self.step_compute_busy0[r] = self.compute.busy(r);
            self.step_nic_busy0[r] = self.nic.busy(r);
        }
    }

    /// Phase 0: intra-node all-gather that unshards the updated parameters
    /// (per node group). Records the phase's intra-node traffic — this is
    /// where the old trainer's hand-rolled unshard accounting now lives.
    pub fn unshard(&mut self, shard_bytes: u64, traffic: &TrafficMatrix) {
        let accels = self.topo.accels_per_node;
        if accels <= 1 {
            return;
        }
        for node in 0..self.topo.nodes {
            traffic.record(node, node, (accels - 1) as u64 * shard_bytes * accels as u64);
        }
        let link = Link::of(&self.net, LinkClass::IntraNode);
        let proto = ring_all_gather_event(&link, accels, shard_bytes);
        let dur = proto.duration;
        if !self.overlap {
            let h = self.barrier();
            for node in 0..self.topo.nodes {
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                for &r in &members {
                    self.nic.reserve(r, h, dur);
                    self.update_visible[r] = h + dur;
                }
                self.push_event(proto.clone().scheduled(h, Vec::new()), &members);
            }
        } else {
            for node in 0..self.topo.nodes {
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                let earliest = members
                    .iter()
                    .fold(0.0f64, |m, &r| m.max(self.update_visible[r]));
                let start = earliest.max(self.nic.join(&members));
                let deps = self.nic_deps(&members);
                for &r in &members {
                    self.nic.reserve(r, start, dur);
                    self.update_visible[r] = start + dur;
                }
                self.push_event(proto.clone().scheduled(start, deps), &members);
            }
        }
        self.serialized += dur;
    }

    /// Phase 1: fwd+bwd on every rank. The forward has no communication
    /// dependency (stale-params pipelining); the backward waits until the
    /// previous step's update is visible on this rank.
    pub fn compute(&mut self, flops: f64) {
        let ct = self.net.compute_time(flops);
        let mut dmax = 0.0f64;
        if !self.overlap {
            let h = self.barrier();
            for r in 0..self.world() {
                let tc = ct * self.cluster.slowdown_of(self.topo.node_of(r));
                // Unsplit in serialized mode so the lane end is exactly
                // h + tc (bit-parity with the legacy clock).
                let (start, end) = self.compute.reserve(r, h, tc);
                self.bwd_start[r] = start;
                self.bwd_end[r] = end;
                dmax = dmax.max(tc);
            }
        } else {
            for r in 0..self.world() {
                let tc = ct * self.cluster.slowdown_of(self.topo.node_of(r));
                let tf = tc * FWD_FRACTION;
                let tb = tc - tf;
                self.compute.reserve(r, 0.0, tf);
                let (bs, be) = self.compute.reserve(r, self.update_visible[r], tb);
                self.bwd_start[r] = bs;
                self.bwd_end[r] = be;
                dmax = dmax.max(tc);
            }
        }
        self.serialized += dmax;
    }

    /// Phase 2: intra-node ring reduce-scatter of the gradients. Streams
    /// behind the backward: may start with it, cannot finish before it.
    pub fn reduce_scatter(&mut self, max_shard_bytes: u64) {
        let accels = self.topo.accels_per_node;
        if accels <= 1 {
            // No reduction needed; the local update is ready when the
            // backward is.
            for r in 0..self.world() {
                self.rs_done[r] = self.bwd_end[r];
                self.update_visible[r] = self.bwd_end[r];
            }
            self.serialized += 0.0;
            return;
        }
        let link = Link::of(&self.net, LinkClass::IntraNode);
        let proto = ring_reduce_scatter_event(&link, accels, max_shard_bytes);
        let dur = proto.duration;
        if !self.overlap {
            let h = self.barrier();
            for node in 0..self.topo.nodes {
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                for &r in &members {
                    self.nic.reserve(r, h, dur);
                    self.rs_done[r] = h + dur;
                    self.update_visible[r] = h + dur;
                }
                self.push_event(proto.clone().scheduled(h, Vec::new()), &members);
            }
        } else {
            for node in 0..self.topo.nodes {
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                let bwd_start_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_start[r]));
                let bwd_end_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_end[r]));
                let start = self.nic.join(&members).max(bwd_start_max);
                let fin = (start + dur).max(bwd_end_max);
                let deps = self.nic_deps(&members);
                for &r in &members {
                    self.nic.reserve(r, start, dur);
                    // the last gradient bucket lands only when bwd ends
                    self.nic.stall_until(r, fin);
                    self.rs_done[r] = fin;
                    self.update_visible[r] = fin;
                }
                self.push_event(proto.clone().scheduled(start, deps), &members);
            }
        }
        self.serialized += dur;
    }

    /// Phase 3/4: replication gather across one R-group (called once per
    /// shard that syncs this step). Overlaps the next step's forward; the
    /// group's inter-node link runs at its slowest member NIC.
    pub fn gather(
        &mut self,
        group: &[usize],
        mode: GatherMode,
        payload_bytes: &[u64],
        traffic: &TrafficMatrix,
    ) {
        let class = self.topo.group_link_class(group);
        let nodes: Vec<usize> = group.iter().map(|&r| self.topo.node_of(r)).collect();
        let link = Link {
            class,
            lat: self.net.lat(class),
            bw: self.cluster.group_bw(&self.net, class, &nodes),
        };
        let ev = mode.comm_event(&link, payload_bytes);
        mode.record_traffic(traffic, &self.topo, group, payload_bytes);
        let dur = ev.duration;
        self.step_gather_max = self.step_gather_max.max(dur);
        if !self.overlap {
            let h = match self.gather_phase_start {
                Some(h) => h,
                None => {
                    let h = self.barrier();
                    self.gather_phase_start = Some(h);
                    h
                }
            };
            for &r in group {
                self.nic.reserve(r, h, dur);
                self.update_visible[r] = h + dur;
            }
            self.push_event(ev.scheduled(h, Vec::new()), group);
        } else {
            let earliest = group.iter().fold(0.0f64, |m, &r| m.max(self.rs_done[r]));
            let start = self.nic.join(group).max(earliest);
            let deps = self.nic_deps(group);
            for &r in group {
                self.nic.reserve(r, start, dur);
                self.update_visible[r] = start + dur;
            }
            self.push_event(ev.scheduled(start, deps), group);
        }
    }

    /// Close the step: settle barriers (serialized mode), fold the gather
    /// phase into the serialized accumulator, and summarize timing.
    pub fn end_step(&mut self) -> StepTiming {
        self.serialized += self.step_gather_max;
        if !self.overlap {
            self.barrier();
        }
        let sim_time = self.now();
        let crit = self.critical_rank();
        let compute_time = self.compute.busy(crit) - self.step_compute_busy0[crit];
        let comm = self.nic.busy(crit) - self.step_nic_busy0[crit];
        let span = (sim_time - self.step_start_horizon).max(0.0);
        let exposed_comm = (span - compute_time).clamp(0.0, comm.max(0.0));
        let hidden_comm = (comm - exposed_comm).max(0.0);
        StepTiming {
            sim_time,
            compute_time,
            exposed_comm,
            hidden_comm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(nodes: usize, accels: usize, overlap: bool) -> StepEngine {
        StepEngine::new(
            Topology::new(nodes, accels),
            NetModel::hpc(),
            ClusterModel::uniform(),
            overlap,
        )
    }

    fn drive(e: &mut StepEngine, steps: usize, with_gather: bool) -> StepTiming {
        let topo = Topology::new(e.topo.nodes, e.topo.accels_per_node);
        let traffic = TrafficMatrix::new(topo.nodes);
        let mut last = StepTiming::default();
        for _ in 0..steps {
            e.begin_step();
            e.unshard(4096, &traffic);
            e.compute(1e9);
            e.reduce_scatter(4096);
            if with_gather {
                for a in 0..topo.accels_per_node {
                    let group: Vec<usize> = (0..topo.nodes).map(|n| topo.rank(n, a)).collect();
                    let sizes = vec![2048u64; group.len()];
                    e.gather(&group, GatherMode::NaiveAllGather, &sizes, &traffic);
                }
            }
            last = e.end_step();
        }
        last
    }

    #[test]
    fn serialized_now_equals_serialized_accumulator() {
        let mut e = engine(2, 2, false);
        drive(&mut e, 5, true);
        // bit-equality: the event engine under --no-overlap IS the legacy
        // barrier clock.
        assert_eq!(e.now(), e.serialized_time());
    }

    #[test]
    fn overlap_is_never_slower_and_hides_comm() {
        let mut ser = engine(2, 2, false);
        let t_ser = drive(&mut ser, 8, true);
        let mut ovl = engine(2, 2, true);
        let t_ovl = drive(&mut ovl, 8, true);
        assert!(
            ovl.now() <= ser.now() * (1.0 + 1e-12),
            "overlap slower: {} vs {}",
            ovl.now(),
            ser.now()
        );
        // the serialized accumulator upper-bounds the overlapped horizon
        assert!(ovl.now() <= ovl.serialized_time() * (1.0 + 1e-12));
        // serialized mode hides (essentially) nothing; overlap does
        assert!(
            t_ser.hidden_comm <= 1e-9 * ser.now(),
            "serialized hid comm: {t_ser:?}"
        );
        assert!(t_ovl.hidden_comm > 1e-7 * ovl.now(), "{t_ovl:?}");
    }

    #[test]
    fn timelines_stay_monotone_across_steps() {
        let mut e = engine(2, 4, true);
        let mut prev = vec![0.0f64; 8];
        let traffic = TrafficMatrix::new(2);
        for _ in 0..6 {
            e.begin_step();
            e.unshard(1024, &traffic);
            e.compute(1e8);
            e.reduce_scatter(1024);
            e.end_step();
            let (c, n) = e.timelines();
            for r in 0..8 {
                let t = c.now(r).max(n.now(r));
                assert!(t >= prev[r], "rank {r} went backwards");
                prev[r] = t;
            }
        }
    }

    #[test]
    fn straggler_owns_critical_path() {
        let cluster = ClusterModel {
            slowdown: vec![1.0, 3.0],
            node_inter_bw: vec![],
        };
        let topo = Topology::new(2, 2);
        let mut e = StepEngine::new(topo, NetModel::hpc(), cluster, true);
        drive(&mut e, 4, true);
        let crit = e.critical_rank();
        assert_eq!(topo.node_of(crit), 1, "critical rank {crit} not on straggler node");
        // and the run is strictly slower than the uniform cluster
        let mut u = engine(2, 2, true);
        drive(&mut u, 4, true);
        assert!(e.now() > u.now());
    }

    #[test]
    fn events_carry_schedule_and_deps() {
        let mut e = engine(2, 2, true);
        drive(&mut e, 2, true);
        assert!(!e.events.is_empty());
        // per-step events: 2 unshard + 2 reduce-scatter + 2 gathers
        assert_eq!(e.events.len(), 6);
        let labels: Vec<&str> = e.events.iter().map(|ev| ev.label).collect();
        assert!(labels.contains(&"all-gather"));
        assert!(labels.contains(&"reduce-scatter"));
        assert!(labels.contains(&"naive-gather"));
        for ev in &e.events {
            assert!(ev.duration > 0.0);
            assert!(ev.end() >= ev.start);
        }
        // the second step's events depend on the first step's (ids exist)
        assert!(e.events.iter().any(|ev| !ev.deps.is_empty()));
    }
}
