//! The discrete-event overlap engine — the simulator's spine.
//!
//! Each rank owns three resource lanes: a **compute** lane (the
//! accelerator), an **intra-node fabric** lane (NVLink/xGMI class — the
//! FSDP unshard and gradient reduce-scatter ride here), and a **NIC**
//! lane (the inter-node link the replication gather crosses). A training
//! step is a DAG of reservations on those lanes; [`StepEngine`]
//! schedules them and the step's duration is whatever the critical path
//! says, instead of the old barrier-synchronous sum of phase maxima.
//! Separating fabric from NIC matches the hardware (intra-node traffic
//! does not contend with the network port) and is what lets a bucketed
//! gather start while later gradient buckets are still reducing.
//!
//! ## Dependency model (one FlexDeMo step)
//!
//! ```text
//! compute lane:   fwd(t) ──────────── bwd(t) ─────────────── fwd(t+1) …
//!                  │  (no comm dep:     ▲ needs update(t-1)
//!                  │   stale-params     │ visible = unshard end)
//! fabric lane:     │   pipelining)      │
//!   unshard(t) ────┘  [≥ gather(t-1)]───┘
//!   reduce-scatter(t)  [starts with bwd(t), ends ≥ bwd(t) end]
//! NIC lane:
//!   gather(t)          [after reduce-scatter(t); overlaps fwd(t+1)]
//! ```
//!
//! * the **replication gather** of step *t* overlaps the next step's
//!   forward: the forward runs on parameters that receive the averaged
//!   update when the gather lands (DeMo's async `dist.all_gather`
//!   decoupling), and only the next *backward* requires the update to be
//!   visible;
//! * a **deferred gather** ([`StepEngine::gather_deferred`], async
//!   DiLoCo's `--staleness` lane) reserves the NIC exactly like a normal
//!   gather but does *not* gate the next backward at all: its completion
//!   time is parked in a per-rank slot and only feeds `update_visible`
//!   when the trainer announces the arrival step via
//!   [`StepEngine::sync_arrival`], S steps after the launch — so up to S
//!   whole optimization steps run under the in-flight sync (the events
//!   carry the `async-gather` label in `--trace-out` Chrome traces);
//! * the **straggler-tolerant per-member lanes**
//!   ([`StepEngine::gather_deferred_per_member`], `--late-policy` +
//!   per-node `--staleness`) replace the single parked completion with
//!   one NIC event per group member: each member's send queue starts at
//!   *its own* reduce-scatter completion (a slow node no longer delays a
//!   fast node's launch) and finishes independently. Nothing gates any
//!   backward until the trainer announces, per member, which
//!   contributions it aggregated ([`StepEngine::sync_arrival_member`]);
//!   contributions are judged against the member's **arrival deadline**
//!   ([`StepEngine::arrival_deadline`] — the end of its backward in the
//!   arrival step), so an admitted contribution can never stall the lane
//!   that admitted it. Per-member events carry the owning sender node
//!   (`owner_node` in `--trace-out` args);
//! * the **intra-node reduce-scatter** streams gradient buckets while the
//!   backward produces them: it may start with the backward but cannot
//!   finish before it;
//! * the **unshard all-gather** (phase 0) rides the fabric once the
//!   gather's update is visible and likewise only gates the next
//!   backward.
//!
//! ## `--no-overlap` parity
//!
//! In serialized mode every phase is fenced by a global barrier and the
//! engine reproduces the legacy `SimClock` arithmetic *bit-for-bit*: the
//! horizon advances by (unshard + compute + max reduce-scatter +
//! max gather) per step, in that order, using the same duration formulas
//! (they live in `collectives::*_event`, shared by both paths). The
//! `serialized_time()` accumulator tracks that sum in *both* modes, so
//! `now() == serialized_time()` under `--no-overlap` always, and
//! `now() ≤ serialized_time()` for overlapped *whole-phase* schedules —
//! both asserted in the integration tests. Bucketed schedules pay one α
//! per bucket while `serialized` keeps whole-phase durations, so on a
//! latency-dominated link a heavily-bucketed run may exceed the
//! serialized reference (which is exactly when `--bucket-mb` should not
//! be used).
//!
//! ## Scenario knobs
//!
//! [`ClusterModel`] supplies per-node straggler slowdowns (scaling that
//! node's compute reservations) and per-node NIC bandwidth overrides
//! (a replication group's link runs at its slowest member NIC).
//!
//! ## Pipelined gradient buckets (`--bucket-mb`)
//!
//! With a bucket size set (and overlap on), the reduce-scatter and the
//! replication gather split their traffic into per-bucket
//! [`CommEvent`]s instead of one whole-phase event:
//!
//! * reduce-scatter bucket *i* of *m* becomes available `(i+1)/m` of the
//!   way through the backward (gradient buckets stream out of the
//!   backward as they are produced) and reduces as soon as the fabric
//!   frees up;
//! * gather bucket *j* ships once the reduce-scatter has covered the
//!   matching fraction of the shard — so the **first bucket's
//!   communication overlaps the remaining buckets' compression** and
//!   the inter-node gather starts deep inside the backward window
//!   instead of after it.
//!
//! Each bucket pays its own α, so the *serialized* accumulator keeps
//! using the whole-phase durations: under `--no-overlap` bucketing is
//! ignored entirely and totals reproduce the legacy clock bit-for-bit.
//! Bucketing never touches data — numerics are identical by
//! construction (tested in `tests/integration.rs`).

use crate::collectives::{ring_all_gather_event, ring_reduce_scatter_event, CommEvent, Link};
use crate::net::{
    ClusterModel, FaultOutcome, FaultTimeline, LinkClass, NetModel, SimTime, Timeline, Topology,
    TrafficMatrix,
};
use crate::replicate::GatherMode;

/// Fraction of a step's compute spent in the forward pass (fwd:bwd ≈ 1:2,
/// the standard transformer estimate).
pub const FWD_FRACTION: f64 = 1.0 / 3.0;

/// Per-step timing summary for metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Global sim-time horizon after the step.
    pub sim_time: SimTime,
    /// Critical rank's compute busy-time this step.
    pub compute_time: f64,
    /// Communication the critical rank could not hide behind compute.
    pub exposed_comm: f64,
    /// Communication the critical rank overlapped with compute.
    pub hidden_comm: f64,
}

/// Hard cap on buckets per phase — bounds event-count blowup when the
/// bucket size is tiny relative to the payload.
const MAX_BUCKETS: u64 = 32;

/// The self-healing transfer knobs (`--link-fault` + retry flags),
/// handed to the engine at trainer construction. The retry lane
/// re-charges a failed/corrupt per-member transfer on the NIC timeline
/// after `retry_timeout` plus a capped exponential backoff
/// (`retry_backoff · 2^attempt`, capped at [`BACKOFF_CAP`]× the base) —
/// all sim-time, fully deterministic from `seed`.
#[derive(Clone, Debug)]
pub struct FaultLane {
    pub timeline: FaultTimeline,
    pub seed: u64,
    pub max_retries: u32,
    pub retry_timeout: f64,
    pub retry_backoff: f64,
}

/// Exponential-backoff cap, as a multiple of the backoff base.
pub const BACKOFF_CAP: f64 = 8.0;

/// What the fault lane did to one member's transfer in a
/// [`StepEngine::gather_deferred_per_member`] call — the trainer reads
/// these (via [`StepEngine::last_member_faults`]) to count retries,
/// verify detected corruption against the payload checksum, and route
/// exhausted senders through the late-arrival machinery.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemberFault {
    /// Extra attempts charged on the NIC beyond the first.
    pub retries: u32,
    /// Attempts that delivered corrupted bytes (caught by checksum).
    pub corrupt: u32,
    /// False = `max_retries` exhausted; the contribution never lands
    /// (its completion is +∞) and falls back to `--late-policy`.
    pub delivered: bool,
}

/// A [`StepEngine`]'s full scheduling state at a step boundary —
/// everything a checkpointed rank needs to continue bit-identically
/// (each timeline's `(ready, busy)` lanes plus the per-rank dependency
/// slots and the serialized reference clock).
#[derive(Clone, Debug)]
pub struct EngineState {
    pub compute: (Vec<SimTime>, Vec<f64>),
    pub fabric: (Vec<SimTime>, Vec<f64>),
    pub nic: (Vec<SimTime>, Vec<f64>),
    pub update_visible: Vec<SimTime>,
    pub deferred_end: Vec<SimTime>,
    pub rs_done: Vec<SimTime>,
    pub bwd_start: Vec<SimTime>,
    pub bwd_end: Vec<SimTime>,
    pub serialized: SimTime,
    pub next_event_id: u64,
}

pub struct StepEngine {
    topo: Topology,
    net: NetModel,
    cluster: ClusterModel,
    overlap: bool,
    /// Bucket size in bytes for pipelined comm (0 = whole-phase events).
    bucket_bytes: u64,
    /// One lane per rank on each resource: accelerator, intra-node
    /// fabric (unshard + reduce-scatter), inter-node NIC (gather).
    compute: Timeline,
    fabric: Timeline,
    nic: Timeline,
    /// When rank r's parameters carry the latest optimizer update
    /// (gather/unshard landing time) — the next backward's dependency.
    update_visible: Vec<SimTime>,
    /// Completion time of rank r's in-flight *deferred* gather (async
    /// DiLoCo). Parked here instead of `update_visible` until the
    /// trainer calls [`Self::sync_arrival`]; 0 when nothing is in
    /// flight.
    deferred_end: Vec<SimTime>,
    /// End of this step's reduce-scatter per rank (gather dependency).
    rs_done: Vec<SimTime>,
    /// Per-bucket reduce-scatter completion times this step (empty when
    /// the phase ran whole; lets gather buckets chase rs progress).
    rs_bucket_end: Vec<Vec<SimTime>>,
    bwd_start: Vec<SimTime>,
    bwd_end: Vec<SimTime>,
    /// What the legacy barrier-synchronous clock would read.
    serialized: SimTime,
    /// Per-node membership mask (elastic membership): inactive nodes'
    /// ranks get no reservations — their lanes freeze at departure time
    /// — and phase maxima are taken over active ranks only. All-true
    /// (the default) is exactly the fixed-group schedule.
    active: Vec<bool>,
    /// Scheduled events of the current/last step (debug + tests).
    pub events: Vec<CommEvent>,
    next_event_id: u64,
    last_nic_event: Vec<Option<u64>>,
    /// Link-fault model + retry knobs (None = the perfect network; every
    /// transfer delivers first try, bit-identical to the pre-fault path).
    fault: Option<FaultLane>,
    /// Step index the fault timeline is consulted at (trainer-set).
    fault_step: u64,
    /// Per-step fault counters (reset by `begin_step`).
    step_retries: u64,
    step_corrupts: u64,
    /// Per-member fault reports of the *last*
    /// `gather_deferred_per_member` call (parallel to its return value).
    last_member_faults: Vec<MemberFault>,
    // per-step bookkeeping
    step_start_horizon: SimTime,
    step_compute_busy0: Vec<f64>,
    step_fabric_busy0: Vec<f64>,
    step_nic_busy0: Vec<f64>,
    step_gather_max: f64,
    gather_phase_start: Option<SimTime>,
}

impl StepEngine {
    pub fn new(topo: Topology, net: NetModel, cluster: ClusterModel, overlap: bool) -> StepEngine {
        let world = topo.world_size();
        StepEngine {
            topo,
            net,
            cluster,
            overlap,
            bucket_bytes: 0,
            compute: Timeline::new(world),
            fabric: Timeline::new(world),
            nic: Timeline::new(world),
            update_visible: vec![0.0; world],
            deferred_end: vec![0.0; world],
            rs_done: vec![0.0; world],
            rs_bucket_end: vec![Vec::new(); world],
            bwd_start: vec![0.0; world],
            bwd_end: vec![0.0; world],
            serialized: 0.0,
            active: vec![true; topo.nodes],
            events: Vec::new(),
            next_event_id: 0,
            last_nic_event: vec![None; world],
            fault: None,
            fault_step: 0,
            step_retries: 0,
            step_corrupts: 0,
            last_member_faults: Vec::new(),
            step_start_horizon: 0.0,
            step_compute_busy0: vec![0.0; world],
            step_fabric_busy0: vec![0.0; world],
            step_nic_busy0: vec![0.0; world],
            step_gather_max: 0.0,
            gather_phase_start: None,
        }
    }

    /// Builder: split reduce-scatter/gather traffic into per-bucket
    /// events of at most `bucket_bytes` (0 = whole-phase, the default).
    /// Only affects the overlapped schedule; `--no-overlap` ignores it.
    pub fn with_buckets(mut self, bucket_bytes: u64) -> StepEngine {
        self.bucket_bytes = bucket_bytes;
        self
    }

    /// Builder: arm the link-fault model + retry lane (`--link-fault`).
    /// An empty timeline is normalized to `None`, so the fault-free spec
    /// is bit-identical to never calling this.
    pub fn with_faults(mut self, lane: FaultLane) -> StepEngine {
        self.fault = if lane.timeline.is_empty() { None } else { Some(lane) };
        self
    }

    /// Announce the step index fault decisions are drawn at (the trainer
    /// calls this at the top of each step; a no-op without faults).
    pub fn set_fault_step(&mut self, step: u64) {
        self.fault_step = step;
    }

    /// This step's fault counters so far: (retry attempts charged,
    /// corrupt deliveries detected). Reset by [`Self::begin_step`].
    pub fn step_fault_counts(&self) -> (u64, u64) {
        (self.step_retries, self.step_corrupts)
    }

    /// Per-member fault reports of the last
    /// [`Self::gather_deferred_per_member`] call, parallel to the
    /// completion times it returned. Empty when no faults were armed.
    pub fn last_member_faults(&self) -> &[MemberFault] {
        &self.last_member_faults
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Set the per-node membership mask for subsequent phases (elastic
    /// membership). Inactive nodes are skipped by every phase as pure
    /// control flow, so an all-true mask is bit-identical to never
    /// calling this.
    pub fn set_active(&mut self, active: &[bool]) {
        debug_assert_eq!(active.len(), self.topo.nodes);
        self.active.clear();
        self.active.extend_from_slice(active);
    }

    fn node_active(&self, node: usize) -> bool {
        self.active.get(node).copied().unwrap_or(true)
    }

    fn rank_active(&self, rank: usize) -> bool {
        self.node_active(self.topo.node_of(rank))
    }

    /// Buckets a phase of `bytes` splits into (1 = whole-phase).
    fn n_buckets(&self, bytes: u64) -> u64 {
        if self.bucket_bytes == 0 || bytes == 0 || !self.overlap {
            1
        } else {
            bytes.div_ceil(self.bucket_bytes).min(MAX_BUCKETS)
        }
    }

    /// Bytes of bucket `j` when `total` splits into `m` even buckets
    /// (remainder spread over the first buckets; sums exactly to total).
    fn bucket_split(total: u64, m: u64, j: u64) -> u64 {
        total / m + u64::from(j < total % m)
    }

    /// When the reduce-scatter output covering fraction `frac` of rank
    /// `r`'s shard became available (bucket-granular when the phase was
    /// bucketed, else the whole-phase completion).
    fn rs_frac_done(&self, rank: usize, frac: f64) -> SimTime {
        let ends = &self.rs_bucket_end[rank];
        if ends.is_empty() {
            return self.rs_done[rank];
        }
        let m = ends.len();
        let idx = ((frac * m as f64).ceil() as usize).clamp(1, m) - 1;
        ends[idx]
    }

    /// Global sim-time horizon (latest lane across all resources).
    pub fn now(&self) -> SimTime {
        self.compute
            .horizon()
            .max(self.fabric.horizon())
            .max(self.nic.horizon())
    }

    /// What the legacy barrier clock would read for the same run — equals
    /// `now()` under `--no-overlap`, upper-bounds it with overlap on.
    pub fn serialized_time(&self) -> SimTime {
        self.serialized
    }

    /// Latest lane end of one rank.
    pub fn rank_end(&self, rank: usize) -> SimTime {
        self.compute
            .now(rank)
            .max(self.fabric.now(rank))
            .max(self.nic.now(rank))
    }

    /// The rank on the step's critical path: latest end, ties broken by
    /// compute busy-time (so a barrier-fenced straggler still wins).
    pub fn critical_rank(&self) -> usize {
        // Inactive ranks' frozen lanes stay off the critical path (under
        // `--no-overlap` the barrier drags every lane to the horizon, so
        // without the filter a departed straggler could win the tiebreak).
        let mut best = 0usize;
        for r in 1..self.topo.world_size() {
            if !self.rank_active(r) {
                continue;
            }
            let (e, b) = (self.rank_end(r), self.compute.busy(r));
            let (be, bb) = (self.rank_end(best), self.compute.busy(best));
            if e > be || (e == be && b > bb) {
                best = r;
            }
        }
        best
    }

    /// Per-rank compute/fabric/NIC timelines (read-only; invariants
    /// tested).
    pub fn timelines(&self) -> (&Timeline, &Timeline, &Timeline) {
        (&self.compute, &self.fabric, &self.nic)
    }

    /// Cumulative NIC-busy seconds of one rank's lane — the occupancy
    /// tap the adaptive rate controller samples per window (it takes
    /// deltas itself, so this stays a monotone run total).
    pub fn nic_busy(&self, rank: usize) -> f64 {
        self.nic.busy(rank)
    }

    fn world(&self) -> usize {
        self.topo.world_size()
    }

    /// Fence every lane at the current horizon (serialized mode only).
    fn barrier(&mut self) -> SimTime {
        let h = self.now();
        for r in 0..self.world() {
            self.compute.stall_until(r, h);
            self.fabric.stall_until(r, h);
            self.nic.stall_until(r, h);
        }
        h
    }

    fn push_event(&mut self, mut ev: CommEvent, members: &[usize]) -> u64 {
        let id = self.next_event_id;
        self.next_event_id += 1;
        ev.id = id;
        ev.ranks = members.to_vec();
        for &r in members {
            self.last_nic_event[r] = Some(id);
        }
        self.events.push(ev);
        id
    }

    fn nic_deps(&self, members: &[usize]) -> Vec<u64> {
        let mut deps: Vec<u64> = members
            .iter()
            .filter_map(|&r| self.last_nic_event[r])
            .collect();
        deps.sort_unstable();
        deps.dedup();
        deps
    }

    pub fn begin_step(&mut self) {
        self.events.clear();
        self.step_gather_max = 0.0;
        self.gather_phase_start = None;
        self.step_retries = 0;
        self.step_corrupts = 0;
        self.step_start_horizon = self.now();
        for r in 0..self.world() {
            self.step_compute_busy0[r] = self.compute.busy(r);
            self.step_fabric_busy0[r] = self.fabric.busy(r);
            self.step_nic_busy0[r] = self.nic.busy(r);
            self.rs_bucket_end[r].clear();
        }
    }

    /// Phase 0: intra-node all-gather that unshards the updated parameters
    /// (per node group). Records the phase's intra-node traffic — this is
    /// where the old trainer's hand-rolled unshard accounting now lives.
    pub fn unshard(&mut self, shard_bytes: u64, traffic: &TrafficMatrix) {
        let accels = self.topo.accels_per_node;
        if accels <= 1 {
            return;
        }
        for node in 0..self.topo.nodes {
            if !self.node_active(node) {
                continue;
            }
            traffic.record(node, node, (accels - 1) as u64 * shard_bytes * accels as u64);
        }
        let link = Link::of(&self.net, LinkClass::IntraNode);
        let proto = ring_all_gather_event(&link, accels, shard_bytes);
        let dur = proto.duration;
        if !self.overlap {
            let h = self.barrier();
            for node in 0..self.topo.nodes {
                if !self.node_active(node) {
                    continue;
                }
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                for &r in &members {
                    self.fabric.reserve(r, h, dur);
                    self.update_visible[r] = h + dur;
                }
                self.push_event(proto.clone().scheduled(h, Vec::new()), &members);
            }
        } else {
            for node in 0..self.topo.nodes {
                if !self.node_active(node) {
                    continue;
                }
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                let earliest = members
                    .iter()
                    .fold(0.0f64, |m, &r| m.max(self.update_visible[r]));
                let start = earliest.max(self.fabric.join(&members));
                let deps = self.nic_deps(&members);
                for &r in &members {
                    self.fabric.reserve(r, start, dur);
                    self.update_visible[r] = start + dur;
                }
                self.push_event(proto.clone().scheduled(start, deps), &members);
            }
        }
        self.serialized += dur;
    }

    /// Phase 1: fwd+bwd on every rank. The forward has no communication
    /// dependency (stale-params pipelining); the backward waits until the
    /// previous step's update is visible on this rank.
    pub fn compute(&mut self, flops: f64) {
        let ct = self.net.compute_time(flops);
        let mut dmax = 0.0f64;
        if !self.overlap {
            let h = self.barrier();
            for r in 0..self.world() {
                if !self.rank_active(r) {
                    continue;
                }
                let tc = ct * self.cluster.slowdown_of(self.topo.node_of(r));
                // Unsplit in serialized mode so the lane end is exactly
                // h + tc (bit-parity with the legacy clock).
                let (start, end) = self.compute.reserve(r, h, tc);
                self.bwd_start[r] = start;
                self.bwd_end[r] = end;
                dmax = dmax.max(tc);
            }
        } else {
            for r in 0..self.world() {
                if !self.rank_active(r) {
                    continue;
                }
                let tc = ct * self.cluster.slowdown_of(self.topo.node_of(r));
                let tf = tc * FWD_FRACTION;
                let tb = tc - tf;
                self.compute.reserve(r, 0.0, tf);
                let (bs, be) = self.compute.reserve(r, self.update_visible[r], tb);
                self.bwd_start[r] = bs;
                self.bwd_end[r] = be;
                dmax = dmax.max(tc);
            }
        }
        self.serialized += dmax;
    }

    /// Phase 2: intra-node ring reduce-scatter of the gradients. Streams
    /// behind the backward: may start with it, cannot finish before it.
    pub fn reduce_scatter(&mut self, max_shard_bytes: u64) {
        let accels = self.topo.accels_per_node;
        if accels <= 1 {
            // No reduction needed; the local update is ready when the
            // backward is.
            for r in 0..self.world() {
                if !self.rank_active(r) {
                    continue;
                }
                self.rs_done[r] = self.bwd_end[r];
                self.update_visible[r] = self.bwd_end[r];
            }
            self.serialized += 0.0;
            return;
        }
        let link = Link::of(&self.net, LinkClass::IntraNode);
        let proto = ring_reduce_scatter_event(&link, accels, max_shard_bytes);
        let dur = proto.duration;
        if !self.overlap {
            let h = self.barrier();
            for node in 0..self.topo.nodes {
                if !self.node_active(node) {
                    continue;
                }
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                for &r in &members {
                    self.fabric.reserve(r, h, dur);
                    self.rs_done[r] = h + dur;
                    self.update_visible[r] = h + dur;
                }
                self.push_event(proto.clone().scheduled(h, Vec::new()), &members);
            }
        } else if self.n_buckets(max_shard_bytes) <= 1 {
            for node in 0..self.topo.nodes {
                if !self.node_active(node) {
                    continue;
                }
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                let bwd_start_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_start[r]));
                let bwd_end_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_end[r]));
                let start = self.fabric.join(&members).max(bwd_start_max);
                let fin = (start + dur).max(bwd_end_max);
                let deps = self.nic_deps(&members);
                for &r in &members {
                    self.fabric.reserve(r, start, dur);
                    // the last gradient bucket lands only when bwd ends
                    self.fabric.stall_until(r, fin);
                    self.rs_done[r] = fin;
                    self.update_visible[r] = fin;
                }
                self.push_event(proto.clone().scheduled(start, deps), &members);
            }
        } else {
            // Bucketed: gradient bucket i streams out of the backward at
            // the (i+1)/m mark and reduces on the fabric as soon as it
            // frees up — early buckets finish deep inside the backward
            // window, and their completion times let the gather start
            // before the whole phase is done.
            let m = self.n_buckets(max_shard_bytes);
            for node in 0..self.topo.nodes {
                if !self.node_active(node) {
                    continue;
                }
                let members: Vec<usize> = (0..accels).map(|a| self.topo.rank(node, a)).collect();
                let bwd_start_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_start[r]));
                let bwd_end_max = members.iter().fold(0.0f64, |m, &r| m.max(self.bwd_end[r]));
                let tb = (bwd_end_max - bwd_start_max).max(0.0);
                let mut deps = self.nic_deps(&members);
                let mut ends = Vec::with_capacity(m as usize);
                for j in 0..m {
                    let bytes_j = Self::bucket_split(max_shard_bytes, m, j);
                    let ev = ring_reduce_scatter_event(&link, accels, bytes_j);
                    let ready = bwd_start_max + tb * (j + 1) as f64 / m as f64;
                    let start = self.fabric.join(&members).max(ready);
                    for &r in &members {
                        self.fabric.reserve(r, start, ev.duration);
                    }
                    ends.push(start + ev.duration);
                    let id = self.push_event(ev.scheduled(start, deps.clone()), &members);
                    deps = vec![id];
                }
                let fin = *ends.last().expect("m >= 1");
                for &r in &members {
                    self.rs_done[r] = fin;
                    self.update_visible[r] = fin;
                    self.rs_bucket_end[r].clone_from(&ends);
                }
            }
        }
        self.serialized += dur;
    }

    /// Phase 3/4: replication gather across one R-group (called once per
    /// shard that syncs this step). Overlaps the next step's forward; the
    /// group's inter-node link runs at its slowest member NIC.
    pub fn gather(
        &mut self,
        group: &[usize],
        mode: GatherMode,
        payload_bytes: &[u64],
        traffic: &TrafficMatrix,
    ) {
        self.gather_inner(group, mode, payload_bytes, traffic, false);
    }

    /// The async (stale) replication lane: charge the gather on the NIC
    /// now — same cost, same schedule, same serialized accounting as
    /// [`Self::gather`] — but park its completion time instead of gating
    /// the next backward on it. The trainer announces the application
    /// step later via [`Self::sync_arrival`]; until then local steps run
    /// free of the sync. Scheduled events carry the `async-gather` label
    /// so in-flight syncs are visible in `--trace-out` Chrome traces.
    pub fn gather_deferred(
        &mut self,
        group: &[usize],
        mode: GatherMode,
        payload_bytes: &[u64],
        traffic: &TrafficMatrix,
    ) {
        self.gather_inner(group, mode, payload_bytes, traffic, true);
    }

    /// The trainer applied a deferred gather's averaged update this step:
    /// its completion now gates the *next* backward (feeds
    /// `update_visible`), S steps after [`Self::gather_deferred`] charged
    /// the wire.
    pub fn sync_arrival(&mut self, group: &[usize]) {
        for &r in group {
            if self.deferred_end[r] > self.update_visible[r] {
                self.update_visible[r] = self.deferred_end[r];
            }
            self.deferred_end[r] = 0.0;
        }
    }

    /// Straggler-tolerant launch: one NIC event per group member instead
    /// of one whole-group event. Member *i*'s send queue ((g−1) sends of
    /// its payload for the naive all-gather) starts at **its own**
    /// reduce-scatter completion at **its own** NIC bandwidth, so fast
    /// members launch early and finish early while a straggler's late
    /// contribution stays its own problem. Returns each member's
    /// contribution completion time — the trainer compares these against
    /// per-member [`Self::arrival_deadline`]s to form the on-time quorum
    /// and announces what it aggregated via
    /// [`Self::sync_arrival_member`]; until then nothing gates any
    /// backward. Events are labelled `async-gather` and tagged with the
    /// owning sender node for `--trace-out`.
    ///
    /// Only the uniform-staleness `--late-policy wait` window keeps the
    /// PR 4 whole-group event ([`Self::gather_deferred`]) — that path is
    /// bit-frozen; this one intentionally prices the same bytes as
    /// independent per-sender queues.
    ///
    /// `topo_dests` arms a non-full [`SyncTopology`]: member *i* then
    /// sends only to the member indices in `topo_dests[i]`, its NIC
    /// event is priced as that many point-to-point sends (so gossip's
    /// O(1) per-window cost is what the clock and traces actually see,
    /// labelled `gossip-gather`), traffic is recorded on the selected
    /// links only, and the fault timeline judges the transfer on those
    /// links alone — a fault on an unused link cannot touch it. A
    /// self-paired member (empty dest list) charges nothing and cannot
    /// fault. `None` is the whole-group exchange, bit-identical to the
    /// pre-topology schedule.
    pub fn gather_deferred_per_member(
        &mut self,
        group: &[usize],
        mode: GatherMode,
        payload_bytes: &[u64],
        traffic: &TrafficMatrix,
        topo_dests: Option<&[Vec<usize>]>,
    ) -> Vec<SimTime> {
        let g = group.len();
        let class = self.topo.group_link_class(group);
        match topo_dests {
            None => mode.record_traffic(traffic, &self.topo, group, payload_bytes),
            Some(dests) => {
                for (i, d) in dests.iter().enumerate() {
                    let src = self.topo.node_of(group[i]);
                    for &j in d {
                        traffic.record(src, self.topo.node_of(group[j]), payload_bytes[i]);
                    }
                }
            }
        }
        let h = if self.overlap {
            None
        } else {
            Some(match self.gather_phase_start {
                Some(h) => h,
                None => {
                    let h = self.barrier();
                    self.gather_phase_start = Some(h);
                    h
                }
            })
        };
        let mut ends = vec![0.0f64; g];
        let mut max_dur = 0.0f64;
        let fault = self.fault.clone();
        let member_nodes: Vec<usize> = group.iter().map(|&r| self.topo.node_of(r)).collect();
        self.last_member_faults.clear();
        self.last_member_faults.resize(g, MemberFault { delivered: true, ..Default::default() });
        for (i, &rank) in group.iter().enumerate() {
            let node = self.topo.node_of(rank);
            let link = Link {
                class,
                lat: self.net.lat(class),
                bw: self.cluster.group_bw(&self.net, class, &[node]),
            };
            let mut ev = match (topo_dests, mode) {
                // A topology-selected peer set prices exactly its links:
                // |dests| point-to-point sends of this member's payload,
                // whatever the scheme's whole-group transport would be.
                (Some(dests), _) => {
                    let n = dests[i].len() as u64;
                    CommEvent::new(
                        "gossip-gather",
                        class,
                        n * payload_bytes[i],
                        n as f64 * link.xfer(payload_bytes[i]),
                    )
                }
                (None, GatherMode::NaiveAllGather) => {
                    let (bytes, dur) = if g <= 1 {
                        (0, 0.0)
                    } else {
                        (
                            (g as u64 - 1) * payload_bytes[i],
                            (g as f64 - 1.0) * link.xfer(payload_bytes[i]),
                        )
                    };
                    CommEvent::new("async-gather", class, bytes, dur)
                }
                // Ring transports have no per-sender decomposition;
                // charge the whole event on this member's lane.
                (None, _) => mode.comm_event(&link, payload_bytes),
            }
            .owned_by(node);
            ev.label = if topo_dests.is_some() {
                "gossip-gather"
            } else {
                "async-gather"
            };
            let earliest = h.unwrap_or(self.rs_done[rank]);
            // The sender's destinations — every *other* member's node,
            // or only the topology-selected peers' nodes — are the links
            // the fault timeline judges this transfer on.
            let dsts: Vec<usize> = match topo_dests {
                Some(dests) => dests[i].iter().map(|&j| member_nodes[j]).collect(),
                None => member_nodes
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| j != i)
                    .map(|(_, &n)| n)
                    .collect(),
            };
            let faulted = fault
                .as_ref()
                .filter(|f| !dsts.is_empty() && f.timeline.affects(self.fault_step, node, &dsts));
            let Some(f) = faulted else {
                // Perfect-network fast path: bit-identical to the
                // pre-fault schedule (one reservation, no outcome roll).
                max_dur = max_dur.max(ev.duration);
                let deps = self.nic_deps(&[rank]);
                let (start, end) = self.nic.reserve(rank, earliest, ev.duration);
                ends[i] = end;
                self.push_event(ev.scheduled(start, deps), &[rank]);
                continue;
            };
            // Self-healing retry lane: attempt 0 plus up to max_retries
            // re-charges, each a real NIC reservation (failed attempts
            // occupy the wire). A degraded link stretches every attempt;
            // the next attempt waits out the timeout + capped backoff.
            let step = self.fault_step;
            let dur = ev.duration * f.timeline.slowdown(step, node, &dsts);
            let mut mf = MemberFault::default();
            let mut next_earliest = earliest;
            let mut first_start = f64::NAN;
            let mut last_end = earliest;
            let mut end = f64::INFINITY;
            for attempt in 0..=f.max_retries {
                let deps = self.nic_deps(&[rank]);
                let (start, a_end) = self.nic.reserve(rank, next_earliest, dur);
                if attempt == 0 {
                    first_start = start;
                } else {
                    mf.retries += 1;
                    self.step_retries += 1;
                }
                let mut at = ev.clone();
                at.duration = dur;
                if attempt > 0 {
                    at.label = "retry-gather";
                }
                self.push_event(at.scheduled(start, deps), &[rank]);
                last_end = a_end;
                match f.timeline.attempt_outcome(f.seed, step, attempt, node, &dsts) {
                    FaultOutcome::Delivered => {
                        mf.delivered = true;
                        end = a_end;
                        break;
                    }
                    FaultOutcome::Corrupted => {
                        mf.corrupt += 1;
                        self.step_corrupts += 1;
                    }
                    FaultOutcome::Dropped => {}
                }
                let backoff = (f.retry_backoff * (1u64 << attempt.min(32)) as f64)
                    .min(BACKOFF_CAP * f.retry_backoff);
                next_earliest = a_end + f.retry_timeout + backoff;
            }
            // The serialized reference charges the whole chain's lane
            // span (attempts + backoff gaps): exact barrier parity under
            // `--no-overlap` (every chain starts at h), an upper bound
            // with overlap on.
            max_dur = max_dur.max(last_end - first_start);
            ends[i] = end;
            self.last_member_faults[i] = mf;
        }
        // The serialized reference charges the phase's slowest member —
        // identical to the whole-phase event on a uniform cluster, and
        // exactly the barriered lane maximum under `--no-overlap`.
        self.step_gather_max = self.step_gather_max.max(max_dur);
        ends
    }

    /// A member of a straggler-tolerant window applied its aggregated
    /// update this step: the latest admitted contribution (`completion`,
    /// the max over the member's on-time quorum — 0.0 when it aggregated
    /// only itself) now gates that rank's *next* backward. The per-member
    /// counterpart of [`Self::sync_arrival`].
    pub fn sync_arrival_member(&mut self, rank: usize, completion: SimTime) {
        if completion > self.update_visible[rank] {
            self.update_visible[rank] = completion;
        }
    }

    /// The per-node arrival deadline: the end of this rank's backward in
    /// the current step. A peer contribution that landed by this instant
    /// can be aggregated *this* step without stalling anything (the
    /// aggregate only gates the next backward, which starts later by
    /// construction); one that missed it is late and subject to
    /// `--late-policy`.
    pub fn arrival_deadline(&self, rank: usize) -> SimTime {
        self.bwd_end[rank]
    }

    /// Elastic membership: a joining node receives the current
    /// parameters from the node-0 anchor before contributing again. One
    /// inter-node transfer of `param_bytes` rides the NIC lanes of both
    /// nodes (at the pair's slowest NIC) and gates the joiner's next
    /// backward; node 0 only donates NIC time. The serialized reference
    /// is charged the same duration, so `--no-overlap` keeps
    /// `now() == serialized_time()` through a join.
    pub fn join_broadcast(&mut self, node: usize, param_bytes: u64, traffic: &TrafficMatrix) {
        if node == 0 {
            return;
        }
        traffic.record(0, node, param_bytes);
        let class = LinkClass::InterNode;
        let link = Link {
            class,
            lat: self.net.lat(class),
            bw: self.cluster.group_bw(&self.net, class, &[0, node]),
        };
        let dur = link.xfer(param_bytes);
        let accels = self.topo.accels_per_node;
        let members: Vec<usize> = (0..accels)
            .map(|a| self.topo.rank(0, a))
            .chain((0..accels).map(|a| self.topo.rank(node, a)))
            .collect();
        let earliest = if self.overlap {
            // The anchor ships its settled params: start once every
            // involved lane (including the joiner's frozen ones) is free.
            self.now()
        } else {
            self.barrier()
        };
        let start = self.nic.join(&members).max(earliest);
        let deps = self.nic_deps(&members);
        for &r in &members {
            self.nic.reserve(r, start, dur);
        }
        for a in 0..accels {
            let r = self.topo.rank(node, a);
            self.update_visible[r] = start + dur;
            // the joiner restarts clean: no stale deferred completion
            self.deferred_end[r] = 0.0;
        }
        let ev = CommEvent::new("join-broadcast", class, param_bytes, dur).owned_by(0);
        self.push_event(ev.scheduled(start, deps), &members);
        self.serialized += dur;
    }

    /// Where a gather's landing time goes: the next backward's dependency
    /// (synchronous), or the parked slot [`Self::sync_arrival`] drains
    /// (deferred). Keeping this the only difference between the two
    /// lanes is what makes `--no-overlap` totals — and the whole
    /// synchronous schedule — bit-identical whether or not the deferred
    /// lane exists (engine-invariant tested).
    fn mark_update_visible(&mut self, rank: usize, at: SimTime, deferred: bool) {
        if deferred {
            self.deferred_end[rank] = at;
        } else {
            self.update_visible[rank] = at;
        }
    }

    fn gather_inner(
        &mut self,
        group: &[usize],
        mode: GatherMode,
        payload_bytes: &[u64],
        traffic: &TrafficMatrix,
        deferred: bool,
    ) {
        let class = self.topo.group_link_class(group);
        let nodes: Vec<usize> = group.iter().map(|&r| self.topo.node_of(r)).collect();
        let link = Link {
            class,
            lat: self.net.lat(class),
            bw: self.cluster.group_bw(&self.net, class, &nodes),
        };
        let mut ev = mode.comm_event(&link, payload_bytes);
        if deferred {
            ev.label = "async-gather";
        }
        mode.record_traffic(traffic, &self.topo, group, payload_bytes);
        let dur = ev.duration;
        self.step_gather_max = self.step_gather_max.max(dur);
        // Bucketing the gather only pays off when the reduce-scatter
        // produced incremental availability to pipeline against; without
        // it (accels=1, or a shard smaller than one bucket) the buckets
        // would serialize after the backward anyway, each paying its own
        // α — fall back to the single whole-phase event.
        let pipelined = group.iter().any(|&r| !self.rs_bucket_end[r].is_empty());
        let max_payload = if pipelined {
            payload_bytes.iter().copied().max().unwrap_or(0)
        } else {
            0
        };
        if !self.overlap {
            let h = match self.gather_phase_start {
                Some(h) => h,
                None => {
                    let h = self.barrier();
                    self.gather_phase_start = Some(h);
                    h
                }
            };
            for &r in group {
                self.nic.reserve(r, h, dur);
                self.mark_update_visible(r, h + dur, deferred);
            }
            self.push_event(ev.scheduled(h, Vec::new()), group);
        } else if self.n_buckets(max_payload) <= 1 {
            let earliest = group.iter().fold(0.0f64, |m, &r| m.max(self.rs_done[r]));
            let start = self.nic.join(group).max(earliest);
            let deps = self.nic_deps(group);
            for &r in group {
                self.nic.reserve(r, start, dur);
                self.mark_update_visible(r, start + dur, deferred);
            }
            self.push_event(ev.scheduled(start, deps), group);
        } else {
            // Bucketed: gather bucket j covers payload fraction (j+1)/m
            // and ships once the reduce-scatter has covered the matching
            // fraction of the shard — the first bucket crosses the
            // inter-node link while later buckets are still reducing.
            let m = self.n_buckets(max_payload);
            let mut deps = self.nic_deps(group);
            let mut sizes = vec![0u64; payload_bytes.len()];
            let mut end = 0.0f64;
            for j in 0..m {
                for (s, &b) in sizes.iter_mut().zip(payload_bytes) {
                    *s = Self::bucket_split(b, m, j);
                }
                let mut bev = mode.comm_event(&link, &sizes);
                if deferred {
                    bev.label = "async-gather";
                }
                let frac = (j + 1) as f64 / m as f64;
                let earliest = group
                    .iter()
                    .fold(0.0f64, |acc, &r| acc.max(self.rs_frac_done(r, frac)));
                let start = self.nic.join(group).max(earliest);
                for &r in group {
                    self.nic.reserve(r, start, bev.duration);
                }
                end = start + bev.duration;
                let id = self.push_event(bev.scheduled(start, deps.clone()), group);
                deps = vec![id];
            }
            for &r in group {
                self.mark_update_visible(r, end, deferred);
            }
        }
    }

    /// Snapshot the full scheduling state at a step boundary
    /// (checkpointing). Per-step scratch (`rs_bucket_end`, busy
    /// baselines, `step_gather_max`) is refreshed by `begin_step` before
    /// it is ever read, and `events`/`last_nic_event` only feed trace
    /// metadata, so none of those need to survive a restore.
    pub fn export_state(&self) -> EngineState {
        EngineState {
            compute: self.compute.export_state(),
            fabric: self.fabric.export_state(),
            nic: self.nic.export_state(),
            update_visible: self.update_visible.clone(),
            deferred_end: self.deferred_end.clone(),
            rs_done: self.rs_done.clone(),
            bwd_start: self.bwd_start.clone(),
            bwd_end: self.bwd_end.clone(),
            serialized: self.serialized,
            next_event_id: self.next_event_id,
        }
    }

    /// Restore a [`StepEngine::export_state`] snapshot taken on an
    /// engine with the same world size.
    pub fn import_state(&mut self, st: EngineState) -> anyhow::Result<()> {
        let world = self.world();
        anyhow::ensure!(
            st.update_visible.len() == world,
            "engine snapshot is for world size {}, engine has {}",
            st.update_visible.len(),
            world
        );
        self.compute.import_state(st.compute.0, st.compute.1)?;
        self.fabric.import_state(st.fabric.0, st.fabric.1)?;
        self.nic.import_state(st.nic.0, st.nic.1)?;
        self.update_visible = st.update_visible;
        self.deferred_end = st.deferred_end;
        self.rs_done = st.rs_done;
        self.bwd_start = st.bwd_start;
        self.bwd_end = st.bwd_end;
        self.serialized = st.serialized;
        self.next_event_id = st.next_event_id;
        self.events.clear();
        self.last_nic_event.fill(None);
        Ok(())
    }

    /// Close the step: settle barriers (serialized mode), fold the gather
    /// phase into the serialized accumulator, and summarize timing.
    pub fn end_step(&mut self) -> StepTiming {
        self.serialized += self.step_gather_max;
        if !self.overlap {
            self.barrier();
        }
        let sim_time = self.now();
        let crit = self.critical_rank();
        let compute_time = self.compute.busy(crit) - self.step_compute_busy0[crit];
        let comm = (self.nic.busy(crit) - self.step_nic_busy0[crit])
            + (self.fabric.busy(crit) - self.step_fabric_busy0[crit]);
        let span = (sim_time - self.step_start_horizon).max(0.0);
        let exposed_comm = (span - compute_time).clamp(0.0, comm.max(0.0));
        let hidden_comm = (comm - exposed_comm).max(0.0);
        StepTiming {
            sim_time,
            compute_time,
            exposed_comm,
            hidden_comm,
        }
    }
}

/// Serialize scheduled [`CommEvent`]s (tagged with their step) as a
/// Chrome-trace JSON document (`chrome://tracing` / Perfetto "X"
/// complete events). One lane (tid) per rank, sim-time µs on the time
/// axis; event args carry step, bytes, event id, dependency ids, and the
/// lane's node (`accels_per_node` maps tids onto nodes) — plus
/// `owner_node` for single-sender events (the per-member async-gather
/// lanes), so parked in-flight syncs are attributable to the node that
/// launched them — the figure-quality timeline view of overlap vs
/// `--no-overlap`.
pub fn chrome_trace_json(
    rows: &[(u64, CommEvent)],
    accels_per_node: usize,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let accels = accels_per_node.max(1);
    let mut evs: Vec<Json> = Vec::new();
    let mut max_rank = None::<usize>;
    for (step, ev) in rows {
        for &r in &ev.ranks {
            max_rank = Some(max_rank.map_or(r, |m| m.max(r)));
            let mut args = vec![
                ("step", Json::Num(*step as f64)),
                ("bytes", Json::Num(ev.bytes as f64)),
                ("event_id", Json::Num(ev.id as f64)),
                (
                    "deps",
                    Json::Arr(ev.deps.iter().map(|&d| Json::Num(d as f64)).collect()),
                ),
                ("node", Json::Num((r / accels) as f64)),
            ];
            if let Some(owner) = ev.node {
                args.push(("owner_node", Json::Num(owner as f64)));
            }
            evs.push(Json::obj(vec![
                ("name", Json::Str(ev.label.to_string())),
                (
                    "cat",
                    Json::Str(
                        match ev.class {
                            LinkClass::IntraNode => "intra-node",
                            LinkClass::InterNode => "inter-node",
                        }
                        .to_string(),
                    ),
                ),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(ev.start * 1e6)),
                ("dur", Json::Num(ev.duration * 1e6)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(r as f64)),
                ("args", Json::obj(args)),
            ]));
        }
    }
    // Lane names: rank index per tid (M metadata events).
    if let Some(mr) = max_rank {
        for r in 0..=mr {
            evs.push(Json::obj(vec![
                ("name", Json::Str("thread_name".to_string())),
                ("ph", Json::Str("M".to_string())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(r as f64)),
                (
                    "args",
                    Json::obj(vec![("name", Json::Str(format!("rank {r}")))]),
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(nodes: usize, accels: usize, overlap: bool) -> StepEngine {
        StepEngine::new(
            Topology::new(nodes, accels),
            NetModel::hpc(),
            ClusterModel::uniform(),
            overlap,
        )
    }

    fn drive(e: &mut StepEngine, steps: usize, with_gather: bool) -> StepTiming {
        let topo = Topology::new(e.topo.nodes, e.topo.accels_per_node);
        let traffic = TrafficMatrix::new(topo.nodes);
        let mut last = StepTiming::default();
        for _ in 0..steps {
            e.begin_step();
            e.unshard(4096, &traffic);
            e.compute(1e9);
            e.reduce_scatter(4096);
            if with_gather {
                for a in 0..topo.accels_per_node {
                    let group: Vec<usize> = (0..topo.nodes).map(|n| topo.rank(n, a)).collect();
                    let sizes = vec![2048u64; group.len()];
                    e.gather(&group, GatherMode::NaiveAllGather, &sizes, &traffic);
                }
            }
            last = e.end_step();
        }
        last
    }

    #[test]
    fn serialized_now_equals_serialized_accumulator() {
        let mut e = engine(2, 2, false);
        drive(&mut e, 5, true);
        // bit-equality: the event engine under --no-overlap IS the legacy
        // barrier clock.
        assert_eq!(e.now(), e.serialized_time());
    }

    #[test]
    fn overlap_is_never_slower_and_hides_comm() {
        let mut ser = engine(2, 2, false);
        let t_ser = drive(&mut ser, 8, true);
        let mut ovl = engine(2, 2, true);
        let t_ovl = drive(&mut ovl, 8, true);
        assert!(
            ovl.now() <= ser.now() * (1.0 + 1e-12),
            "overlap slower: {} vs {}",
            ovl.now(),
            ser.now()
        );
        // the serialized accumulator upper-bounds the overlapped horizon
        assert!(ovl.now() <= ovl.serialized_time() * (1.0 + 1e-12));
        // serialized mode hides (essentially) nothing; overlap does
        assert!(
            t_ser.hidden_comm <= 1e-9 * ser.now(),
            "serialized hid comm: {t_ser:?}"
        );
        assert!(t_ovl.hidden_comm > 1e-7 * ovl.now(), "{t_ovl:?}");
    }

    #[test]
    fn nic_busy_tap_is_monotone_and_tracks_gather_traffic() {
        let mut e = engine(2, 2, true);
        assert_eq!(e.nic_busy(0), 0.0);
        let mut prev = vec![0.0f64; 4];
        for _ in 0..4 {
            drive(&mut e, 1, true);
            for (r, p) in prev.iter_mut().enumerate() {
                let b = e.nic_busy(r);
                assert!(b >= *p, "rank {r}: cumulative busy went backwards");
                assert_eq!(b, e.timelines().2.busy(r));
                *p = b;
            }
        }
        // gather traffic actually lands on the tap
        assert!(prev.iter().all(|&b| b > 0.0), "no NIC occupancy recorded");
    }

    #[test]
    fn timelines_stay_monotone_across_steps() {
        let mut e = engine(2, 4, true);
        let mut prev = vec![0.0f64; 8];
        let traffic = TrafficMatrix::new(2);
        for _ in 0..6 {
            e.begin_step();
            e.unshard(1024, &traffic);
            e.compute(1e8);
            e.reduce_scatter(1024);
            e.end_step();
            let (c, f, n) = e.timelines();
            for r in 0..8 {
                let t = c.now(r).max(f.now(r)).max(n.now(r));
                assert!(t >= prev[r], "rank {r} went backwards");
                prev[r] = t;
            }
        }
    }

    #[test]
    fn straggler_owns_critical_path() {
        let cluster = ClusterModel {
            slowdown: vec![1.0, 3.0],
            node_inter_bw: vec![],
        };
        let topo = Topology::new(2, 2);
        let mut e = StepEngine::new(topo, NetModel::hpc(), cluster, true);
        drive(&mut e, 4, true);
        let crit = e.critical_rank();
        assert_eq!(topo.node_of(crit), 1, "critical rank {crit} not on straggler node");
        // and the run is strictly slower than the uniform cluster
        let mut u = engine(2, 2, true);
        drive(&mut u, 4, true);
        assert!(e.now() > u.now());
    }

    #[test]
    fn bucketed_schedule_keeps_serialized_parity_and_splits_events() {
        let drive_with = |bucket: u64| {
            let mut e = StepEngine::new(
                Topology::new(2, 2),
                NetModel::hpc(),
                ClusterModel::uniform(),
                true,
            )
            .with_buckets(bucket);
            drive(&mut e, 3, true);
            e
        };
        let whole = drive_with(0);
        let bucketed = drive_with(1024); // shard 4096 B → 4 rs buckets; payload 2048 → 2
        // the serialized accumulator always uses whole-phase durations:
        // bucketing must not perturb the legacy reference clock
        assert_eq!(whole.serialized_time(), bucketed.serialized_time());
        // per-bucket events appear in the last step's schedule
        assert!(bucketed.events.len() > whole.events.len());
        let count = |e: &StepEngine, label: &str| {
            e.events.iter().filter(|ev| ev.label == label).count()
        };
        assert_eq!(count(&bucketed, "reduce-scatter"), 2 * 4); // 2 nodes × 4 buckets
        assert_eq!(count(&bucketed, "naive-gather"), 2 * 2); // 2 groups × 2 buckets
        // the byte split is exact — buckets cover the whole phase
        let bytes = |e: &StepEngine, label: &str| -> u64 {
            e.events.iter().filter(|ev| ev.label == label).map(|ev| ev.bytes).sum()
        };
        assert_eq!(bytes(&bucketed, "reduce-scatter"), bytes(&whole, "reduce-scatter"));
        assert_eq!(bytes(&bucketed, "naive-gather"), bytes(&whole, "naive-gather"));
        // bucket chains carry dependencies (each bucket gates the next)
        assert!(bucketed.events.iter().any(|ev| !ev.deps.is_empty()));
    }

    #[test]
    fn buckets_noop_without_reduce_scatter_progress() {
        // accels=1: no reduce-scatter, so there is nothing to pipeline
        // against — bucketing must fall back to the whole-phase gather
        // instead of serializing α-paying buckets after the backward.
        let drive_with = |bucket: u64| {
            let mut e = StepEngine::new(
                Topology::new(4, 1),
                NetModel::hpc(),
                ClusterModel::uniform(),
                true,
            )
            .with_buckets(bucket);
            let t = drive(&mut e, 4, true);
            (e, t)
        };
        let (whole, tw) = drive_with(0);
        let (bucketed, tb) = drive_with(512); // payload 2048 would split 4×
        assert_eq!(whole.now(), bucketed.now());
        assert_eq!(tw.exposed_comm, tb.exposed_comm);
        assert_eq!(whole.events.len(), bucketed.events.len());
    }

    #[test]
    fn buckets_ignored_when_overlap_off() {
        let mut a = engine(2, 2, false);
        let ta = drive(&mut a, 4, true);
        let mut b = StepEngine::new(
            Topology::new(2, 2),
            NetModel::hpc(),
            ClusterModel::uniform(),
            false,
        )
        .with_buckets(512);
        let tb = drive(&mut b, 4, true);
        // --no-overlap reproduces the legacy barrier clock bit-for-bit,
        // bucket knob or not
        assert_eq!(a.now(), b.now());
        assert_eq!(ta.exposed_comm, tb.exposed_comm);
        assert_eq!(b.now(), b.serialized_time());
    }

    #[test]
    fn bucket_split_is_exact_and_even() {
        assert_eq!(StepEngine::bucket_split(10, 3, 0), 4);
        assert_eq!(StepEngine::bucket_split(10, 3, 1), 3);
        assert_eq!(StepEngine::bucket_split(10, 3, 2), 3);
        for total in [0u64, 1, 7, 4096, 99_999] {
            for m in 1..=8u64 {
                let sum: u64 = (0..m).map(|j| StepEngine::bucket_split(total, m, j)).sum();
                assert_eq!(sum, total, "total={total} m={m}");
            }
        }
    }

    /// Satellite invariant: the deferred (async DiLoCo) lane must leave
    /// `--no-overlap` totals bit-for-bit unchanged — under barriers the
    /// gather is charged at the launch step either way, and the parked
    /// completion slot is never on the critical path.
    #[test]
    fn no_overlap_totals_unchanged_by_deferred_lane() {
        let topo = Topology::new(2, 2);
        let traffic = TrafficMatrix::new(2);
        let mk = || StepEngine::new(topo, NetModel::hpc(), ClusterModel::uniform(), false);
        let mut a = mk();
        let mut b = mk();
        let (mut ta, mut tb) = (StepTiming::default(), StepTiming::default());
        for step in 0..6u64 {
            for e in [&mut a, &mut b] {
                e.begin_step();
                e.unshard(4096, &traffic);
                e.compute(1e9);
                e.reduce_scatter(4096);
            }
            for acc in 0..2 {
                let group: Vec<usize> = (0..2).map(|n| topo.rank(n, acc)).collect();
                let sizes = vec![2048u64; 2];
                if step % 3 == 0 {
                    a.gather(&group, GatherMode::NaiveAllGather, &sizes, &traffic);
                    b.gather_deferred(&group, GatherMode::NaiveAllGather, &sizes, &traffic);
                }
                if step % 3 == 2 {
                    b.sync_arrival(&group);
                }
            }
            ta = a.end_step();
            tb = b.end_step();
        }
        assert_eq!(a.now(), b.now());
        assert_eq!(a.serialized_time(), b.serialized_time());
        assert_eq!(a.now(), a.serialized_time());
        assert_eq!(ta.exposed_comm, tb.exposed_comm);
        assert_eq!(ta.compute_time, tb.compute_time);
    }

    /// The tentpole schedule property: with a gather in flight on the
    /// deferred lane, local steps keep running inside the gather window
    /// (the synchronous lane stalls its next backward on it), and the
    /// arrival S steps later still gates the following backward — so the
    /// whole run ends strictly earlier than blocking at the launch.
    #[test]
    fn deferred_gather_overlaps_local_steps_until_arrival() {
        let topo = Topology::new(2, 1);
        let traffic = TrafficMatrix::new(2);
        let group = [0usize, 1];
        let payload = vec![1_000_000u64; 2];
        let mk = || StepEngine::new(topo, NetModel::throttled(10.0), ClusterModel::uniform(), true);
        let mut sync = mk();
        let mut asy = mk();
        let mut gather_end = 0.0f64;
        for step in 0..4u64 {
            for (e, deferred) in [(&mut sync, false), (&mut asy, true)] {
                e.begin_step();
                e.unshard(4096, &traffic);
                e.compute(1e9);
                e.reduce_scatter(4096);
                if step == 0 {
                    if deferred {
                        e.gather_deferred(&group, GatherMode::NaiveAllGather, &payload, &traffic);
                    } else {
                        e.gather(&group, GatherMode::NaiveAllGather, &payload, &traffic);
                    }
                }
                if step == 2 && deferred {
                    e.sync_arrival(&group);
                }
                e.end_step();
            }
            if step == 0 {
                let ev = asy
                    .events
                    .iter()
                    .find(|ev| ev.label == "async-gather")
                    .expect("deferred gather event with async label");
                gather_end = ev.end();
                assert!(sync.events.iter().any(|ev| ev.label == "naive-gather"));
            }
            if step == 2 {
                let (ac, _, _) = asy.timelines();
                let (sc, _, _) = sync.timelines();
                for r in 0..2 {
                    assert!(
                        ac.now(r) < gather_end,
                        "async rank {r} stalled on the in-flight sync"
                    );
                    assert!(sc.now(r) > gather_end, "sync rank {r} did not wait for it");
                }
            }
        }
        // the arrival fed update_visible: the step-3 backward ran after
        // the gather landed, yet the run beats the blocking schedule.
        let (ac, _, _) = asy.timelines();
        assert!(ac.now(0) > gather_end);
        assert!(
            asy.now() < sync.now(),
            "deferred lane should beat blocking at the launch: {} vs {}",
            asy.now(),
            sync.now()
        );
    }

    /// Tentpole: the straggler-tolerant lanes. Each member's async-gather
    /// event starts at its *own* reduce-scatter completion (the fast
    /// member launches while the straggler is still computing), finishes
    /// independently, and carries the owning sender node for
    /// `--trace-out`. Admission is per member: `sync_arrival_member`
    /// gates only with the completion time the trainer aggregated.
    #[test]
    fn per_member_deferred_lanes_launch_early_and_carry_owner_node() {
        let topo = Topology::new(2, 1);
        let cluster = ClusterModel {
            slowdown: vec![1.0, 4.0],
            node_inter_bw: vec![],
        };
        let mut e = StepEngine::new(topo, NetModel::throttled(10.0), cluster, true);
        let traffic = TrafficMatrix::new(2);
        let group = [0usize, 1];
        let payload = vec![1_000_000u64; 2];
        e.begin_step();
        e.unshard(4096, &traffic);
        e.compute(1e9);
        e.reduce_scatter(4096);
        let ends = e.gather_deferred_per_member(
            &group,
            GatherMode::NaiveAllGather,
            &payload,
            &traffic,
            None,
        );
        e.end_step();
        let evs: Vec<CommEvent> = e
            .events
            .iter()
            .filter(|ev| ev.label == "async-gather")
            .cloned()
            .collect();
        assert_eq!(evs.len(), 2, "one event per member");
        assert_eq!(evs[0].node, Some(0));
        assert_eq!(evs[1].node, Some(1));
        assert_eq!(evs[0].ranks, vec![0]);
        // the fast member's send starts at its rs completion, long before
        // the 4× straggler's, and finishes first
        assert!(evs[0].start < evs[1].start, "{evs:?}");
        assert!(ends[0] < ends[1], "{ends:?}");
        // serialize: per-member events surface their owner in args
        let rows: Vec<(u64, CommEvent)> = evs.iter().map(|ev| (0u64, ev.clone())).collect();
        let doc = chrome_trace_json(&rows, 1);
        let tr = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let owners: Vec<u64> = tr
            .iter()
            .filter(|j| j.get("ph").unwrap().as_str() == Some("X"))
            .map(|j| j.get("args").unwrap().get("owner_node").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(owners, vec![0, 1]);

        // Per-member admission: gating rank 0 with only the fast
        // contribution leaves it free of the straggler's late send.
        e.sync_arrival_member(0, ends[0]);
        e.begin_step();
        e.unshard(4096, &traffic);
        e.compute(1e9);
        e.reduce_scatter(4096);
        e.end_step();
        let (c, _, _) = e.timelines();
        assert!(
            c.now(0) < ends[1],
            "rank 0 stalled on the straggler's contribution: {} vs {}",
            c.now(0),
            ends[1]
        );
        // the deadline accessor is the backward end — an admitted
        // contribution (end <= deadline) can never stall its admitter
        assert!(e.arrival_deadline(0) <= c.now(0));
    }

    /// The per-member lanes price the same bytes as the whole-group
    /// event on a uniform cluster (slowest member == whole-phase naive
    /// gather), so the serialized reference is unchanged; and under
    /// `--no-overlap` the barriered lane maximum still equals the
    /// serialized accumulator.
    #[test]
    fn per_member_deferred_matches_whole_phase_cost_on_uniform_cluster() {
        let topo = Topology::new(2, 1);
        let traffic = TrafficMatrix::new(2);
        let group = [0usize, 1];
        let payload = vec![500_000u64; 2];
        let mk = |overlap| {
            StepEngine::new(topo, NetModel::throttled(50.0), ClusterModel::uniform(), overlap)
        };
        let drive = |e: &mut StepEngine, per_member: bool| {
            for _ in 0..3 {
                e.begin_step();
                e.unshard(4096, &traffic);
                e.compute(1e9);
                e.reduce_scatter(4096);
                if per_member {
                    let ends = e.gather_deferred_per_member(
                        &group,
                        GatherMode::NaiveAllGather,
                        &payload,
                        &traffic,
                        None,
                    );
                    e.sync_arrival_member(0, ends[1]);
                    e.sync_arrival_member(1, ends[0]);
                } else {
                    e.gather_deferred(&group, GatherMode::NaiveAllGather, &payload, &traffic);
                    e.sync_arrival(&group);
                }
                e.end_step();
            }
        };
        let mut whole = mk(true);
        drive(&mut whole, false);
        let mut member = mk(true);
        drive(&mut member, true);
        // same serialized accounting (the slowest member IS the phase)
        assert_eq!(whole.serialized_time(), member.serialized_time());
        // no-overlap: barriers keep now == serialized with per-member lanes
        let mut ser = mk(false);
        drive(&mut ser, true);
        assert_eq!(ser.now(), ser.serialized_time());
    }

    /// Elastic membership at the engine level: an all-true mask is the
    /// identity (bit-equal schedule), an inactive node's lanes freeze at
    /// departure, and the surviving nodes' schedule is exactly the
    /// smaller cluster's.
    #[test]
    fn membership_mask_identity_and_freeze() {
        let mut plain = engine(2, 2, true);
        let mut masked = engine(2, 2, true);
        masked.set_active(&[true, true]);
        drive(&mut plain, 4, true);
        drive(&mut masked, 4, true);
        assert_eq!(plain.now(), masked.now());
        assert_eq!(plain.serialized_time(), masked.serialized_time());

        // deactivate node 1: its lanes freeze, node 0 keeps moving
        let frozen = {
            let (c, f, n) = masked.timelines();
            (2..4).map(|r| c.now(r).max(f.now(r)).max(n.now(r))).collect::<Vec<_>>()
        };
        masked.set_active(&[true, false]);
        let traffic = TrafficMatrix::new(2);
        for _ in 0..3 {
            masked.begin_step();
            masked.unshard(4096, &traffic);
            masked.compute(1e9);
            masked.reduce_scatter(4096);
            // group re-formed to the single surviving member
            masked.gather(&[0], GatherMode::NaiveAllGather, &[2048], &traffic);
            masked.end_step();
        }
        let (c, f, n) = masked.timelines();
        for (i, r) in (2..4).enumerate() {
            assert_eq!(
                c.now(r).max(f.now(r)).max(n.now(r)),
                frozen[i],
                "inactive rank {r} lanes moved"
            );
        }
        assert!(c.now(0) > frozen[0]);
        // inactive ranks never win the critical path
        assert!(masked.critical_rank() < 2);
    }

    /// Join broadcast: gates the joiner's next backward, charges the
    /// serialized reference, and `--no-overlap` keeps `now() ==
    /// serialized_time()` through a leave/join cycle.
    #[test]
    fn join_broadcast_gates_joiner_and_keeps_serialized_parity() {
        for overlap in [true, false] {
            let mut e = engine(2, 1, overlap);
            let traffic = TrafficMatrix::new(2);
            let drive_step = |e: &mut StepEngine, with_node1: bool| {
                e.begin_step();
                e.unshard(4096, &traffic);
                e.compute(1e9);
                e.reduce_scatter(4096);
                let group: Vec<usize> = if with_node1 { vec![0, 1] } else { vec![0] };
                let sizes = vec![2048u64; group.len()];
                e.gather(&group, GatherMode::NaiveAllGather, &sizes, &traffic);
                e.end_step();
            };
            drive_step(&mut e, true);
            e.set_active(&[true, false]);
            drive_step(&mut e, false);
            let frozen = e.rank_end(1);
            e.set_active(&[true, true]);
            e.join_broadcast(1, 1 << 20, &traffic);
            assert!(e.events.iter().any(|ev| ev.label == "join-broadcast"));
            // the broadcast moved the joiner's lanes and gates its backward
            assert!(e.rank_end(1) > frozen);
            let visible = e.rank_end(1);
            drive_step(&mut e, true);
            let (c, _, _) = e.timelines();
            assert!(c.now(1) >= visible, "joiner's backward ran before the params landed");
            if !overlap {
                assert_eq!(e.now(), e.serialized_time());
            }
            // traffic flowed anchor → joiner
            assert!(traffic.snapshot()[1] >= 1 << 20);
        }
    }

    /// Checkpoint surface: export → import on a fresh engine, then drive
    /// both identically — bit-equal horizons and serialized clocks.
    #[test]
    fn engine_state_roundtrip_continues_bit_identically() {
        let mut a = engine(2, 2, true);
        drive(&mut a, 3, true);
        let mut b = engine(2, 2, true);
        b.import_state(a.export_state()).unwrap();
        assert_eq!(a.now(), b.now());
        drive(&mut a, 3, true);
        drive(&mut b, 3, true);
        assert_eq!(a.now(), b.now());
        assert_eq!(a.serialized_time(), b.serialized_time());
        let (ac, af, an) = a.timelines();
        let (bc, bf, bn) = b.timelines();
        for r in 0..4 {
            assert_eq!(ac.now(r), bc.now(r));
            assert_eq!(af.now(r), bf.now(r));
            assert_eq!(an.now(r), bn.now(r));
        }
        // world-size mismatch is rejected
        assert!(engine(2, 1, true).import_state(a.export_state()).is_err());
    }

    #[test]
    fn events_carry_schedule_and_deps() {
        let mut e = engine(2, 2, true);
        drive(&mut e, 2, true);
        assert!(!e.events.is_empty());
        // per-step events: 2 unshard + 2 reduce-scatter + 2 gathers
        assert_eq!(e.events.len(), 6);
        let labels: Vec<&str> = e.events.iter().map(|ev| ev.label).collect();
        assert!(labels.contains(&"all-gather"));
        assert!(labels.contains(&"reduce-scatter"));
        assert!(labels.contains(&"naive-gather"));
        for ev in &e.events {
            assert!(ev.duration > 0.0);
            assert!(ev.end() >= ev.start);
        }
        // the second step's events depend on the first step's (ids exist)
        assert!(e.events.iter().any(|ev| !ev.deps.is_empty()));
    }

    #[test]
    fn events_carry_ranks_and_serialize_to_chrome_trace() {
        let mut e = engine(2, 2, true);
        drive(&mut e, 2, true);
        // scheduled events know their participants
        for ev in &e.events {
            assert!(!ev.ranks.is_empty(), "{} has no ranks", ev.label);
            assert!(ev.ranks.iter().all(|&r| r < 4));
        }
        let rows: Vec<(u64, CommEvent)> =
            e.events.iter().map(|ev| (1u64, ev.clone())).collect();
        let doc = chrome_trace_json(&rows, 2);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // one X event per (event, rank) + one M lane-name event per rank
        let n_x: usize = rows.iter().map(|(_, ev)| ev.ranks.len()).sum();
        assert_eq!(evs.len(), n_x + 4);
        let x0 = evs
            .iter()
            .find(|j| j.get("ph").unwrap().as_str() == Some("X"))
            .unwrap();
        assert!(x0.get("ts").is_some() && x0.get("dur").is_some());
        assert_eq!(x0.get("args").unwrap().get("step").unwrap().as_u64(), Some(1));
        // every lane row carries its node (tid → node via accels_per_node)
        for j in evs {
            if j.get("ph").unwrap().as_str() == Some("X") {
                let tid = j.get("tid").unwrap().as_u64().unwrap();
                assert_eq!(j.get("args").unwrap().get("node").unwrap().as_u64(), Some(tid / 2));
            }
        }
        // document round-trips through the JSON parser
        let text = doc.to_string_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    fn fault_lane(spec: &str) -> FaultLane {
        let mut timeline = FaultTimeline::new();
        timeline.add_spec(spec).unwrap();
        FaultLane {
            timeline,
            seed: 0xFA117,
            max_retries: 3,
            retry_timeout: 0.1,
            retry_backoff: 0.05,
        }
    }

    fn drive_per_member(e: &mut StepEngine, step: u64) -> Vec<SimTime> {
        let traffic = TrafficMatrix::new(2);
        e.set_fault_step(step);
        e.begin_step();
        e.unshard(4096, &traffic);
        e.compute(1e9);
        e.reduce_scatter(4096);
        let ends = e.gather_deferred_per_member(
            &[0, 1],
            GatherMode::NaiveAllGather,
            &[500_000, 500_000],
            &traffic,
            None,
        );
        e.end_step();
        ends
    }

    /// Tentpole: an always-dropping link exhausts the retry budget —
    /// every attempt is a real NIC reservation with timeout + capped
    /// backoff between attempts, retries carry the `retry-gather` trace
    /// label, and the exhausted sender's completion is +∞ (the trainer's
    /// late-arrival fallback), while the healthy sender is untouched.
    #[test]
    fn fault_lane_retries_then_falls_back_to_infinity() {
        let topo = Topology::new(2, 1);
        let mk = || StepEngine::new(topo, NetModel::throttled(50.0), ClusterModel::uniform(), true);
        let mut e = mk().with_faults(fault_lane("drop:0-1@p1"));
        let ends = drive_per_member(&mut e, 0);
        assert!(ends[0].is_infinite(), "dead link delivered: {ends:?}");
        assert!(ends[1].is_finite(), "healthy sender caught the fault");
        let mf = e.last_member_faults()[0];
        assert!(!mf.delivered);
        assert_eq!(mf.retries, 3);
        assert_eq!(e.last_member_faults()[1].retries, 0);
        assert!(e.last_member_faults()[1].delivered);
        assert_eq!(e.step_fault_counts(), (3, 0));
        // attempt 0 keeps the async-gather label; retries are marked
        let retries: Vec<&CommEvent> =
            e.events.iter().filter(|ev| ev.label == "retry-gather").collect();
        assert_eq!(retries.len(), 3);
        assert!(retries.iter().all(|ev| ev.node == Some(0) && ev.ranks == vec![0]));
        // backoff: gaps between consecutive attempts grow (capped exp)
        let mut attempts: Vec<&CommEvent> = e
            .events
            .iter()
            .filter(|ev| {
                ev.node == Some(0) && (ev.label == "async-gather" || ev.label == "retry-gather")
            })
            .collect();
        attempts.sort_by(|a, b| a.start.total_cmp(&b.start));
        assert_eq!(attempts.len(), 4);
        let gap = |i: usize| attempts[i + 1].start - attempts[i].end();
        assert!(gap(1) > gap(0), "backoff not growing: {} vs {}", gap(1), gap(0));
        // fixed seed → bit-reproducible schedule
        let mut f = mk().with_faults(fault_lane("drop:0-1@p1"));
        let ends2 = drive_per_member(&mut f, 0);
        assert_eq!(ends[1].to_bits(), ends2[1].to_bits());
        assert_eq!(e.now().to_bits(), f.now().to_bits());
    }

    /// The fault-free spec is the identity: an empty timeline is
    /// normalized away and the schedule is bit-identical to an engine
    /// that never heard of faults; corrupt-only links deliver after
    /// retries (numerics unaffected, only sim-time paid); degraded links
    /// stretch every attempt.
    #[test]
    fn fault_free_identity_corrupt_retries_and_degrade_stretch() {
        let topo = Topology::new(2, 1);
        let mk = || StepEngine::new(topo, NetModel::throttled(50.0), ClusterModel::uniform(), true);
        let mut plain = mk();
        let base = drive_per_member(&mut plain, 0);
        let mut empty = mk().with_faults(FaultLane {
            timeline: FaultTimeline::new(),
            seed: 1,
            max_retries: 3,
            retry_timeout: 0.1,
            retry_backoff: 0.05,
        });
        let ends = drive_per_member(&mut empty, 0);
        assert_eq!(base[0].to_bits(), ends[0].to_bits());
        assert_eq!(plain.now().to_bits(), empty.now().to_bits());
        assert!(empty.last_member_faults().iter().all(|m| m.delivered && m.retries == 0));

        // corrupt p=1: every pre-delivery attempt corrupts; with the
        // retry budget it still exhausts (checksum rejects each copy)
        let mut cor = mk().with_faults(fault_lane("corrupt:0-1@p1"));
        let cends = drive_per_member(&mut cor, 0);
        assert!(cends[0].is_infinite());
        let mf = cor.last_member_faults()[0];
        assert_eq!(mf.corrupt, 4, "all four attempts delivered garbage");
        assert_eq!(cor.step_fault_counts().1, 4);

        // degrade 0.25x: attempt duration stretches 4×, delivered first try
        let mut deg = mk().with_faults(fault_lane("degrade:0-*@0.25x"));
        let dends = drive_per_member(&mut deg, 0);
        assert!(deg.last_member_faults()[0].delivered);
        assert_eq!(deg.last_member_faults()[0].retries, 0);
        assert!(dends[0] > base[0], "degraded link not slower");
        let ev0 = deg
            .events
            .iter()
            .find(|ev| ev.label == "async-gather" && ev.node == Some(0))
            .unwrap();
        let evb = plain
            .events
            .iter()
            .find(|ev| ev.label == "async-gather" && ev.node == Some(0))
            .unwrap();
        assert!((ev0.duration / evb.duration - 4.0).abs() < 1e-9);

        // a flap window drops unconditionally inside, heals outside
        let mut flap = mk().with_faults(fault_lane("flap:0-1@1..2"));
        let f0 = drive_per_member(&mut flap, 0);
        assert!(f0[0].is_finite(), "link down before the flap window");
        let f1 = drive_per_member(&mut flap, 1);
        assert!(f1[0].is_infinite(), "link up inside the flap window");
        let f2 = drive_per_member(&mut flap, 2);
        assert!(f2[0].is_finite(), "link down after the flap window");
    }

    /// `--no-overlap` parity holds through the retry lane: the serialized
    /// accumulator charges each chain's barriered lane span, so
    /// `now() == serialized_time()` even with a flaky link retrying.
    #[test]
    fn fault_retries_keep_no_overlap_serialized_parity() {
        let topo = Topology::new(2, 1);
        let mut e = StepEngine::new(topo, NetModel::throttled(50.0), ClusterModel::uniform(), false)
            .with_faults(fault_lane("drop:0-1@p0.7,corrupt:1-0@p0.4"));
        for step in 0..5 {
            drive_per_member(&mut e, step);
        }
        assert_eq!(e.now(), e.serialized_time());
    }
}
