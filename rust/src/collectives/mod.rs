//! Collective communication over the simulated cluster.
//!
//! Real data movement (numerics are exact — divergence across ranks is the
//! phenomenon under study) + α–β cost accounting per algorithm
//! (DESIGN.md §2). Two algorithm families, matching what the paper's stack
//! uses:
//!
//! * **Ring** reduce-scatter / all-gather / all-reduce — what
//!   FSDP/NCCL/RCCL use. Per-rank wire volume `(g-1)/g · N`, i.e. nearly
//!   size-independent of group size — these *scale*.
//! * **Naive (blocking) all-gather** of opaque payloads — what DeMo's
//!   replication uses (`dist.all_gather` of compressed components). Every
//!   rank sends its payload to every other: received volume `(g-1)·B`
//!   grows linearly with the group — the paper's Fig 6 "DeMo does not
//!   scale" mechanism falls straight out of this cost model.
//!
//! ## Cost events
//!
//! Every collective's α–β cost is described by a [`CommEvent`] — start,
//! duration, link class, wire bytes, dependency ids — built by the
//! `*_event` constructors below. The legacy scalar entry points still
//! return an elapsed `SimTime` (callers under `--no-overlap` advance a
//! barrier clock by the max across groups); the event engine in
//! `train::engine` instead schedules the same events onto per-rank NIC
//! timelines so communication can hide behind compute. Both paths share
//! one duration formula per algorithm, so serialized totals are identical
//! bit-for-bit between the old and new clocks.

//! ## Data plane
//!
//! The *data* side of every collective (the real averaging/copying) runs
//! chunk-parallel on the caller's [`crate::parallel::WorkerPool`] over
//! the fixed grid, staging accumulators and shards through the
//! [`CollScratch`] arena threaded via [`CollCtx`] — so the steady state
//! performs zero heap allocations (asserted in `benches/kernels.rs`) and
//! is bit-identical to the scalar reference at any `--threads N`
//! (prop-tested below).

use crate::net::{LinkClass, NetModel, SimTime, Topology, TrafficMatrix};
use crate::parallel::{self, SlicePtr, WorkerPool};

/// One collective's cost description: what moves, over which link class,
/// how long it occupies the participants' NICs once started, and (after
/// scheduling) when it starts and which earlier events gated it.
#[derive(Clone, Debug, PartialEq)]
pub struct CommEvent {
    /// Engine-assigned id (0 until scheduled); `deps` entries refer to
    /// these ids, so a dependency graph survives across steps.
    pub id: u64,
    /// Algorithm tag ("reduce-scatter", "all-gather", "naive-gather", ...).
    pub label: &'static str,
    pub class: LinkClass,
    /// Cost-bearing per-rank wire volume (the bytes the busiest NIC moves).
    pub bytes: u64,
    /// α–β duration once started.
    pub duration: SimTime,
    /// Scheduled start time (0 until a scheduler places the event).
    pub start: SimTime,
    /// Ids of the events whose completion gated this start.
    pub deps: Vec<u64>,
    /// Participating ranks (empty until scheduled; the engine fills it —
    /// Chrome-trace lanes map one tid per rank).
    pub ranks: Vec<usize>,
    /// Owning node for events that belong to a single sender (the
    /// straggler-tolerant per-member async gather lanes); `None` for
    /// whole-group collectives. Surfaces as `owner_node` in
    /// `--trace-out` args so parked gathers are attributable.
    pub node: Option<u64>,
}

impl CommEvent {
    pub fn new(label: &'static str, class: LinkClass, bytes: u64, duration: SimTime) -> CommEvent {
        CommEvent {
            id: 0,
            label,
            class,
            bytes,
            duration,
            start: 0.0,
            deps: Vec::new(),
            ranks: Vec::new(),
            node: None,
        }
    }

    /// Builder: tag this event with its owning (sender) node.
    pub fn owned_by(mut self, node: usize) -> CommEvent {
        self.node = Some(node as u64);
        self
    }

    pub fn end(&self) -> SimTime {
        self.start + self.duration
    }

    /// Fill in scheduling results (used by the event engine).
    pub fn scheduled(mut self, start: SimTime, deps: Vec<u64>) -> CommEvent {
        self.start = start;
        self.deps = deps;
        self
    }
}

/// An effective point-to-point link: class + α + β. Heterogeneous
/// clusters (per-node NIC overrides) inject a reduced `bw` here; the
/// homogeneous case is `Link::of(model, class)`.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    pub class: LinkClass,
    pub lat: f64,
    pub bw: f64,
}

impl Link {
    pub fn of(model: &NetModel, class: LinkClass) -> Link {
        Link {
            class,
            lat: model.lat(class),
            bw: model.bw(class),
        }
    }

    /// α–β time of one message (identical formula to `NetModel::xfer_time`).
    pub fn xfer(&self, bytes: u64) -> SimTime {
        self.lat + bytes as f64 / self.bw
    }
}

/// Ring reduce-scatter cost: (g−1) steps of the largest shard.
pub fn ring_reduce_scatter_event(link: &Link, g: usize, max_shard_bytes: u64) -> CommEvent {
    let dur = if g <= 1 {
        0.0
    } else {
        (g as f64 - 1.0) * link.xfer(max_shard_bytes)
    };
    let bytes = if g <= 1 { 0 } else { (g as u64 - 1) * max_shard_bytes };
    CommEvent::new("reduce-scatter", link.class, bytes, dur)
}

/// Ring all-gather cost: same wire shape as reduce-scatter.
pub fn ring_all_gather_event(link: &Link, g: usize, max_shard_bytes: u64) -> CommEvent {
    let dur = if g <= 1 {
        0.0
    } else {
        (g as f64 - 1.0) * link.xfer(max_shard_bytes)
    };
    let bytes = if g <= 1 { 0 } else { (g as u64 - 1) * max_shard_bytes };
    CommEvent::new("all-gather", link.class, bytes, dur)
}

/// Ring all-reduce cost over a dense buffer of `total_bytes`:
/// reduce-scatter + all-gather, each (g−1) steps of `total_bytes/g`.
pub fn ring_all_reduce_event(link: &Link, g: usize, total_bytes: u64) -> CommEvent {
    if g <= 1 {
        return CommEvent::new("all-reduce", link.class, 0, 0.0);
    }
    let chunk = total_bytes / g as u64;
    let dur = 2.0 * (g as f64 - 1.0) * link.xfer(chunk);
    CommEvent::new("all-reduce", link.class, 2 * (g as u64 - 1) * chunk, dur)
}

/// Naive blocking all-gather cost (DeMo's `dist.all_gather` of opaque
/// payloads): each rank serializes (g−1) sends of its payload on its own
/// NIC; the event lasts as long as the worst rank's send queue. The
/// repeated-addition form is kept deliberately — it is bit-identical to
/// the legacy accounting.
pub fn naive_all_gather_event(link: &Link, payload_bytes: &[u64]) -> CommEvent {
    let g = payload_bytes.len();
    if g <= 1 {
        return CommEvent::new("naive-gather", link.class, 0, 0.0);
    }
    let mut worst: SimTime = 0.0;
    let mut worst_bytes = 0u64;
    for (i, &bytes_i) in payload_bytes.iter().enumerate() {
        let mut t_send: SimTime = 0.0;
        for j in 0..g {
            if i != j {
                t_send += link.xfer(bytes_i);
            }
        }
        if t_send > worst {
            worst = t_send;
            worst_bytes = (g as u64 - 1) * bytes_i;
        }
    }
    CommEvent::new("naive-gather", link.class, worst_bytes, worst)
}

/// Tree broadcast cost: ⌈log2 g⌉ rounds of the full buffer.
pub fn broadcast_event(link: &Link, g: usize, bytes: u64) -> CommEvent {
    if g <= 1 {
        return CommEvent::new("broadcast", link.class, 0, 0.0);
    }
    let rounds = (g as f64).log2().ceil();
    CommEvent::new("broadcast", link.class, bytes, rounds * link.xfer(bytes))
}

/// Record the neighbor traffic of a ring pass (`msgs_per_link` messages of
/// `bytes` from every group member to its ring successor).
pub fn record_ring_traffic(
    traffic: &TrafficMatrix,
    topo: &Topology,
    group: &[usize],
    msgs_per_link: usize,
    bytes: u64,
) {
    let g = group.len();
    if g <= 1 {
        return;
    }
    for i in 0..g {
        for _ in 0..msgs_per_link {
            traffic.record(
                topo.node_of(group[i]),
                topo.node_of(group[(i + 1) % g]),
                bytes,
            );
        }
    }
}

/// Reusable workspace for the collectives' data plane: the mean
/// accumulator plus the lifetime-erased buffer-pointer list the
/// chunk-parallel kernels fan out over. One
/// instance per trainer (threaded via [`CollCtx`]); after one warm-up
/// step every buffer is at steady-state capacity and no collective call
/// allocates.
#[derive(Debug, Default)]
pub struct CollScratch {
    /// Elementwise-mean accumulator (whole-buffer sized).
    acc: Vec<f32>,
    /// Per-call lifetime-erased buffer views (cleared before each call
    /// returns; only the capacity persists).
    ptrs: Vec<SlicePtr<f32>>,
}

impl CollScratch {
    pub fn new() -> CollScratch {
        CollScratch::default()
    }
}

/// Context threaded through every collective call: topology + cost
/// model + traffic accounting, plus the worker pool the data plane runs
/// on and the scratch arena it stages through.
pub struct CollCtx<'a> {
    pub topo: &'a Topology,
    pub model: &'a NetModel,
    pub traffic: &'a TrafficMatrix,
    pub pool: &'a WorkerPool,
    pub scratch: &'a mut CollScratch,
}

impl<'a> CollCtx<'a> {
    /// Record `bytes` flowing rank→rank and return nothing; time is
    /// accounted by the calling algorithm.
    fn record(&self, src: usize, dst: usize, bytes: u64) {
        self.traffic
            .record(self.topo.node_of(src), self.topo.node_of(dst), bytes);
    }

    fn class(&self, group: &[usize]) -> LinkClass {
        self.topo.group_link_class(group)
    }
}

/// Stash lifetime-erased views of every buffer in the scratch pointer
/// list (capacity reused across calls; cleared before return-by-use).
fn buf_ptrs<'a>(ptrs: &'a mut Vec<SlicePtr<f32>>, bufs: &mut [&mut [f32]]) -> &'a [SlicePtr<f32>] {
    ptrs.clear();
    ptrs.extend(bufs.iter_mut().map(|b| SlicePtr::new(b)));
    ptrs
}

/// Shard ranges must be ascending and pairwise disjoint — the
/// chunk-parallel data plane writes them concurrently, so this is a
/// soundness precondition (hard assert, O(g)); every real layout
/// (`ShardSpec::even`) satisfies it.
fn assert_disjoint(shards: &[(usize, usize)]) {
    assert!(
        shards.windows(2).all(|w| w[0].1 <= w[1].0),
        "shard ranges must be ascending and disjoint: {shards:?}"
    );
}

/// Ring all-reduce (average) over `bufs[i]` belonging to `group[i]`.
/// Every buffer ends up holding the element-wise mean. Data plane runs
/// chunk-parallel on `ctx.pool`, staging through `ctx.scratch` — zero
/// steady-state allocations, bit-identical at any worker count.
pub fn ring_all_reduce_avg(
    ctx: &mut CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Semantics: mean into every buffer. Per element the accumulation
    // order over `bufs` matches the scalar sweep exactly.
    {
        let CollScratch { acc, ptrs, .. } = &mut *ctx.scratch;
        acc.clear();
        acc.resize(n, 0.0);
        let accp = SlicePtr::new(acc);
        let bp = buf_ptrs(&mut *ptrs, bufs);
        let inv = 1.0 / g as f32;
        parallel::run_chunks(ctx.pool, n, |_w, lo, hi| {
            // Safety: grid chunks are disjoint; every access below stays
            // inside this task's [lo, hi).
            let a = unsafe { accp.range(lo, hi) };
            for p in bp {
                crate::tensor::axpy(a, 1.0, unsafe { p.range(lo, hi) });
            }
            parallel::lanes::scale(a, inv);
            for p in bp {
                unsafe { p.range(lo, hi) }.copy_from_slice(a);
            }
        });
        ptrs.clear();
    }

    // Cost: ring all-reduce = reduce-scatter + all-gather, each (g-1)
    // steps of N/g elements; record ring-neighbor traffic.
    let chunk_bytes = (n * 4 / g) as u64;
    record_ring_traffic(ctx.traffic, ctx.topo, group, 2 * (g - 1), chunk_bytes);
    let class = ctx.class(group);
    ring_all_reduce_event(&Link::of(ctx.model, class), g, (n * 4) as u64).duration
}

/// Ring reduce-scatter (average): after the call, `bufs[i]` holds the mean
/// in its own shard range `[shards[i].0, shards[i].1)`; other regions are
/// left untouched (FSDP only guarantees the owned shard). Chunk-parallel
/// + scratch-staged like [`ring_all_reduce_avg`].
pub fn ring_reduce_scatter_avg(
    ctx: &mut CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    shards: &[(usize, usize)],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    assert_eq!(group.len(), shards.len());
    assert_disjoint(shards);
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Mean of each shard region into its owner: each grid chunk handles
    // the overlap with every shard range it intersects.
    {
        let CollScratch { acc, ptrs, .. } = &mut *ctx.scratch;
        acc.clear();
        acc.resize(n, 0.0);
        let accp = SlicePtr::new(acc);
        let bp = buf_ptrs(&mut *ptrs, bufs);
        let inv = 1.0 / g as f32;
        parallel::run_chunks(ctx.pool, n, |_w, clo, chi| {
            for (i, &(slo, shi)) in shards.iter().enumerate() {
                let (lo, hi) = (clo.max(slo), chi.min(shi));
                if lo >= hi {
                    continue;
                }
                // Safety: (chunk ∩ shard) regions are pairwise disjoint
                // across tasks and across shards.
                let a = unsafe { accp.range(lo, hi) };
                for p in bp {
                    crate::tensor::axpy(a, 1.0, unsafe { p.range(lo, hi) });
                }
                parallel::lanes::scale(a, inv);
                unsafe { bp[i].range(lo, hi) }.copy_from_slice(a);
            }
        });
        ptrs.clear();
    }

    let max_shard_bytes = shards.iter().map(|&(lo, hi)| (hi - lo) * 4).max().unwrap() as u64;
    record_ring_traffic(ctx.traffic, ctx.topo, group, g - 1, max_shard_bytes);
    let class = ctx.class(group);
    ring_reduce_scatter_event(&Link::of(ctx.model, class), g, max_shard_bytes).duration
}

/// Ring all-gather: rank i contributes `bufs[i][shards[i]]`; afterwards
/// every buffer holds every shard (i.e. the full vector). Chunk-parallel
/// owner→peers copies; no shard staging clones.
pub fn ring_all_gather(
    ctx: &mut CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    shards: &[(usize, usize)],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    assert_disjoint(shards);
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Copy every shard from its owner into all peers, chunk-parallel.
    {
        let ptrs = &mut ctx.scratch.ptrs;
        let bp = buf_ptrs(&mut *ptrs, bufs);
        parallel::run_chunks(ctx.pool, n, |_w, clo, chi| {
            for (i, &(slo, shi)) in shards.iter().enumerate() {
                let (lo, hi) = (clo.max(slo), chi.min(shi));
                if lo >= hi {
                    continue;
                }
                // Safety: disjoint (chunk ∩ shard) regions per task; the
                // owner's region is read-only here, peers are written.
                let src: &[f32] = unsafe { bp[i].range(lo, hi) };
                for (j, p) in bp.iter().enumerate() {
                    if j != i {
                        unsafe { p.range(lo, hi) }.copy_from_slice(src);
                    }
                }
            }
        });
        ptrs.clear();
    }

    let max_shard_bytes = shards.iter().map(|&(lo, hi)| (hi - lo) * 4).max().unwrap() as u64;
    record_ring_traffic(ctx.traffic, ctx.topo, group, g - 1, max_shard_bytes);
    let class = ctx.class(group);
    ring_all_gather_event(&Link::of(ctx.model, class), g, max_shard_bytes).duration
}

/// Naive blocking all-gather of opaque payloads (DeMo's replication
/// primitive). Returns (gathered payloads in group order, elapsed time).
/// Received volume per rank is `Σ_{j≠i} bytes_j` — linear in group size.
pub fn naive_all_gather_bytes<T: Clone>(
    ctx: &mut CollCtx,
    group: &[usize],
    payloads: &[(T, u64)],
) -> (Vec<T>, SimTime) {
    assert_eq!(group.len(), payloads.len());
    let g = group.len();
    let gathered: Vec<T> = payloads.iter().map(|(p, _)| p.clone()).collect();
    if g <= 1 {
        return (gathered, 0.0);
    }
    let class = ctx.class(group);
    for (i, &(_, bytes_i)) in payloads.iter().enumerate() {
        // rank i sends its payload to every peer (blocking, serialized on
        // its NIC — the paper's non-scaling mechanism).
        for (j, _) in group.iter().enumerate() {
            if i != j {
                ctx.record(group[i], group[j], bytes_i);
            }
        }
    }
    let sizes: Vec<u64> = payloads.iter().map(|&(_, b)| b).collect();
    let ev = naive_all_gather_event(&Link::of(ctx.model, class), &sizes);
    (gathered, ev.duration)
}

/// Broadcast `src_buf` (group index `src`) into every buffer (tree cost).
/// Chunk-parallel src→peers copies; no staging clone.
pub fn broadcast(
    ctx: &mut CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    src: usize,
) -> SimTime {
    let g = group.len();
    assert!(src < g);
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[src].len();
    {
        let ptrs = &mut ctx.scratch.ptrs;
        let bp = buf_ptrs(&mut *ptrs, bufs);
        parallel::run_chunks(ctx.pool, n, |_w, lo, hi| {
            // Safety: disjoint grid chunks; src is read-only, peers written.
            let data: &[f32] = unsafe { bp[src].range(lo, hi) };
            for (i, p) in bp.iter().enumerate() {
                if i != src {
                    unsafe { p.range(lo, hi) }.copy_from_slice(data);
                }
            }
        });
        ptrs.clear();
    }
    let bytes = (n * 4) as u64;
    for (j, _) in group.iter().enumerate() {
        if j != src {
            ctx.record(group[src], group[j], bytes);
        }
    }
    let class = ctx.class(group);
    broadcast_event(&Link::of(ctx.model, class), g, bytes).duration
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetModel, Topology, TrafficMatrix};
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};

    fn ctx<'a>(
        topo: &'a Topology,
        model: &'a NetModel,
        traffic: &'a TrafficMatrix,
        scratch: &'a mut CollScratch,
    ) -> CollCtx<'a> {
        CollCtx {
            topo,
            model,
            traffic,
            pool: WorkerPool::inline(),
            scratch,
        }
    }

    fn even_shards(n: usize, g: usize) -> Vec<(usize, usize)> {
        (0..g).map(|i| (i * n / g, (i + 1) * n / g)).collect()
    }

    #[test]
    fn all_reduce_averages() {
        let topo = Topology::new(2, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(2);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut a = vec![1.0f32, 2.0];
        let mut b = vec![3.0f32, 6.0];
        let t = ring_all_reduce_avg(&mut c, &[0, 1], &mut [&mut a, &mut b]);
        assert_eq!(a, vec![2.0, 4.0]);
        assert_eq!(b, vec![2.0, 4.0]);
        assert!(t > 0.0);
    }

    #[test]
    fn reduce_scatter_then_all_gather_equals_all_reduce() {
        proptest(24, |g| {
            let gsz = g.usize(2, 6);
            let n = gsz * g.usize(1, 40);
            let topo = Topology::new(1, gsz);
            let model = NetModel::hpc();
            let traffic = TrafficMatrix::new(1);
            let mut s = CollScratch::new();
            let mut c = ctx(&topo, &model, &traffic, &mut s);
            let group: Vec<usize> = (0..gsz).collect();
            let shards = even_shards(n, gsz);

            let orig: Vec<Vec<f32>> = (0..gsz).map(|_| g.vec_normal(n, 1.0)).collect();

            // Path A: all-reduce
            let mut a: Vec<Vec<f32>> = orig.clone();
            {
                let mut refs: Vec<&mut [f32]> = a.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_all_reduce_avg(&mut c, &group, &mut refs);
            }

            // Path B: reduce-scatter + all-gather
            let mut b: Vec<Vec<f32>> = orig.clone();
            {
                let mut refs: Vec<&mut [f32]> = b.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_reduce_scatter_avg(&mut c, &group, &mut refs, &shards);
                let mut refs: Vec<&mut [f32]> = b.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_all_gather(&mut c, &group, &mut refs, &shards);
            }

            for i in 0..gsz {
                prop_assert(
                    approx_slice_eq(&a[i], &b[i], 1e-5),
                    format!("rank {i} mismatch"),
                );
            }
        });
    }

    #[test]
    fn reduce_scatter_only_touches_own_shard() {
        let topo = Topology::new(1, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut a = vec![1.0f32, 1.0, 5.0, 5.0];
        let mut b = vec![3.0f32, 3.0, 7.0, 7.0];
        ring_reduce_scatter_avg(&mut c, &[0, 1], &mut [&mut a, &mut b], &[(0, 2), (2, 4)]);
        assert_eq!(a, vec![2.0, 2.0, 5.0, 5.0]); // own shard averaged
        assert_eq!(b, vec![3.0, 3.0, 6.0, 6.0]);
    }

    #[test]
    fn all_gather_distributes_all_shards() {
        let topo = Topology::new(1, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut a = vec![1.0f32, 2.0, 0.0, 0.0];
        let mut b = vec![0.0f32, 0.0, 3.0, 4.0];
        ring_all_gather(&mut c, &[0, 1], &mut [&mut a, &mut b], &[(0, 2), (2, 4)]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn naive_gather_time_scales_linearly_with_group() {
        // The Fig 6 mechanism: time(g) grows ~linearly for fixed payload.
        let model = NetModel::hpc();
        let payload_bytes = 1_000_000u64;
        let mut times = Vec::new();
        for nodes in [2usize, 8, 32] {
            let topo = Topology::new(nodes, 1);
            let traffic = TrafficMatrix::new(nodes);
            let mut s = CollScratch::new();
            let mut c = ctx(&topo, &model, &traffic, &mut s);
            let group: Vec<usize> = (0..nodes).collect();
            let payloads: Vec<((), u64)> = group.iter().map(|_| ((), payload_bytes)).collect();
            let (_, t) = naive_all_gather_bytes(&mut c, &group, &payloads);
            times.push(t);
        }
        let r1 = times[1] / times[0]; // 8 vs 2 nodes → ~7/1
        let r2 = times[2] / times[1]; // 32 vs 8 nodes → ~31/7
        assert!((r1 - 7.0).abs() < 0.2, "{r1}");
        assert!((r2 - 31.0 / 7.0).abs() < 0.2, "{r2}");
    }

    #[test]
    fn ring_all_reduce_time_nearly_constant_in_group() {
        // Ring scales: in the bandwidth-dominated regime the wire time
        // 2(g-1)/g·N/bw approaches 2N/bw — nearly group-size independent
        // (contrast with naive_gather_time_scales_linearly_with_group).
        let model = NetModel::hpc();
        let n = 4_000_000usize; // 16 MiB/rank: bandwidth term dominates α
        let t_at = |nodes: usize| {
            let topo = Topology::new(nodes, 1);
            let traffic = TrafficMatrix::new(nodes);
            let mut s = CollScratch::new();
            let mut c = ctx(&topo, &model, &traffic, &mut s);
            let group: Vec<usize> = (0..nodes).collect();
            let mut bufs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0; n]).collect();
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            ring_all_reduce_avg(&mut c, &group, &mut refs)
        };
        let t2 = t_at(2);
        let t8 = t_at(8);
        assert!(t8 / t2 < 2.5, "ring should not blow up: {t2} vs {t8}");
    }

    #[test]
    fn pooled_collectives_bit_match_scalar_reference_at_any_width() {
        // The chunk-parallel data plane must reproduce the pre-PR scalar
        // loops bit-for-bit at every pool width (buffers span multiple
        // grid chunks so the parallel path is actually exercised).
        use crate::parallel::CHUNK;
        proptest(5, |g| {
            let gsz = g.usize(2, 4);
            let per = CHUNK / 2 + g.usize(0, CHUNK);
            let n = gsz * per;
            let orig: Vec<Vec<f32>> = (0..gsz).map(|_| g.vec_normal(n, 1.0)).collect();
            let shards = even_shards(n, gsz);
            let group: Vec<usize> = (0..gsz).collect();
            let inv = 1.0 / gsz as f32;

            // Scalar references: the pre-PR loops, spelled out.
            let mut want_ar = orig.clone();
            {
                let mut acc = vec![0.0f32; n];
                for b in want_ar.iter() {
                    crate::tensor::axpy(&mut acc, 1.0, b);
                }
                for x in acc.iter_mut() {
                    *x *= inv;
                }
                for b in want_ar.iter_mut() {
                    b.copy_from_slice(&acc);
                }
            }
            let mut want_rs = orig.clone();
            for (i, &(lo, hi)) in shards.iter().enumerate() {
                let mut acc = vec![0.0f32; hi - lo];
                for b in want_rs.iter() {
                    crate::tensor::axpy(&mut acc, 1.0, &b[lo..hi]);
                }
                for x in acc.iter_mut() {
                    *x *= inv;
                }
                want_rs[i][lo..hi].copy_from_slice(&acc);
            }
            let mut want_ag = orig.clone();
            {
                let owned: Vec<Vec<f32>> = shards
                    .iter()
                    .enumerate()
                    .map(|(i, &(lo, hi))| want_ag[i][lo..hi].to_vec())
                    .collect();
                for b in want_ag.iter_mut() {
                    for (&(lo, hi), shard) in shards.iter().zip(&owned) {
                        b[lo..hi].copy_from_slice(shard);
                    }
                }
            }

            let bits_eq = |a: &[Vec<f32>], b: &[Vec<f32>]| {
                a.iter().zip(b).all(|(x, y)| {
                    x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                })
            };
            let model = NetModel::hpc();
            for threads in [1usize, 2, 4] {
                let pool = crate::parallel::WorkerPool::new(threads);
                let topo = Topology::new(1, gsz);
                let traffic = TrafficMatrix::new(1);
                let mut scr = CollScratch::new();
                let mut c = CollCtx {
                    topo: &topo,
                    model: &model,
                    traffic: &traffic,
                    pool: &pool,
                    scratch: &mut scr,
                };
                let mut got = orig.clone();
                {
                    let mut refs: Vec<&mut [f32]> =
                        got.iter_mut().map(|v| v.as_mut_slice()).collect();
                    ring_all_reduce_avg(&mut c, &group, &mut refs);
                }
                prop_assert(
                    bits_eq(&got, &want_ar),
                    format!("all-reduce diverged: g={gsz} n={n} threads={threads}"),
                );
                let mut got = orig.clone();
                {
                    let mut refs: Vec<&mut [f32]> =
                        got.iter_mut().map(|v| v.as_mut_slice()).collect();
                    ring_reduce_scatter_avg(&mut c, &group, &mut refs, &shards);
                }
                prop_assert(
                    bits_eq(&got, &want_rs),
                    format!("reduce-scatter diverged: g={gsz} n={n} threads={threads}"),
                );
                let mut got = orig.clone();
                {
                    let mut refs: Vec<&mut [f32]> =
                        got.iter_mut().map(|v| v.as_mut_slice()).collect();
                    ring_all_gather(&mut c, &group, &mut refs, &shards);
                }
                prop_assert(
                    bits_eq(&got, &want_ag),
                    format!("all-gather diverged: g={gsz} n={n} threads={threads}"),
                );
            }
        });
    }

    #[test]
    fn traffic_matrix_sees_inter_node_bytes() {
        let topo = Topology::new(2, 1);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(2);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut a = vec![0.0f32; 64];
        let mut b = vec![2.0f32; 64];
        ring_all_reduce_avg(&mut c, &[0, 1], &mut [&mut a, &mut b]);
        assert!(traffic.inter_node_bytes() > 0);
        assert_eq!(traffic.intra_node_bytes(), 0);
    }

    #[test]
    fn broadcast_copies_and_costs() {
        let topo = Topology::new(1, 4);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; 8]; 4];
        bufs[2] = vec![7.0; 8];
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        let t = broadcast(&mut c, &[0, 1, 2, 3], &mut refs, 2);
        assert!(t > 0.0);
        for b in &bufs {
            assert_eq!(b, &vec![7.0; 8]);
        }
    }

    #[test]
    fn event_durations_bit_match_scalar_collectives() {
        // The event constructors are the single source of truth for cost;
        // the scalar entry points must return exactly the same floats.
        let topo = Topology::new(2, 1);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(2);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let group = [0usize, 1];
        let link = Link::of(&model, LinkClass::InterNode);

        let n = 1000usize;
        let mut a = vec![1.0f32; n];
        let mut b = vec![2.0f32; n];
        let t = ring_all_reduce_avg(&mut c, &group, &mut [&mut a, &mut b]);
        assert_eq!(t, ring_all_reduce_event(&link, 2, (n * 4) as u64).duration);

        let shards = [(0usize, 500usize), (500, 1000)];
        let t = ring_reduce_scatter_avg(&mut c, &group, &mut [&mut a, &mut b], &shards);
        assert_eq!(t, ring_reduce_scatter_event(&link, 2, 2000).duration);

        let t = ring_all_gather(&mut c, &group, &mut [&mut a, &mut b], &shards);
        assert_eq!(t, ring_all_gather_event(&link, 2, 2000).duration);

        let payloads: Vec<((), u64)> = vec![((), 777), ((), 99)];
        let (_, t) = naive_all_gather_bytes(&mut c, &group, &payloads);
        assert_eq!(t, naive_all_gather_event(&link, &[777, 99]).duration);
    }

    #[test]
    fn event_metadata_and_scheduling() {
        let link = Link {
            class: LinkClass::InterNode,
            lat: 1.0,
            bw: 100.0,
        };
        let ev = naive_all_gather_event(&link, &[200, 100, 100]);
        assert_eq!(ev.label, "naive-gather");
        assert_eq!(ev.class, LinkClass::InterNode);
        // worst rank sends its 200 B payload to 2 peers
        assert_eq!(ev.bytes, 400);
        assert!((ev.duration - 2.0 * (1.0 + 2.0)).abs() < 1e-12);
        assert_eq!(ev.start, 0.0);
        let ev = ev.scheduled(5.0, vec![3, 4]);
        assert_eq!(ev.start, 5.0);
        assert!((ev.end() - 11.0).abs() < 1e-12);
        assert_eq!(ev.deps, vec![3, 4]);

        // singleton groups are free in every constructor
        assert_eq!(ring_all_reduce_event(&link, 1, 4096).duration, 0.0);
        assert_eq!(naive_all_gather_event(&link, &[4096]).duration, 0.0);
        assert_eq!(broadcast_event(&link, 1, 4096).duration, 0.0);
    }

    #[test]
    fn heterogeneous_link_slows_event() {
        let model = NetModel::hpc();
        let fast = Link::of(&model, LinkClass::InterNode);
        let slow = Link {
            bw: model.inter_bw / 10.0,
            ..fast
        };
        let f = ring_all_reduce_event(&fast, 4, 1 << 20).duration;
        let s = ring_all_reduce_event(&slow, 4, 1 << 20).duration;
        assert!(s > f * 5.0, "slow NIC must dominate: {f} vs {s}");
    }

    #[test]
    fn singleton_groups_are_free() {
        let topo = Topology::new(1, 1);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let mut s = CollScratch::new();
        let mut c = ctx(&topo, &model, &traffic, &mut s);
        let mut a = vec![1.0f32; 4];
        assert_eq!(ring_all_reduce_avg(&mut c, &[0], &mut [&mut a]), 0.0);
        assert_eq!(
            ring_all_gather(&mut c, &[0], &mut [&mut a], &[(0, 4)]),
            0.0
        );
        let (g, t) = naive_all_gather_bytes(&mut c, &[0], &[((), 100)]);
        assert_eq!(g.len(), 1);
        assert_eq!(t, 0.0);
    }
}
