//! Collective communication over the simulated cluster.
//!
//! Real data movement (numerics are exact — divergence across ranks is the
//! phenomenon under study) + α–β cost accounting per algorithm
//! (DESIGN.md §2). Two algorithm families, matching what the paper's stack
//! uses:
//!
//! * **Ring** reduce-scatter / all-gather / all-reduce — what
//!   FSDP/NCCL/RCCL use. Per-rank wire volume `(g-1)/g · N`, i.e. nearly
//!   size-independent of group size — these *scale*.
//! * **Naive (blocking) all-gather** of opaque payloads — what DeMo's
//!   replication uses (`dist.all_gather` of compressed components). Every
//!   rank sends its payload to every other: received volume `(g-1)·B`
//!   grows linearly with the group — the paper's Fig 6 "DeMo does not
//!   scale" mechanism falls straight out of this cost model.
//!
//! All functions return the elapsed `SimTime` for the op; the caller
//! advances the shared clock (groups that run in parallel advance by the
//! max across groups).

use crate::net::{LinkClass, NetModel, SimTime, Topology, TrafficMatrix};

/// Context threaded through every collective call.
pub struct CollCtx<'a> {
    pub topo: &'a Topology,
    pub model: &'a NetModel,
    pub traffic: &'a TrafficMatrix,
}

impl<'a> CollCtx<'a> {
    /// Record `bytes` flowing rank→rank and return nothing; time is
    /// accounted by the calling algorithm.
    fn record(&self, src: usize, dst: usize, bytes: u64) {
        self.traffic
            .record(self.topo.node_of(src), self.topo.node_of(dst), bytes);
    }

    fn class(&self, group: &[usize]) -> LinkClass {
        self.topo.group_link_class(group)
    }
}

/// Ring all-reduce (average) over `bufs[i]` belonging to `group[i]`.
/// Every buffer ends up holding the element-wise mean.
pub fn ring_all_reduce_avg(
    ctx: &CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Semantics: mean into every buffer.
    let mut acc = vec![0.0f32; n];
    for b in bufs.iter() {
        crate::tensor::axpy(&mut acc, 1.0, b);
    }
    let inv = 1.0 / g as f32;
    for x in acc.iter_mut() {
        *x *= inv;
    }
    for b in bufs.iter_mut() {
        b.copy_from_slice(&acc);
    }

    // Cost: ring all-reduce = reduce-scatter + all-gather, each (g-1)
    // steps of N/g elements; record ring-neighbor traffic.
    let chunk_bytes = (n * 4 / g) as u64;
    for step in 0..2 * (g - 1) {
        let _ = step;
        for i in 0..g {
            ctx.record(group[i], group[(i + 1) % g], chunk_bytes);
        }
    }
    let class = ctx.class(group);
    2.0 * (g as f64 - 1.0) * ctx.model.xfer_time(class, chunk_bytes)
}

/// Ring reduce-scatter (average): after the call, `bufs[i]` holds the mean
/// in its own shard range `[shards[i].0, shards[i].1)`; other regions are
/// left untouched (FSDP only guarantees the owned shard).
pub fn ring_reduce_scatter_avg(
    ctx: &CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    shards: &[(usize, usize)],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    assert_eq!(group.len(), shards.len());
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Mean of each shard region into its owner.
    let inv = 1.0 / g as f32;
    for (i, &(lo, hi)) in shards.iter().enumerate() {
        let mut acc = vec![0.0f32; hi - lo];
        for b in bufs.iter() {
            crate::tensor::axpy(&mut acc, 1.0, &b[lo..hi]);
        }
        for x in acc.iter_mut() {
            *x *= inv;
        }
        bufs[i][lo..hi].copy_from_slice(&acc);
    }

    let max_shard_bytes = shards.iter().map(|&(lo, hi)| (hi - lo) * 4).max().unwrap() as u64;
    for i in 0..g {
        for _ in 0..g - 1 {
            ctx.record(group[i], group[(i + 1) % g], max_shard_bytes);
        }
    }
    let class = ctx.class(group);
    (g as f64 - 1.0) * ctx.model.xfer_time(class, max_shard_bytes)
}

/// Ring all-gather: rank i contributes `bufs[i][shards[i]]`; afterwards
/// every buffer holds every shard (i.e. the full vector).
pub fn ring_all_gather(
    ctx: &CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    shards: &[(usize, usize)],
) -> SimTime {
    assert_eq!(group.len(), bufs.len());
    let g = group.len();
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n));

    // Collect every shard from its owner, then write into all buffers.
    let mut owned: Vec<Vec<f32>> = Vec::with_capacity(g);
    for (i, &(lo, hi)) in shards.iter().enumerate() {
        owned.push(bufs[i][lo..hi].to_vec());
    }
    for b in bufs.iter_mut() {
        for (&(lo, hi), shard) in shards.iter().zip(&owned) {
            b[lo..hi].copy_from_slice(shard);
        }
    }

    let max_shard_bytes = shards.iter().map(|&(lo, hi)| (hi - lo) * 4).max().unwrap() as u64;
    for i in 0..g {
        for _ in 0..g - 1 {
            ctx.record(group[i], group[(i + 1) % g], max_shard_bytes);
        }
    }
    let class = ctx.class(group);
    (g as f64 - 1.0) * ctx.model.xfer_time(class, max_shard_bytes)
}

/// Naive blocking all-gather of opaque payloads (DeMo's replication
/// primitive). Returns (gathered payloads in group order, elapsed time).
/// Received volume per rank is `Σ_{j≠i} bytes_j` — linear in group size.
pub fn naive_all_gather_bytes<T: Clone>(
    ctx: &CollCtx,
    group: &[usize],
    payloads: &[(T, u64)],
) -> (Vec<T>, SimTime) {
    assert_eq!(group.len(), payloads.len());
    let g = group.len();
    let gathered: Vec<T> = payloads.iter().map(|(p, _)| p.clone()).collect();
    if g <= 1 {
        return (gathered, 0.0);
    }
    let class = ctx.class(group);
    let mut worst: SimTime = 0.0;
    for (i, &(_, bytes_i)) in payloads.iter().enumerate() {
        // rank i sends its payload to every peer (blocking, serialized on
        // its NIC — the paper's non-scaling mechanism).
        let mut t_send: SimTime = 0.0;
        for (j, _) in group.iter().enumerate() {
            if i != j {
                ctx.record(group[i], group[j], bytes_i);
                t_send += ctx.model.xfer_time(class, bytes_i);
            }
        }
        worst = worst.max(t_send);
    }
    (gathered, worst)
}

/// Broadcast `src_buf` (group index `src`) into every buffer (tree cost).
pub fn broadcast(
    ctx: &CollCtx,
    group: &[usize],
    bufs: &mut [&mut [f32]],
    src: usize,
) -> SimTime {
    let g = group.len();
    assert!(src < g);
    if g <= 1 {
        return 0.0;
    }
    let n = bufs[src].len();
    let data = bufs[src].to_vec();
    for (i, b) in bufs.iter_mut().enumerate() {
        if i != src {
            b.copy_from_slice(&data);
        }
    }
    let bytes = (n * 4) as u64;
    for (j, _) in group.iter().enumerate() {
        if j != src {
            ctx.record(group[src], group[j], bytes);
        }
    }
    let class = ctx.class(group);
    let rounds = (g as f64).log2().ceil();
    rounds * ctx.model.xfer_time(class, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetModel, Topology, TrafficMatrix};
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};

    fn ctx<'a>(
        topo: &'a Topology,
        model: &'a NetModel,
        traffic: &'a TrafficMatrix,
    ) -> CollCtx<'a> {
        CollCtx {
            topo,
            model,
            traffic,
        }
    }

    fn even_shards(n: usize, g: usize) -> Vec<(usize, usize)> {
        (0..g).map(|i| (i * n / g, (i + 1) * n / g)).collect()
    }

    #[test]
    fn all_reduce_averages() {
        let topo = Topology::new(2, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(2);
        let c = ctx(&topo, &model, &traffic);
        let mut a = vec![1.0f32, 2.0];
        let mut b = vec![3.0f32, 6.0];
        let t = ring_all_reduce_avg(&c, &[0, 1], &mut [&mut a, &mut b]);
        assert_eq!(a, vec![2.0, 4.0]);
        assert_eq!(b, vec![2.0, 4.0]);
        assert!(t > 0.0);
    }

    #[test]
    fn reduce_scatter_then_all_gather_equals_all_reduce() {
        proptest(24, |g| {
            let gsz = g.usize(2, 6);
            let n = gsz * g.usize(1, 40);
            let topo = Topology::new(1, gsz);
            let model = NetModel::hpc();
            let traffic = TrafficMatrix::new(1);
            let c = ctx(&topo, &model, &traffic);
            let group: Vec<usize> = (0..gsz).collect();
            let shards = even_shards(n, gsz);

            let orig: Vec<Vec<f32>> = (0..gsz).map(|_| g.vec_normal(n, 1.0)).collect();

            // Path A: all-reduce
            let mut a: Vec<Vec<f32>> = orig.clone();
            {
                let mut refs: Vec<&mut [f32]> = a.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_all_reduce_avg(&c, &group, &mut refs);
            }

            // Path B: reduce-scatter + all-gather
            let mut b: Vec<Vec<f32>> = orig.clone();
            {
                let mut refs: Vec<&mut [f32]> = b.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_reduce_scatter_avg(&c, &group, &mut refs, &shards);
                let mut refs: Vec<&mut [f32]> = b.iter_mut().map(|v| v.as_mut_slice()).collect();
                ring_all_gather(&c, &group, &mut refs, &shards);
            }

            for i in 0..gsz {
                prop_assert(
                    approx_slice_eq(&a[i], &b[i], 1e-5),
                    format!("rank {i} mismatch"),
                );
            }
        });
    }

    #[test]
    fn reduce_scatter_only_touches_own_shard() {
        let topo = Topology::new(1, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let c = ctx(&topo, &model, &traffic);
        let mut a = vec![1.0f32, 1.0, 5.0, 5.0];
        let mut b = vec![3.0f32, 3.0, 7.0, 7.0];
        ring_reduce_scatter_avg(&c, &[0, 1], &mut [&mut a, &mut b], &[(0, 2), (2, 4)]);
        assert_eq!(a, vec![2.0, 2.0, 5.0, 5.0]); // own shard averaged
        assert_eq!(b, vec![3.0, 3.0, 6.0, 6.0]);
    }

    #[test]
    fn all_gather_distributes_all_shards() {
        let topo = Topology::new(1, 2);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let c = ctx(&topo, &model, &traffic);
        let mut a = vec![1.0f32, 2.0, 0.0, 0.0];
        let mut b = vec![0.0f32, 0.0, 3.0, 4.0];
        ring_all_gather(&c, &[0, 1], &mut [&mut a, &mut b], &[(0, 2), (2, 4)]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn naive_gather_time_scales_linearly_with_group() {
        // The Fig 6 mechanism: time(g) grows ~linearly for fixed payload.
        let model = NetModel::hpc();
        let payload_bytes = 1_000_000u64;
        let mut times = Vec::new();
        for nodes in [2usize, 8, 32] {
            let topo = Topology::new(nodes, 1);
            let traffic = TrafficMatrix::new(nodes);
            let c = ctx(&topo, &model, &traffic);
            let group: Vec<usize> = (0..nodes).collect();
            let payloads: Vec<((), u64)> = group.iter().map(|_| ((), payload_bytes)).collect();
            let (_, t) = naive_all_gather_bytes(&c, &group, &payloads);
            times.push(t);
        }
        let r1 = times[1] / times[0]; // 8 vs 2 nodes → ~7/1
        let r2 = times[2] / times[1]; // 32 vs 8 nodes → ~31/7
        assert!((r1 - 7.0).abs() < 0.2, "{r1}");
        assert!((r2 - 31.0 / 7.0).abs() < 0.2, "{r2}");
    }

    #[test]
    fn ring_all_reduce_time_nearly_constant_in_group() {
        // Ring scales: in the bandwidth-dominated regime the wire time
        // 2(g-1)/g·N/bw approaches 2N/bw — nearly group-size independent
        // (contrast with naive_gather_time_scales_linearly_with_group).
        let model = NetModel::hpc();
        let n = 4_000_000usize; // 16 MiB/rank: bandwidth term dominates α
        let t_at = |nodes: usize| {
            let topo = Topology::new(nodes, 1);
            let traffic = TrafficMatrix::new(nodes);
            let c = ctx(&topo, &model, &traffic);
            let group: Vec<usize> = (0..nodes).collect();
            let mut bufs: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0; n]).collect();
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            ring_all_reduce_avg(&c, &group, &mut refs)
        };
        let t2 = t_at(2);
        let t8 = t_at(8);
        assert!(t8 / t2 < 2.5, "ring should not blow up: {t2} vs {t8}");
    }

    #[test]
    fn traffic_matrix_sees_inter_node_bytes() {
        let topo = Topology::new(2, 1);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(2);
        let c = ctx(&topo, &model, &traffic);
        let mut a = vec![0.0f32; 64];
        let mut b = vec![2.0f32; 64];
        ring_all_reduce_avg(&c, &[0, 1], &mut [&mut a, &mut b]);
        assert!(traffic.inter_node_bytes() > 0);
        assert_eq!(traffic.intra_node_bytes(), 0);
    }

    #[test]
    fn broadcast_copies_and_costs() {
        let topo = Topology::new(1, 4);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let c = ctx(&topo, &model, &traffic);
        let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; 8]; 4];
        bufs[2] = vec![7.0; 8];
        let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        let t = broadcast(&c, &[0, 1, 2, 3], &mut refs, 2);
        assert!(t > 0.0);
        for b in &bufs {
            assert_eq!(b, &vec![7.0; 8]);
        }
    }

    #[test]
    fn singleton_groups_are_free() {
        let topo = Topology::new(1, 1);
        let model = NetModel::hpc();
        let traffic = TrafficMatrix::new(1);
        let c = ctx(&topo, &model, &traffic);
        let mut a = vec![1.0f32; 4];
        assert_eq!(ring_all_reduce_avg(&c, &[0], &mut [&mut a]), 0.0);
        assert_eq!(
            ring_all_gather(&c, &[0], &mut [&mut a], &[(0, 4)]),
            0.0
        );
        let (g, t) = naive_all_gather_bytes(&c, &[0], &[((), 100)]);
        assert_eq!(g.len(), 1);
        assert_eq!(t, 0.0);
    }
}
