//! bf16 / f16 conversion primitives (substrate — no `half` crate offline).
//!
//! Used by `compress` to model and perform the paper's transfer-dtype
//! reduction (Figs 13/14). Conversions use round-to-nearest-even, the
//! same rounding NCCL/RCCL reductions and PyTorch `.to(bfloat16)` apply.

/// f32 → bf16 bits, round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Quiet NaN, preserve sign.
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE on the low 16 bits.
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(round_bit - 1 + lsb)) >> 16) as u16
}

/// bf16 bits → f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even, with proper
/// subnormal and overflow (→ inf) handling.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent, rebiased for f16 (bias 15 vs 127).
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → inf
    }
    if e <= 0 {
        // Subnormal or underflow to zero.
        if e < -10 {
            return sign;
        }
        // Add implicit leading 1, shift into subnormal position with RNE.
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let rounded = man + half_ulp - 1 + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // Normal: RNE on the 13 dropped mantissa bits.
    let half_ulp = 0x0000_0FFFu32;
    let rounded = man + half_ulp + ((man >> 13) & 1);
    let mut e16 = e as u32;
    let mut m16 = rounded >> 13;
    if m16 & 0x0400 != 0 {
        // Mantissa overflow from rounding bumps the exponent.
        m16 = 0;
        e16 += 1;
        if e16 >= 0x1F {
            return sign | 0x7C00;
        }
    }
    sign | ((e16 as u16) << 10) | (m16 as u16 & 0x03FF)
}

/// IEEE 754 binary16 bits → f32 (exact).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = m;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            // value = m·2⁻²⁴; after s = -1-e shifts m sits at bit 10, so the
            // unbiased exponent is -14-s = e-13 ⇒ field = 127-15+e+2.
            sign | (((127 - 15 + e + 2) as u32) << 23) | ((m & 0x03FF) << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_exact_values_roundtrip() {
        // Values exactly representable in bf16 survive untouched.
        for x in [0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5, 256.0, -1024.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x, "{x}");
        }
    }

    #[test]
    fn f16_exact_values_roundtrip() {
        for x in [0.0f32, -0.0, 1.0, -2.0, 0.5, 0.25, 2048.0, -65504.0] {
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "{x}");
        }
    }

    #[test]
    fn bf16_relative_error_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = (rng.next_f32() - 0.5) * 100.0;
            let y = bf16_to_f32(f32_to_bf16(x));
            let rel = ((x - y) / x.abs().max(1e-20)).abs();
            assert!(rel <= 1.0 / 128.0, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = (rng.next_f32() - 0.5) * 100.0;
            let y = f16_to_f32(f32_to_f16(x));
            let rel = ((x - y) / x.abs().max(1e-20)).abs();
            assert!(rel <= 1.0 / 1024.0, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(-1e6)).is_infinite());
        assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0); // f16 max
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 6e-8f32; // within f16 subnormal range
        let y = f16_to_f32(f32_to_f16(tiny));
        assert!(y > 0.0 && (y - tiny).abs() / tiny < 0.05, "{tiny} -> {y}");
        assert_eq!(f16_to_f32(f32_to_f16(1e-12)), 0.0); // underflow
    }

    #[test]
    fn nan_propagates() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn infinities_preserved() {
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn rne_ties_to_even_bf16() {
        // 1.0 + 2^-8 is exactly halfway between two bf16 values around 1.0;
        // RNE must choose the even mantissa (1.0).
        let x = f32::from_bits(0x3F80_8000);
        let y = bf16_to_f32(f32_to_bf16(x));
        assert_eq!(y, 1.0);
    }
}
