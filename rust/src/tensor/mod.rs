//! Flat f32 tensors + half-precision conversions.
//!
//! The coordinator's state (parameters, gradients, momenta) lives in flat
//! `Tensor` buffers; named shapes come from the artifact manifest
//! (`runtime::Manifest`). Half-precision (`bf16`/`f16`) conversion is
//! needed for the transfer-dtype experiments (paper Figs 13/14) and is a
//! from-scratch substrate (no `half` crate offline).

pub mod half;

pub use half::{bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16};

/// Transfer data type for replicated payloads (paper Fig 13/14).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    Bf16,
    F16,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 | Dtype::F16 => 2,
        }
    }

    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" | "float32" => Some(Dtype::F32),
            "bf16" | "bfloat16" => Some(Dtype::Bf16),
            "f16" | "float16" => Some(Dtype::F16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
            Dtype::F16 => "f16",
        }
    }

    /// Round-trip a value through this dtype (quantize to transfer
    /// precision). F32 is identity.
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Dtype::F32 => x,
            Dtype::Bf16 => bf16_to_f32(f32_to_bf16(x)),
            Dtype::F16 => f16_to_f32(f32_to_f16(x)),
        }
    }
}

/// A dense f32 tensor: flat data + shape. Row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "shape {shape:?} does not match len {}",
            data.len()
        );
        Tensor {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn l2(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.len(), other.len());
        axpy(&mut self.data, alpha, &other.data);
    }

    pub fn scale(&mut self, alpha: f32) {
        crate::parallel::lanes::scale(&mut self.data, alpha);
    }
}

/// y += alpha * x over slices (the hot axpy used everywhere). Runs on
/// the unrolled f32×8 lanes of [`crate::parallel::lanes`]; bit-identical
/// to the scalar loop at every length.
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    crate::parallel::lanes::axpy(y, alpha, x);
}

/// Chunk-parallel `y += alpha * x` on the pool's fixed grid —
/// bit-identical to [`axpy`] at any worker count (each element's float
/// chain is unchanged; only which thread computes it varies).
pub fn axpy_pooled(pool: &crate::parallel::WorkerPool, y: &mut [f32], alpha: f32, x: &[f32]) {
    crate::parallel::zip_chunks(pool, y, x, |ys, xs| axpy(ys, alpha, xs));
}

/// Elementwise mean of many equally-sized slices into `out`.
pub fn mean_into(out: &mut [f32], parts: &[&[f32]]) {
    assert!(!parts.is_empty());
    let inv = 1.0 / parts.len() as f32;
    out.copy_from_slice(parts[0]);
    for p in &parts[1..] {
        axpy(out, 1.0, p);
    }
    crate::parallel::lanes::scale(out, inv);
}

/// Chunk-parallel [`mean_into`]: per element the accumulation order over
/// `parts` is identical to the scalar version, so results are
/// bit-identical at any worker count.
pub fn mean_into_pooled(pool: &crate::parallel::WorkerPool, out: &mut [f32], parts: &[&[f32]]) {
    assert!(!parts.is_empty());
    let inv = 1.0 / parts.len() as f32;
    crate::parallel::for_each_chunk(pool, out, |lo, oseg| {
        let hi = lo + oseg.len();
        oseg.copy_from_slice(&parts[0][lo..hi]);
        for p in &parts[1..] {
            axpy(oseg, 1.0, &p[lo..hi]);
        }
        crate::parallel::lanes::scale(oseg, inv);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_product() {
        let t = Tensor::zeros(&[3, 4, 5]);
        assert_eq!(t.len(), 60);
        assert_eq!(t.shape, vec![3, 4, 5]);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_rejects_mismatch() {
        Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn axpy_basic() {
        let mut t = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let u = Tensor::from_vec(vec![10.0, 20.0], &[2]);
        t.axpy(0.5, &u);
        assert_eq!(t.data, vec![6.0, 12.0]);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert!((t.l2() - 5.0).abs() < 1e-9);
        assert!((t.sq_norm() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn mean_into_averages() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&mut out, &[&a, &b]);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn pooled_kernels_bit_match_scalar() {
        let n = crate::parallel::CHUNK * 2 + 77;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.013).sin()).collect();
        let z: Vec<f32> = (0..n).map(|i| (i as f32 * 0.029).cos()).collect();
        let y0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.007).tan()).collect();
        let mut want = y0.clone();
        axpy(&mut want, -0.3, &x);
        let mut want_mean = vec![0.0f32; n];
        mean_into(&mut want_mean, &[&x, &z, &y0]);
        for threads in [1usize, 2, 4] {
            let pool = crate::parallel::WorkerPool::new(threads);
            let mut got = y0.clone();
            axpy_pooled(&pool, &mut got, -0.3, &x);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "axpy_pooled threads={threads}"
            );
            let mut got_mean = vec![0.0f32; n];
            mean_into_pooled(&pool, &mut got_mean, &[&x, &z, &y0]);
            assert!(
                got_mean
                    .iter()
                    .zip(&want_mean)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "mean_into_pooled threads={threads}"
            );
        }
    }

    #[test]
    fn dtype_quantize_f32_identity() {
        for x in [0.0f32, -1.5, 3.25e-8, 1e30] {
            assert_eq!(Dtype::F32.quantize(x), x);
        }
    }

    #[test]
    fn dtype_parse_names() {
        assert_eq!(Dtype::parse("bf16"), Some(Dtype::Bf16));
        assert_eq!(Dtype::parse("float16"), Some(Dtype::F16));
        assert_eq!(Dtype::parse("nope"), None);
        for d in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
            assert_eq!(Dtype::parse(d.name()), Some(d));
        }
    }
}
