//! # DeToNATION — Decoupled Network-Aware Training on Interlinked Online Nodes
//!
//! Rust + JAX + Pallas reproduction of *DeToNATION* (From et al., AAAI
//! 2026): the FlexDeMo hybrid-sharded decoupled-momentum training strategy
//! and its family of replication schemes (DeMo, Random, Striding, DiLoCo)
//! plus decoupled optimizers (DeMo-SGD, Decoupled AdamW).
//!
//! Architecture (DESIGN.md):
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   hybrid sharding mesh, collectives over a simulated cluster with a
//!   deterministic α–β network cost model, decoupled optimizers,
//!   replication schemes, metrics, launcher.
//! * **Layer 2/1 (python/, build-time only)** — JAX transformer models
//!   whose fwd/bwd lowers through Pallas kernels into HLO-text artifacts.
//! * **runtime** — loads those artifacts via the PJRT CPU client (`xla`
//!   crate) and executes them from the training hot path. Python is never
//!   on the training path.

pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod replicate;
pub mod runtime;
pub mod shard;
pub mod tensor;
pub mod topk;
pub mod train;
pub mod util;
