//! # DeToNATION — Decoupled Network-Aware Training on Interlinked Online Nodes
//!
//! Rust + JAX + Pallas reproduction of *DeToNATION* (From et al., AAAI
//! 2026): the FlexDeMo hybrid-sharded decoupled-momentum training strategy
//! and its family of replication schemes (DeMo, Random, Striding, DiLoCo)
//! plus decoupled optimizers (DeMo-SGD, Decoupled AdamW).
//!
//! Architecture (DESIGN.md):
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   hybrid sharding mesh, collectives over a simulated cluster with a
//!   deterministic α–β network cost model, decoupled optimizers,
//!   replication schemes, metrics, launcher.
//! * **Layer 2/1 (python/, build-time only)** — JAX transformer models
//!   whose fwd/bwd lowers through Pallas kernels into HLO-text artifacts.
//! * **runtime** — two backends behind one API: the PJRT CPU client
//!   (cargo feature `xla`) executing the AOT artifacts, and a pure-Rust
//!   surrogate (default) so the whole simulator builds and tests offline.
//!   Python is never on the training path.
//!
//! ## Time model: the event engine
//!
//! Numerics and time are decoupled. Data always moves in program order
//! (bit-deterministic); *when* it moves is decided by the discrete-event
//! engine (`train::engine`):
//!
//! * every rank owns a **compute lane**, an **intra-node fabric lane**
//!   (unshard + reduce-scatter), and an inter-node **NIC lane**
//!   ([`net::Timeline`] — monotone per-rank ready-times);
//! * collectives describe their cost as [`collectives::CommEvent`]s
//!   (start, duration, link class, bytes, dependency ids), built by one
//!   shared set of `*_event` constructors;
//! * with overlap on (default), phase 0/2 intra-node traffic hides behind
//!   backward compute and the replication gather overlaps the next
//!   step's forward (DeMo's async-all-gather decoupling); `--no-overlap`
//!   reproduces the legacy barrier-synchronous totals bit-for-bit;
//! * `--bucket-mb` splits reduce-scatter/gather into per-bucket events
//!   so the first bucket's communication overlaps the remaining buckets'
//!   compression (pipelined gradient buckets; schedule-only — numerics
//!   and serialized totals are untouched);
//! * `--staleness S` turns DiLoCo's periodic sync asynchronous
//!   ([`replicate::AsyncDiLoCoReplicator`]): the gather is charged on a
//!   deferred NIC lane while up to S further local steps run, and the
//!   averaged delta lands S steps late with the federated-averaging
//!   correction taken against the launch snapshot — the first scheme
//!   where communication overlaps *optimization*, not just compute
//!   within a step (`S = 0` is bit-identical to synchronous DiLoCo);
//! * on heterogeneous clusters the window turns **straggler-tolerant**:
//!   `--staleness auto` resolves one S per node from its compute/NIC
//!   profile ([`net::ClusterModel::auto_staleness`], with explicit
//!   `--node-staleness R:S` overrides), the launch charges one
//!   per-member NIC lane so fast nodes ship at their own pace, and
//!   `--late-policy drop|partial` finalizes each node's window from the
//!   on-time quorum (NoLoCo-style, averaging denominator corrected to
//!   the contributing set) instead of blocking on the slowest member;
//! * [`net::ClusterModel`] adds per-node straggler slowdowns and NIC
//!   bandwidth overrides on top of the homogeneous α–β [`net::NetModel`];
//! * membership is **elastic** ([`net::MembershipTimeline`]): a
//!   deterministic `--churn`/`--crash` timeline of join/leave/crash
//!   events re-forms each sync window's group around the departed
//!   members (averaging denominator corrected, node 0 anchoring),
//!   `--quorum K` finalizes a deferred window once K contributions
//!   land, and `--checkpoint-dir` publishes a full trainer checkpoint
//!   (`train::checkpoint` via [`train::Trainer::save_checkpoint`]) at
//!   every window-quiescent step so a crashed node rejoins from its
//!   stash bit-identically — an empty timeline is bit-inert
//!   (prop-tested);
//! * links are **fallible** ([`net::FaultTimeline`]): a seeded
//!   `--link-fault` spec drops, corrupts, flaps, or degrades individual
//!   directed links, payload checksums catch corruption at decode, and
//!   the engine's retry lane re-charges failed transfers with
//!   per-attempt timeout plus capped exponential backoff
//!   (`--max-retries`/`--retry-timeout`/`--retry-backoff`); an
//!   exhausted sender falls back through `--late-policy`/`--quorum`, so
//!   a persistent partition degrades instead of deadlocking — every
//!   fault decision is a pure hash of (seed, step, attempt, link), so
//!   faulted runs are bit-reproducible and an empty spec is bit-inert
//!   (both prop-tested);
//! * connectivity is **selectable** ([`replicate::SyncTopology`]):
//!   `--topology full|ring|random-pair|hier:<F>` picks, per sync
//!   window, which peers each node exchanges deltas with — `full`
//!   keeps today's whole-group exchange bit-frozen (prop-tested
//!   identical), `ring` talks to ±1 neighbors, `random-pair` draws a
//!   seeded perfect matching per window (a pure hash of seed × step,
//!   no RNG stream consumed), and `hier:<F>` combines the intra-node
//!   fabric reduce with a rotating F-wide inter-node fanout; the
//!   engine charges only the selected links' NIC events, so gossip
//!   topologies expose O(1) comm per window while `full` grows with
//!   the group (gated in `BENCH_topology.json`), and the averaging
//!   denominator is always the contributing set actually heard from;
//! * the compression rate is **adaptive**
//!   ([`replicate::RateController`]): `--compress-control aimd` runs a
//!   per-node AIMD loop that samples each node's NIC busy fraction
//!   (`train::engine::StepEngine::nic_busy`) and the run's exposed-comm
//!   ratio once per `--control-window`, backs a congested node's
//!   DeMo/Random/Striding rate off multiplicatively while idle peers
//!   climb additively, clamped to `[--rate-min, --rate-max]` — the
//!   water-filling equilibrium beats every uniform fixed rate on a
//!   mixed-NIC cluster (gated in `BENCH_adaptive.json`); retuned rates
//!   land in the steps-CSV `rate` column and the v4 checkpoint, and
//!   `off` (the default) is bit-inert (prop-tested);
//! * metrics split each step into compute vs exposed-comm vs hidden-comm
//!   on the critical rank (`results/*.steps.csv` columns).
//!
//! ## Wall-clock model: the worker pool
//!
//! Orthogonal to simulated time, the *host* data plane runs on a
//! persistent [`parallel::WorkerPool`] built once per trainer from
//! `--threads`: the per-stream fwd/bwd fan-out, ring collectives, fused
//! optimizer kernels, DeMo decode/residual scatter, blocked DCT batches,
//! and the surrogate eval loop all dispatch chunk-parallel work onto it
//! over a fixed grid, so results are bit-identical for any `--threads N`
//! (prop-tested) and the steady-state hot path allocates nothing.
//!
//! ## Where to start reading
//!
//! [`train`] (the step loop) → [`train::engine`] (the clock) →
//! [`replicate`] (what crosses the wire) → [`collectives`] (how, and at
//! what α–β cost) → [`parallel`] (how the host executes it). The repo
//! root's `README.md` has the scheme matrix and the full CLI reference;
//! `docs/BENCHMARKS.md` describes every `BENCH_*.json` artifact.

pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dct;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod parallel;
pub mod replicate;
pub mod runtime;
pub mod shard;
pub mod tensor;
pub mod topk;
pub mod train;
pub mod util;
