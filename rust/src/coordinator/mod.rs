//! Experiment coordinator: the leader that turns configs into runs.
//!
//! One [`Runtime`] (PJRT client under the `xla` feature, the pure-Rust
//! surrogate otherwise) is shared across a whole sweep; each experiment
//! builds a fresh [`Trainer`] (cluster + optimizer + replicator state,
//! event-engine clock), runs it, and lands metrics + config in
//! `results/<name>/`.
//! Every figure bench and example drives this module, so the behaviour of
//! "an experiment" is defined in exactly one place.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::metrics::{comparison_table, RunMetrics};
use crate::runtime::Runtime;
use crate::train::Trainer;
use crate::util::json::Json;

/// A named collection of runs (one figure / one table).
pub struct Experiment {
    pub name: String,
    pub out_dir: PathBuf,
    pub runs: Vec<RunMetrics>,
}

impl Experiment {
    pub fn new(name: &str, results_root: &Path) -> Experiment {
        Experiment {
            name: name.to_string(),
            out_dir: results_root.join(name),
            runs: Vec::new(),
        }
    }

    /// Run one configuration (label defaults to opt+repl) and collect it.
    pub fn run(&mut self, rt: &Runtime, cfg: &ExperimentConfig, label: Option<&str>) -> Result<&RunMetrics> {
        log::info!(
            "[{}] run {} model={} mesh={}x{} opt={} repl={} sched={}",
            self.name,
            label.unwrap_or("-"),
            cfg.model,
            cfg.nodes,
            cfg.accels_per_node,
            cfg.opt.label(),
            cfg.repl.label(),
            if cfg.overlap { "overlap" } else { "serialized" }
        );
        let mut trainer = Trainer::new(rt, cfg.clone())?;
        let mut metrics = trainer.run()?;
        if let Some(l) = label {
            metrics.label = l.to_string();
        }
        std::fs::create_dir_all(&self.out_dir)?;
        metrics.write_csv(&self.out_dir)?;
        let cfg_path = self
            .out_dir
            .join(format!("{}.config.json", metrics.label.replace('/', "-")));
        std::fs::write(cfg_path, cfg.to_json().to_string_pretty())?;
        self.runs.push(metrics);
        Ok(self.runs.last().unwrap())
    }

    /// Write the experiment-level summary (table + JSON) and return the
    /// rendered table.
    pub fn finish(&self) -> Result<String> {
        std::fs::create_dir_all(&self.out_dir)?;
        let refs: Vec<&RunMetrics> = self.runs.iter().collect();
        let table = comparison_table(&refs);
        std::fs::write(self.out_dir.join("summary.txt"), &table)?;
        let summaries: Vec<Json> = self.runs.iter().map(|r| r.summary_json()).collect();
        std::fs::write(
            self.out_dir.join("summary.json"),
            Json::Arr(summaries).to_string_pretty(),
        )?;
        Ok(table)
    }

    /// Best (lowest) final validation loss across runs.
    pub fn best_val(&self) -> Option<(&str, f64)> {
        self.runs
            .iter()
            .filter_map(|r| r.final_val_loss().map(|l| (r.label.as_str(), l)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Best (lowest) tail train loss across runs.
    pub fn best_tail_loss(&self, n: usize) -> Option<(&str, f64)> {
        self.runs
            .iter()
            .filter_map(|r| r.tail_loss(n).map(|l| (r.label.as_str(), l)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }
}

/// Shared entry: build the model runtime once (PJRT with `--features
/// xla`, the pure-Rust surrogate backend otherwise).
pub fn runtime() -> Result<Runtime> {
    crate::util::logging::init();
    Runtime::cpu()
}

/// Default results root (overridable with DETONATION_RESULTS).
pub fn results_root() -> PathBuf {
    std::env::var("DETONATION_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StepRow;

    #[test]
    fn experiment_summary_and_best() {
        let mut e = Experiment::new("t", &std::env::temp_dir().join("detonation-coord-test"));
        for (label, loss) in [("a", 2.0), ("b", 1.0)] {
            let mut m = RunMetrics::new(label);
            m.steps.push(StepRow {
                step: 0,
                sim_time: 1.0,
                loss,
                inter_bytes: 0,
                intra_bytes: 0,
                compute_time: 0.0,
                exposed_comm: 0.0,
                hidden_comm: 0.0,
                comm_events: 0,
                staleness: 0,
                node_staleness: String::new(),
                sync_in_flight: 0,
                dropped_syncs: String::new(),
                peer_set: String::new(),
                membership: String::new(),
                retries: 0,
                corrupt_detected: 0,
                faulted_links: 0,
                wall_time: 0.0,
            });
            m.val.push(crate::metrics::ValRow {
                step: 1,
                sim_time: 1.0,
                loss,
            });
            e.runs.push(m);
        }
        let table = e.finish().unwrap();
        assert!(table.contains('a') && table.contains('b'));
        assert_eq!(e.best_val().unwrap().0, "b");
        assert_eq!(e.best_tail_loss(5).unwrap().0, "b");
        std::fs::remove_dir_all(&e.out_dir).ok();
    }
}
