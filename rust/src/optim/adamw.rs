//! AdamW (Loshchilov & Hutter 2019) — the conventional full-sync baseline.
//!
//! Data flow differs from the decoupled optimizers: the replication buffer
//! is the *raw gradient* (overwritten each step), the Full replicator
//! averages it across nodes, and the Adam moments are driven by the
//! synchronized gradient inside [`Optimizer::apply`]. Paired with
//! `ReplSpec::Full` this reproduces the paper's "Hybrid-FSDP + AdamW"
//! red baseline curve (Figs 1, 3–6).

use super::Optimizer;
use crate::parallel::{self, lanes, PoolHandle, SlicePtr};

pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m1: Vec<f32>,
    m2: Vec<f32>,
    buffer: Vec<f32>,
    t: u64,
    pool: PoolHandle,
}

impl AdamW {
    pub fn new(shard_len: usize, beta1: f32, beta2: f32, weight_decay: f32) -> AdamW {
        AdamW {
            beta1,
            beta2,
            eps: 1e-8,
            weight_decay,
            m1: vec![0.0; shard_len],
            m2: vec![0.0; shard_len],
            buffer: vec![0.0; shard_len],
            t: 0,
            pool: PoolHandle::default(),
        }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        format!("adamw(b1={},b2={})", self.beta1, self.beta2)
    }

    fn attach_pool(&mut self, pool: PoolHandle) {
        self.pool = pool;
    }

    fn accumulate(&mut self, grad: &[f32]) {
        // Baseline semantics: ship the gradient itself; no decoupled state.
        self.buffer.copy_from_slice(grad);
    }

    fn buffer_mut(&mut self) -> &mut [f32] {
        &mut self.buffer
    }

    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), q.len());
        self.t += 1;
        let (beta1, beta2, eps, wd) = (self.beta1, self.beta2, self.eps, self.weight_decay);
        let bc1 = 1.0 - beta1.powi(self.t as i32);
        let bc2 = 1.0 - beta2.powi(self.t as i32);
        // Fused single sweep (moments + decay + step), chunk-parallel on
        // the unrolled lane kernel — same per-element float chain as the
        // scalar loop.
        let consts = lanes::AdamConsts {
            beta1,
            beta2,
            bc1,
            bc2,
            eps,
        };
        let pool = self.pool.clone();
        let m1 = SlicePtr::new(&mut self.m1);
        let m2 = SlicePtr::new(&mut self.m2);
        let ps = SlicePtr::new(params);
        parallel::run_chunks(pool.get(), q.len(), |_w, lo, hi| {
            // Safety: grid chunks are disjoint per task.
            let m1 = unsafe { m1.range(lo, hi) };
            let m2 = unsafe { m2.range(lo, hi) };
            let ps = unsafe { ps.range(lo, hi) };
            lanes::adamw_step(m1, m2, ps, &q[lo..hi], consts, lr, wd);
        });
    }

    fn state_bytes(&self) -> u64 {
        ((self.m1.len() + self.m2.len()) * 4) as u64
    }

    fn export_state(&self) -> super::OptState {
        super::OptState {
            vecs: vec![self.m1.clone(), self.m2.clone(), self.buffer.clone()],
            t: self.t,
        }
    }

    fn import_state(&mut self, st: super::OptState) -> anyhow::Result<()> {
        let lens = [self.m1.len(), self.m2.len(), self.buffer.len()];
        let [m1, m2, buffer] = super::unpack_state("adamw", st.vecs, lens)?;
        self.m1 = m1;
        self.m2 = m2;
        self.buffer = buffer;
        self.t = st.t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_is_overwritten_not_accumulated() {
        let mut o = AdamW::new(2, 0.9, 0.999, 0.0);
        o.accumulate(&[1.0, 2.0]);
        o.accumulate(&[3.0, 4.0]);
        assert_eq!(o.buffer_mut(), &[3.0, 4.0]);
    }

    #[test]
    fn first_apply_steps_by_lr() {
        let mut o = AdamW::new(1, 0.9, 0.999, 0.0);
        let mut p = vec![1.0f32];
        o.apply(&mut p, &[10.0], 0.001);
        // Adam's first step is ≈ lr regardless of gradient scale.
        assert!((p[0] - 0.999).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = (x-3)², grad = 2(x-3)
        let mut o = AdamW::new(1, 0.9, 0.999, 0.0);
        let mut x = vec![0.0f32];
        for _ in 0..2000 {
            let g = 2.0 * (x[0] - 3.0);
            o.apply(&mut x, &[g], 0.05);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "{}", x[0]);
    }

    #[test]
    fn decoupled_weight_decay_not_in_moments() {
        // With zero gradient, params still shrink by wd but moments stay 0.
        let mut o = AdamW::new(1, 0.9, 0.999, 0.1);
        let mut p = vec![5.0f32];
        o.apply(&mut p, &[0.0], 0.1);
        assert!((p[0] - 5.0 * 0.99).abs() < 1e-5);
    }
}
