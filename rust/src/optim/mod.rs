//! Decoupled optimizers (paper §Methods, §Decoupled AdamW).
//!
//! An [`Optimizer`] owns one rank's shard-local state and plugs into the
//! FlexDeMo step (Algorithm 1) at two points:
//!
//! 1. [`Optimizer::accumulate`] — ingest the reduce-scattered gradient
//!    shard into the *replication buffer* (the thing replicators extract
//!    from; e.g. DeMo-SGD's decoupled momentum `m ← βm + Δ`);
//! 2. [`Optimizer::apply`] — apply the finalized (synchronized) update Q
//!    to the parameter shard.
//!
//! Four implementations:
//! * **DeMo-SGD** — SGD with decoupled momentum (the paper's default;
//!   "we differentiate [from plain SGD] as it accumulates momenta").
//! * **Decoupled AdamW** — AdamW whose first/second moments stay local and
//!   are *never* synchronized ("which would require 2-3 times more
//!   communication"); the replication buffer accumulates update steps.
//! * **AdamW** — the conventional full-sync baseline: the replication
//!   buffer is the raw gradient, and the Adam moments are driven by the
//!   *synchronized* gradient in `apply` (classic hybrid-FSDP + AdamW).
//! * **Sgd** — plain SGD on the synchronized gradient (ablations).

mod adamw;
mod decoupled_adamw;
mod demo_sgd;
mod sgd;

pub use adamw::AdamW;
pub use decoupled_adamw::DecoupledAdamW;
pub use demo_sgd::DemoSgd;
pub use sgd::Sgd;

/// Fused SGD-family parameter step, chunk-parallel on the pool's fixed
/// grid: weight decay and the `θ ← θ − lr·q` update run in **one sweep**
/// over the shard (the seed code made two). Per element the float chain
/// matches the old two-pass `decay; axpy` exactly — `p·d − lr·q` vs
/// `(p·d) + (−lr)·q` are the same IEEE operations — so results are
/// bit-identical, at any worker count.
pub(crate) fn fused_decay_step(
    pool: &crate::parallel::WorkerPool,
    params: &mut [f32],
    q: &[f32],
    lr: f32,
    weight_decay: f32,
) {
    debug_assert_eq!(params.len(), q.len());
    if weight_decay > 0.0 {
        let decay = 1.0 - lr * weight_decay;
        crate::parallel::zip_chunks(pool, params, q, |ps, qs| {
            crate::parallel::lanes::decay_step(ps, decay, lr, qs);
        });
    } else {
        crate::tensor::axpy_pooled(pool, params, -lr, q);
    }
}

/// One rank's optimizer state over its parameter shard.
pub trait Optimizer: Send {
    fn name(&self) -> String;

    /// Hand the optimizer the trainer's worker pool: the fused
    /// accumulate/apply kernels dispatch chunk-parallel onto it.
    /// Without a pool they run inline (bit-identical either way).
    fn attach_pool(&mut self, pool: crate::parallel::PoolHandle);

    /// Fold this step's (intra-node averaged) gradient shard into the
    /// replication buffer / internal state.
    fn accumulate(&mut self, grad: &[f32]);

    /// The buffer replicators extract from (decoupled momentum for
    /// DeMo-SGD, accumulated update for Decoupled AdamW, raw gradient for
    /// the baselines). Residual semantics belong to the replicator.
    fn buffer_mut(&mut self) -> &mut [f32];

    /// Apply the finalized update `q` to `params`:
    /// `θ ← θ − lr·(q [+ wd·θ])` or the optimizer's own rule.
    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32);

    /// Bytes of optimizer state that would need synchronizing if this
    /// optimizer were *not* decoupled (paper's 2-3× communication claim).
    fn state_bytes(&self) -> u64;

    /// Snapshot the full mutable state (moment vectors, replication
    /// buffer, Adam step counter) for checkpointing. The vector order is
    /// implementation-defined but stable across export/import.
    fn export_state(&self) -> OptState;

    /// Restore an [`Optimizer::export_state`] snapshot taken on an
    /// optimizer of the same kind and shard length.
    fn import_state(&mut self, st: OptState) -> anyhow::Result<()>;
}

/// A serializable snapshot of one optimizer's mutable state: its f32
/// vectors (moments and buffers, order fixed per implementation) plus
/// the Adam-style step counter (0 for the SGD family).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OptState {
    pub vecs: Vec<Vec<f32>>,
    pub t: u64,
}

/// Shared import plumbing: unpack `st.vecs` into exactly `N` vectors
/// whose lengths match the current state's (checkpoint shape check).
pub(crate) fn unpack_state<const N: usize>(
    name: &str,
    st: Vec<Vec<f32>>,
    want_lens: [usize; N],
) -> anyhow::Result<[Vec<f32>; N]> {
    let vecs: [Vec<f32>; N] = st
        .try_into()
        .map_err(|v: Vec<Vec<f32>>| anyhow::anyhow!("{name} snapshot has {} vecs, want {N}", v.len()))?;
    for (i, (v, want)) in vecs.iter().zip(want_lens).enumerate() {
        anyhow::ensure!(
            v.len() == want,
            "{name} snapshot vec {i} has {} elements, shard has {want}",
            v.len()
        );
    }
    Ok(vecs)
}

/// Which optimizer to build (config / CLI surface).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptSpec {
    DemoSgd { beta: f32, weight_decay: f32 },
    DecoupledAdamW { beta1: f32, beta2: f32, weight_decay: f32 },
    AdamW { beta1: f32, beta2: f32, weight_decay: f32 },
    Sgd { weight_decay: f32 },
}

impl OptSpec {
    /// Parse "demo-sgd", "decoupled-adamw", "adamw", "sgd" with optional
    /// ":beta=0.9"-style overrides.
    pub fn parse(s: &str) -> anyhow::Result<OptSpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let mut beta = 0.9f32;
        let mut beta2 = 0.999f32;
        let mut wd = 0.0f32;
        for p in parts {
            if let Some(v) = p.strip_prefix("beta=") {
                beta = v.parse()?;
            } else if let Some(v) = p.strip_prefix("beta2=") {
                beta2 = v.parse()?;
            } else if let Some(v) = p.strip_prefix("wd=") {
                wd = v.parse()?;
            } else {
                anyhow::bail!("bad optimizer component {p:?} in {s:?}");
            }
        }
        Ok(match kind {
            "demo-sgd" => OptSpec::DemoSgd {
                beta,
                weight_decay: wd,
            },
            "decoupled-adamw" => OptSpec::DecoupledAdamW {
                beta1: beta,
                beta2,
                weight_decay: wd,
            },
            "adamw" => OptSpec::AdamW {
                beta1: beta,
                beta2,
                weight_decay: wd,
            },
            "sgd" => OptSpec::Sgd { weight_decay: wd },
            _ => anyhow::bail!("unknown optimizer {kind:?} (demo-sgd|decoupled-adamw|adamw|sgd)"),
        })
    }

    pub fn build(&self, shard_len: usize) -> Box<dyn Optimizer> {
        match *self {
            OptSpec::DemoSgd { beta, weight_decay } => {
                Box::new(DemoSgd::new(shard_len, beta, weight_decay))
            }
            OptSpec::DecoupledAdamW {
                beta1,
                beta2,
                weight_decay,
            } => Box::new(DecoupledAdamW::new(shard_len, beta1, beta2, weight_decay)),
            OptSpec::AdamW {
                beta1,
                beta2,
                weight_decay,
            } => Box::new(AdamW::new(shard_len, beta1, beta2, weight_decay)),
            OptSpec::Sgd { weight_decay } => Box::new(Sgd::new(shard_len, weight_decay)),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            OptSpec::DemoSgd { .. } => "demo-sgd",
            OptSpec::DecoupledAdamW { .. } => "decoupled-adamw",
            OptSpec::AdamW { .. } => "adamw",
            OptSpec::Sgd { .. } => "sgd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(
            OptSpec::parse("demo-sgd").unwrap(),
            OptSpec::DemoSgd {
                beta: 0.9,
                weight_decay: 0.0
            }
        );
        assert_eq!(
            OptSpec::parse("decoupled-adamw:beta=0.8:beta2=0.95:wd=0.01").unwrap(),
            OptSpec::DecoupledAdamW {
                beta1: 0.8,
                beta2: 0.95,
                weight_decay: 0.01
            }
        );
        assert!(OptSpec::parse("rmsprop").is_err());
    }

    #[test]
    fn build_all() {
        for s in ["demo-sgd", "decoupled-adamw", "adamw", "sgd"] {
            let o = OptSpec::parse(s).unwrap().build(128);
            assert!(!o.name().is_empty());
        }
    }

    #[test]
    fn state_roundtrip_restores_bit_identical_trajectory() {
        // Drive an optimizer, checkpoint it, continue both the original
        // and the restored copy identically — params must match bitwise.
        for s in ["demo-sgd", "decoupled-adamw", "adamw", "sgd"] {
            let spec = OptSpec::parse(s).unwrap();
            let mut a = spec.build(16);
            let grad: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.1).collect();
            let mut pa: Vec<f32> = (0..16).map(|i| i as f32).collect();
            for _ in 0..3 {
                a.accumulate(&grad);
                let q: Vec<f32> = a.buffer_mut().to_vec();
                a.apply(&mut pa, &q, 0.01);
            }
            let mut b = spec.build(16);
            b.import_state(a.export_state()).unwrap();
            let mut pb = pa.clone();
            for _ in 0..3 {
                for (o, p) in [(&mut a, &mut pa), (&mut b, &mut pb)] {
                    o.accumulate(&grad);
                    let q: Vec<f32> = o.buffer_mut().to_vec();
                    o.apply(p, &q, 0.01);
                }
            }
            assert_eq!(pa, pb, "{s} diverged after restore");
            // shape mismatches are rejected with context
            let mut wrong = spec.build(8);
            assert!(wrong.import_state(a.export_state()).is_err(), "{s}");
            let mut bad = a.export_state();
            bad.vecs.push(vec![0.0]);
            assert!(spec.build(16).import_state(bad).is_err(), "{s}");
        }
    }

    #[test]
    fn decoupled_optimizers_avoid_state_sync() {
        // The paper's claim: syncing AdamW moments would cost 2× extra.
        let adamw = OptSpec::parse("decoupled-adamw").unwrap().build(1000);
        assert_eq!(adamw.state_bytes(), 2 * 1000 * 4);
        let sgd = OptSpec::parse("demo-sgd").unwrap().build(1000);
        assert_eq!(sgd.state_bytes(), 1000 * 4);
    }
}
