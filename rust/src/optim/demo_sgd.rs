//! DeMo-SGD: SGD with *decoupled* momentum (Peng et al. 2024; the paper's
//! default underlying optimizer).
//!
//! The momentum buffer `m ← βm + Δ` is the replication buffer: replicators
//! extract the fast components out of it (leaving the residual to keep
//! accumulating — the "controlled divergence" mechanism), and the final
//! synchronized Q drives a plain SGD update `θ ← θ − η·Q`.

use super::{fused_decay_step, Optimizer};
use crate::parallel::PoolHandle;

pub struct DemoSgd {
    pub beta: f32,
    pub weight_decay: f32,
    momentum: Vec<f32>,
    pool: PoolHandle,
}

impl DemoSgd {
    pub fn new(shard_len: usize, beta: f32, weight_decay: f32) -> DemoSgd {
        assert!((0.0..1.0).contains(&beta), "beta {beta}");
        DemoSgd {
            beta,
            weight_decay,
            momentum: vec![0.0; shard_len],
            pool: PoolHandle::default(),
        }
    }
}

impl Optimizer for DemoSgd {
    fn name(&self) -> String {
        format!("demo-sgd(b={})", self.beta)
    }

    fn attach_pool(&mut self, pool: PoolHandle) {
        self.pool = pool;
    }

    fn accumulate(&mut self, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.momentum.len());
        // m ← βm + Δ  (Algorithm 1; note: *not* (1−β)-scaled — DeMo keeps
        // the raw gradient magnitude so extraction thresholds stay scale-
        // comparable across β). Chunk-parallel on the unrolled lane
        // kernel, bit-identical at any worker count (pure elementwise).
        let beta = self.beta;
        crate::parallel::zip_chunks(self.pool.get(), &mut self.momentum, grad, |ms, gs| {
            crate::parallel::lanes::momentum(ms, beta, gs);
        });
    }

    fn buffer_mut(&mut self) -> &mut [f32] {
        &mut self.momentum
    }

    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), q.len());
        fused_decay_step(self.pool.get(), params, q, lr, self.weight_decay);
    }

    fn state_bytes(&self) -> u64 {
        (self.momentum.len() * 4) as u64
    }

    fn export_state(&self) -> super::OptState {
        super::OptState {
            vecs: vec![self.momentum.clone()],
            t: 0,
        }
    }

    fn import_state(&mut self, st: super::OptState) -> anyhow::Result<()> {
        let [momentum] = super::unpack_state("demo-sgd", st.vecs, [self.momentum.len()])?;
        self.momentum = momentum;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_accumulates_geometrically() {
        let mut o = DemoSgd::new(3, 0.5, 0.0);
        o.accumulate(&[1.0, 2.0, 4.0]);
        o.accumulate(&[1.0, 2.0, 4.0]);
        // m = 0.5·g + g = 1.5·g
        assert_eq!(o.buffer_mut(), &[1.5, 3.0, 6.0]);
    }

    #[test]
    fn apply_is_sgd_step() {
        let mut o = DemoSgd::new(2, 0.9, 0.0);
        let mut p = vec![1.0f32, -1.0];
        o.apply(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -0.95]);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut o = DemoSgd::new(1, 0.9, 0.1);
        let mut p = vec![10.0f32];
        o.apply(&mut p, &[0.0], 0.1);
        assert!((p[0] - 9.9).abs() < 1e-5);
    }

    #[test]
    fn residual_left_by_replicator_keeps_accumulating() {
        // Simulates the decoupling contract: replicator zeroes part of the
        // buffer; later gradients still fold in on top of the residual.
        let mut o = DemoSgd::new(2, 0.9, 0.0);
        o.accumulate(&[1.0, 1.0]);
        o.buffer_mut()[0] = 0.0; // extracted
        o.accumulate(&[1.0, 1.0]);
        let b = o.buffer_mut();
        assert!((b[0] - 1.0).abs() < 1e-6);
        assert!((b[1] - 1.9).abs() < 1e-6);
    }
}
