//! Decoupled AdamW (introduced by DeToNATION, paper §Decoupled AdamW).
//!
//! AdamW "with reducing communication in mind": the exponential moving
//! averages (first moment) and the moving average of squared gradients
//! (second moment) are **never synchronized** — syncing them "would
//! require 2-3 times more communication". Each rank runs Adam on its own
//! (intra-node averaged) gradient shard and pushes the resulting *update
//! direction* into the replication buffer; replicators then exchange the
//! selected components of that buffer across nodes.

use super::{fused_decay_step, Optimizer};
use crate::parallel::{self, lanes, PoolHandle, SlicePtr};

pub struct DecoupledAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m1: Vec<f32>,
    m2: Vec<f32>,
    /// Accumulated not-yet-replicated update mass (the replication buffer).
    buffer: Vec<f32>,
    t: u64,
    pool: PoolHandle,
}

impl DecoupledAdamW {
    pub fn new(shard_len: usize, beta1: f32, beta2: f32, weight_decay: f32) -> DecoupledAdamW {
        DecoupledAdamW {
            beta1,
            beta2,
            eps: 1e-8,
            weight_decay,
            m1: vec![0.0; shard_len],
            m2: vec![0.0; shard_len],
            buffer: vec![0.0; shard_len],
            t: 0,
            pool: PoolHandle::default(),
        }
    }
}

impl Optimizer for DecoupledAdamW {
    fn name(&self) -> String {
        format!("decoupled-adamw(b1={},b2={})", self.beta1, self.beta2)
    }

    fn attach_pool(&mut self, pool: PoolHandle) {
        self.pool = pool;
    }

    fn accumulate(&mut self, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.m1.len());
        self.t += 1;
        let (beta1, beta2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - beta1.powi(self.t as i32);
        let bc2 = 1.0 - beta2.powi(self.t as i32);
        // Fused single sweep: both moment updates and the buffer push in
        // one pass, chunk-parallel on the unrolled lane kernel (pure
        // elementwise — bit-identical at any worker count). The Adam
        // update direction joins whatever residual the replicator left
        // behind from previous steps.
        let consts = lanes::AdamConsts {
            beta1,
            beta2,
            bc1,
            bc2,
            eps,
        };
        let pool = self.pool.clone();
        let m1 = SlicePtr::new(&mut self.m1);
        let m2 = SlicePtr::new(&mut self.m2);
        let buf = SlicePtr::new(&mut self.buffer);
        parallel::run_chunks(pool.get(), grad.len(), |_w, lo, hi| {
            // Safety: grid chunks are disjoint per task.
            let m1 = unsafe { m1.range(lo, hi) };
            let m2 = unsafe { m2.range(lo, hi) };
            let buf = unsafe { buf.range(lo, hi) };
            lanes::dadamw_accum(m1, m2, buf, &grad[lo..hi], consts);
        });
    }

    fn buffer_mut(&mut self) -> &mut [f32] {
        &mut self.buffer
    }

    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), q.len());
        fused_decay_step(self.pool.get(), params, q, lr, self.weight_decay);
    }

    fn state_bytes(&self) -> u64 {
        ((self.m1.len() + self.m2.len()) * 4) as u64
    }

    fn export_state(&self) -> super::OptState {
        super::OptState {
            vecs: vec![self.m1.clone(), self.m2.clone(), self.buffer.clone()],
            t: self.t,
        }
    }

    fn import_state(&mut self, st: super::OptState) -> anyhow::Result<()> {
        let lens = [self.m1.len(), self.m2.len(), self.buffer.len()];
        let [m1, m2, buffer] = super::unpack_state("decoupled-adamw", st.vecs, lens)?;
        self.m1 = m1;
        self.m2 = m2;
        self.buffer = buffer;
        self.t = st.t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_update_is_unit_scale() {
        // With bias correction, step 1 gives m̂/√v̂ = g/|g| = ±1.
        let mut o = DecoupledAdamW::new(3, 0.9, 0.999, 0.0);
        o.accumulate(&[0.5, -2.0, 0.0]);
        let b = o.buffer_mut();
        assert!((b[0] - 1.0).abs() < 1e-3, "{}", b[0]);
        assert!((b[1] + 1.0).abs() < 1e-3, "{}", b[1]);
        assert_eq!(b[2], 0.0);
    }

    #[test]
    fn moments_stay_local_buffer_accumulates() {
        let mut o = DecoupledAdamW::new(1, 0.9, 0.999, 0.0);
        o.accumulate(&[1.0]);
        o.accumulate(&[1.0]);
        // buffer ≈ 2 (two ±1 steps), moments not exposed to the wire
        assert!((o.buffer_mut()[0] - 2.0).abs() < 1e-2);
        assert_eq!(o.state_bytes(), 8);
    }

    #[test]
    fn apply_subtracts_lr_times_q_with_decay() {
        let mut o = DecoupledAdamW::new(2, 0.9, 0.999, 0.5);
        let mut p = vec![2.0f32, -2.0];
        o.apply(&mut p, &[1.0, -1.0], 0.1);
        // decay 1−0.05 then −0.1·q
        assert!((p[0] - (2.0 * 0.95 - 0.1)).abs() < 1e-6);
        assert!((p[1] - (-2.0 * 0.95 + 0.1)).abs() < 1e-6);
    }

    #[test]
    fn adaptive_scaling_dampens_large_gradients() {
        let mut big = DecoupledAdamW::new(1, 0.9, 0.999, 0.0);
        let mut small = DecoupledAdamW::new(1, 0.9, 0.999, 0.0);
        big.accumulate(&[100.0]);
        small.accumulate(&[0.01]);
        // Both step ≈ 1 — Adam normalizes magnitude.
        assert!((big.buffer_mut()[0] - small.buffer_mut()[0]).abs() < 1e-3);
    }
}
