//! Plain SGD on the synchronized gradient — ablation arm ("we
//! differentiate [DeMo-SGD] as it accumulates momenta"; this one doesn't).

use super::{fused_decay_step, Optimizer};
use crate::parallel::PoolHandle;

pub struct Sgd {
    pub weight_decay: f32,
    buffer: Vec<f32>,
    pool: PoolHandle,
}

impl Sgd {
    pub fn new(shard_len: usize, weight_decay: f32) -> Sgd {
        Sgd {
            weight_decay,
            buffer: vec![0.0; shard_len],
            pool: PoolHandle::default(),
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".to_string()
    }

    fn attach_pool(&mut self, pool: PoolHandle) {
        self.pool = pool;
    }

    fn accumulate(&mut self, grad: &[f32]) {
        self.buffer.copy_from_slice(grad);
    }

    fn buffer_mut(&mut self) -> &mut [f32] {
        &mut self.buffer
    }

    fn apply(&mut self, params: &mut [f32], q: &[f32], lr: f32) {
        fused_decay_step(self.pool.get(), params, q, lr, self.weight_decay);
    }

    fn state_bytes(&self) -> u64 {
        0
    }

    fn export_state(&self) -> super::OptState {
        super::OptState {
            vecs: vec![self.buffer.clone()],
            t: 0,
        }
    }

    fn import_state(&mut self, st: super::OptState) -> anyhow::Result<()> {
        let [buffer] = super::unpack_state("sgd", st.vecs, [self.buffer.len()])?;
        self.buffer = buffer;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stateless_step() {
        let mut o = Sgd::new(2, 0.0);
        let mut p = vec![1.0f32, 2.0];
        o.apply(&mut p, &[1.0, -1.0], 0.5);
        assert_eq!(p, vec![0.5, 2.5]);
        assert_eq!(o.state_bytes(), 0);
    }
}
