//! Experiment configuration: one struct, JSON-file + CLI-override surface.
//!
//! Every launcher entry point (main binary, examples, figure benches)
//! builds an [`ExperimentConfig`], so runs are fully described by a small
//! JSON document (written next to the metrics for reproducibility).

use std::path::PathBuf;

use crate::net::{ClusterModel, FaultTimeline, MembershipTimeline, NetModel};
use crate::optim::OptSpec;
use crate::replicate::control::parse_rate;
use crate::replicate::{ControlSpec, LatePolicy, ReplSpec, SyncTopology};
use crate::util::json::Json;

/// A recorded `--staleness` intent, held until it can attach to a DiLoCo
/// spec (see [`ExperimentConfig::validate`] — flags fold in any order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessArg {
    /// `--staleness auto`: derive one window per node from its profile.
    Auto,
    /// `--staleness S`: one global window.
    Fixed(u64),
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifact/model name (e.g. "lm-small").
    pub model: String,
    pub artifacts_dir: PathBuf,
    /// Cluster shape.
    pub nodes: usize,
    pub accels_per_node: usize,
    /// Optimizer + replication scheme.
    pub opt: OptSpec,
    pub repl: ReplSpec,
    /// Learning-rate schedule: linear warmup then constant (the paper's
    /// small-scale runs use constant LR; OLMo uses 4% warmup).
    pub lr: f32,
    pub warmup_steps: u64,
    pub steps: u64,
    pub seed: u64,
    /// Validation cadence (0 = never) and size.
    pub val_every: u64,
    pub val_batches: u64,
    /// Network model for the simulated cluster.
    pub net: NetModel,
    /// Number of distinct gradient streams actually computed (0 = world
    /// size). Large-scale sims (Fig 5/6) compute a few real streams and
    /// mirror them — the comm clock still models every rank (DESIGN.md §2).
    pub compute_streams: usize,
    /// Event-engine scheduling: true = overlap communication with compute
    /// (the default); false = legacy barrier-serialized phases
    /// (`--no-overlap`, bit-parity with the old `SimClock`).
    pub overlap: bool,
    /// Execution slots of the persistent worker pool that runs the data
    /// plane: per-stream fwd/bwd fan-out *and* the chunk-parallel
    /// kernels (collectives, optimizer updates, DCT batches, eval).
    /// 1 = fully inline, 0 = one slot per hardware thread. Never changes
    /// numerics — results are bit-identical for any value (prop-tested).
    pub threads: usize,
    /// Dump the engine's scheduled comm events as Chrome-trace JSON to
    /// this path after the run (`--trace-out`; None = off).
    pub trace_out: Option<PathBuf>,
    /// Pipelined-comm bucket size in MiB (`--bucket-mb`): reduce-scatter
    /// and replication-gather traffic splits into per-bucket events so
    /// the first bucket's communication overlaps the remaining buckets'
    /// compression. 0 = whole-phase events (default). Only affects the
    /// overlapped schedule — never numerics, never `--no-overlap` totals.
    pub bucket_mb: f64,
    /// Per-node stragglers + NIC bandwidth overrides (empty = uniform).
    pub cluster: ClusterModel,
    /// `--staleness auto`: derive each node's async DiLoCo staleness
    /// from its simulated compute/NIC profile
    /// ([`ClusterModel::auto_staleness`]) instead of one global S.
    pub staleness_auto: bool,
    /// `--node-staleness R:S[,R:S…]`: explicit per-node staleness
    /// overrides (index = node; `None` = use the global/auto value).
    pub node_staleness: Vec<Option<u64>>,
    /// Deterministic join/leave/crash timeline (`--churn`, `--crash`;
    /// empty = fixed group, bit-identical to the pre-elastic path).
    pub membership: MembershipTimeline,
    /// `--quorum K`: finalize a deferred sync window as soon as ≥K of g
    /// contributions have landed instead of waiting on the arrival
    /// deadline (0 = off, deadline semantics only).
    pub quorum: usize,
    /// `--checkpoint-dir`: persist full trainer state here after every
    /// completed sync window, and restore crashed nodes from it on
    /// rejoin (None = off).
    pub checkpoint_dir: Option<PathBuf>,
    /// Deterministic link-fault timeline (`--link-fault`; empty = the
    /// perfect network, bit-identical to the pre-fault path).
    pub link_fault: FaultTimeline,
    /// `--max-retries`: attempts re-charged on the NIC timeline before a
    /// failed/corrupt transfer gives up and falls back to the
    /// late-arrival machinery.
    pub max_retries: u32,
    /// `--retry-timeout`: sim-seconds a sender waits on a failed attempt
    /// before re-charging the transfer.
    pub retry_timeout: f64,
    /// `--retry-backoff`: base of the capped exponential backoff added
    /// per retry attempt (sim-seconds; cap is 8x the base).
    pub retry_backoff: f64,
    /// `--topology`: which peers each R-group member exchanges payloads
    /// with per sync window ([`SyncTopology`]; `full` = the bit-frozen
    /// whole-group path, `ring`/`random-pair`/`hier:<F>` = NoLoCo-style
    /// gossip with O(1) per-window inter-node cost).
    pub topology: SyncTopology,
    /// `--compress-control`: the closed-loop per-node rate controller
    /// ([`crate::replicate::RateController`]; `off` = bit-frozen
    /// fixed-rate default, `aimd[:key=val…]` = AIMD on NIC occupancy).
    pub compress_control: ControlSpec,
    /// `--control-window`: steps between controller retunes (>= 1).
    pub control_window: u64,
    /// `--rate-min` / `--rate-max`: the band the controller may move a
    /// node's compression rate within (`1/N` or float forms).
    pub rate_min: f64,
    pub rate_max: f64,
    /// `--staleness` intent not yet folded into the spec (attaches to
    /// whichever `--repl` the config ends up with; leftover incompatible
    /// intents are reported by [`ExperimentConfig::validate`]).
    pub pending_staleness: Option<StalenessArg>,
    /// `--late-policy` intent not yet folded into the spec.
    pub pending_late_policy: Option<LatePolicy>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "lm-tiny".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            nodes: 2,
            accels_per_node: 2,
            opt: OptSpec::DemoSgd {
                beta: 0.9,
                weight_decay: 0.0,
            },
            repl: ReplSpec::parse("demo:1/8").unwrap(),
            lr: 1e-3,
            warmup_steps: 0,
            steps: 100,
            seed: 0xD37,
            val_every: 0,
            val_batches: 8,
            net: NetModel::hpc(),
            compute_streams: 0,
            overlap: true,
            threads: 1,
            trace_out: None,
            bucket_mb: 0.0,
            cluster: ClusterModel::uniform(),
            staleness_auto: false,
            node_staleness: Vec::new(),
            membership: MembershipTimeline::new(),
            quorum: 0,
            checkpoint_dir: None,
            link_fault: FaultTimeline::new(),
            max_retries: 3,
            retry_timeout: 0.1,
            retry_backoff: 0.05,
            topology: SyncTopology::Full,
            compress_control: ControlSpec::Off,
            control_window: 8,
            rate_min: 1.0 / 64.0,
            rate_max: 1.0 / 4.0,
            pending_staleness: None,
            pending_late_policy: None,
        }
    }
}

impl ExperimentConfig {
    pub fn world_size(&self) -> usize {
        self.nodes * self.accels_per_node
    }

    /// Comm-pipelining bucket size in bytes (0 = whole-phase events).
    pub fn bucket_bytes(&self) -> u64 {
        (self.bucket_mb * (1u64 << 20) as f64).round() as u64
    }

    /// The async DiLoCo staleness knob (`--staleness`): steps between a
    /// sync launch and the application of its averaged delta. 0 for
    /// every synchronous configuration (including plain `diloco:N`
    /// without the knob).
    pub fn staleness(&self) -> u64 {
        match self.repl {
            ReplSpec::DiLoCo {
                staleness: Some(s), ..
            } => s,
            _ => 0,
        }
    }

    /// The late-arrival policy of the async DiLoCo window
    /// (`--late-policy`, or the `async=S,policy` spec component).
    /// [`LatePolicy::Wait`] for every non-DiLoCo scheme.
    pub fn late_policy(&self) -> LatePolicy {
        match self.repl {
            ReplSpec::DiLoCo { policy, .. } => policy,
            _ => LatePolicy::Wait,
        }
    }

    /// Resolve the per-node staleness table: the global `--staleness`
    /// value everywhere, replaced by the profile-derived
    /// [`ClusterModel::auto_staleness`] under `--staleness auto`, then
    /// patched by explicit `--node-staleness R:S` overrides. `step_flops`
    /// and `gather_bytes` feed the auto derivation (the trainer passes
    /// the model's step cost and its per-node send-volume estimate).
    /// Every entry is validated against the DiLoCo period; non-DiLoCo
    /// schemes only accept an all-zero result.
    pub fn resolve_node_staleness(
        &self,
        step_flops: f64,
        gather_bytes: u64,
    ) -> anyhow::Result<Vec<u64>> {
        let period = match self.repl {
            ReplSpec::DiLoCo { period, .. } => Some(period),
            _ => None,
        };
        let mut table = if self.staleness_auto {
            let period = period
                .ok_or_else(|| anyhow::anyhow!("--staleness auto requires the diloco replicator"))?;
            self.cluster
                .auto_staleness(&self.net, self.nodes, step_flops, gather_bytes, period)
        } else {
            vec![self.staleness(); self.nodes]
        };
        for (node, s) in self.node_staleness.iter().enumerate() {
            if let Some(s) = *s {
                anyhow::ensure!(
                    node < self.nodes,
                    "--node-staleness names node {node}, but the cluster has {} nodes",
                    self.nodes
                );
                table[node] = s;
            }
        }
        match period {
            Some(period) => {
                for (node, &s) in table.iter().enumerate() {
                    anyhow::ensure!(
                        s < period,
                        "node {node} staleness {s} must be < diloco period {period} \
                         (one gather in flight at a time)"
                    );
                }
            }
            None => anyhow::ensure!(
                table.iter().all(|&s| s == 0),
                "per-node staleness only applies to the diloco replicator (got {:?})",
                self.repl.label()
            ),
        }
        Ok(table)
    }

    /// Parse the `--node-staleness` table, "NODE:S[,NODE:S…]". In a
    /// *mixed* table, S = 0 makes that node aggregate at the launch
    /// step itself: under `wait` it blocks on every peer transfer
    /// (synchronous-style), under `drop`/`partial` it averages whatever
    /// has landed by its own backward end — typically only its own
    /// delta on slow links. An **all-zero** resolved table means no
    /// async window exists at all: the run is plain synchronous DiLoCo
    /// and the late policy is inert (there are never late arrivals).
    pub fn parse_node_staleness(spec: &str) -> anyhow::Result<Vec<Option<u64>>> {
        let mut table: Vec<Option<u64>> = Vec::new();
        if spec.trim().is_empty() {
            return Ok(table);
        }
        for part in spec.split(',') {
            let (node, value) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad entry {part:?}, want NODE:STALENESS"))?;
            let node: usize = node.trim().parse()?;
            anyhow::ensure!(node < 65_536, "node index {node} out of range");
            let value: u64 = value.trim().parse()?;
            if table.len() <= node {
                table.resize(node + 1, None);
            }
            table[node] = Some(value);
        }
        Ok(table)
    }

    /// Fold recorded `--staleness` / `--late-policy` intents into the
    /// current replication spec where they fit, silently keeping what
    /// doesn't fit pending (for [`ExperimentConfig::validate`] to
    /// report). Best-effort and idempotent — called after every
    /// [`ExperimentConfig::apply_arg`] so the spec, its label, and the
    /// [`ExperimentConfig::staleness`]/[`ExperimentConfig::late_policy`]
    /// accessors are correct in *any* flag order.
    fn fold_pending(&mut self) {
        if let ReplSpec::DiLoCo {
            period, staleness, ..
        } = &mut self.repl
        {
            match self.pending_staleness {
                Some(StalenessArg::Auto) => {
                    // Arm the async machinery; the trainer fills the
                    // per-node table at resolve time.
                    staleness.get_or_insert(0);
                    self.staleness_auto = true;
                    self.pending_staleness = None;
                }
                Some(StalenessArg::Fixed(s)) if s < *period => {
                    *staleness = Some(s);
                    self.staleness_auto = false;
                    self.pending_staleness = None;
                }
                // Out-of-band values stay pending: validate reports them
                // against the period they failed to fit.
                Some(StalenessArg::Fixed(_)) | None => {}
            }
        }
        if let ReplSpec::DiLoCo { policy, .. } = &mut self.repl {
            if let Some(p) = self.pending_late_policy.take() {
                *policy = p;
            }
        }
        // A per-node staleness table arms the async window on whichever
        // DiLoCo spec is current (values validate at resolve time).
        if self.node_staleness.iter().any(|s| s.is_some_and(|s| s > 0)) {
            if let ReplSpec::DiLoCo { staleness, .. } = &mut self.repl {
                staleness.get_or_insert(0);
            }
        }
    }

    /// Validate the whole configuration at once — every cross-flag
    /// incompatibility (repl × staleness × late-policy × controller),
    /// plus the mesh-dependent checks (membership/fault timelines,
    /// topology shape, quorum vs group size), reported together in one
    /// error instead of one-at-a-time in flag order. Called at trainer
    /// construction, once mesh shape and step count are final; folds
    /// pending intents first, so it is order-independent and idempotent.
    pub fn validate(&mut self) -> anyhow::Result<()> {
        self.fold_pending();
        let mut errors: Vec<String> = Vec::new();
        match self.pending_staleness {
            Some(StalenessArg::Auto) => errors.push(format!(
                "--staleness auto only applies to the diloco replicator (got {:?})",
                self.repl.label()
            )),
            Some(StalenessArg::Fixed(s)) => {
                if let ReplSpec::DiLoCo { period, .. } = self.repl {
                    // It failed to fold, so it broke the period bound.
                    errors.push(format!(
                        "staleness {s} must be < diloco period {period} \
                         (one gather in flight at a time)"
                    ));
                } else if s > 0 {
                    errors.push(format!(
                        "--staleness only applies to the diloco replicator (got {:?})",
                        self.repl.label()
                    ));
                }
                // s = 0 on a non-diloco scheme is the harmless default.
            }
            None => {}
        }
        if let Some(p) = self.pending_late_policy {
            // Only a real (non-Wait) policy needs the deferring scheme.
            if p != LatePolicy::Wait {
                errors.push(format!(
                    "--late-policy only applies to the diloco replicator (got {:?})",
                    self.repl.label()
                ));
            }
        }
        if self.node_staleness.iter().any(|s| s.is_some_and(|s| s > 0))
            && !matches!(self.repl, ReplSpec::DiLoCo { .. })
        {
            errors.push(format!(
                "--node-staleness only applies to the diloco replicator (got {:?})",
                self.repl.label()
            ));
        }
        if self.compress_control.is_armed()
            && !matches!(
                self.repl,
                ReplSpec::Demo { .. } | ReplSpec::Random { .. } | ReplSpec::Striding { .. }
            )
        {
            errors.push(format!(
                "--compress-control {} only applies to demo/random/striding (got {:?})",
                self.compress_control.label(),
                self.repl.label()
            ));
        }
        if self.control_window == 0 {
            errors.push("--control-window must be >= 1 steps".into());
        }
        if !(self.rate_min > 0.0 && self.rate_min <= self.rate_max && self.rate_max <= 1.0) {
            errors.push(format!(
                "need 0 < rate-min <= rate-max <= 1 (got {} / {})",
                self.rate_min, self.rate_max
            ));
        }
        if let Err(e) = self.membership.validate(self.nodes, self.steps) {
            errors.push(e.to_string());
        }
        if let Err(e) = self.link_fault.validate(self.nodes) {
            errors.push(e.to_string());
        }
        // The replication group spans one member per node, so the
        // topology validates against the node count.
        if let Err(e) = self.topology.validate(self.nodes) {
            errors.push(e.to_string());
        }
        if !(self.retry_timeout.is_finite() && self.retry_timeout >= 0.0) {
            errors.push("--retry-timeout must be a finite non-negative sim-time".into());
        }
        if !(self.retry_backoff.is_finite() && self.retry_backoff >= 0.0) {
            errors.push("--retry-backoff must be a finite non-negative sim-time".into());
        }
        if self.quorum > self.nodes {
            errors.push(format!(
                "--quorum {} exceeds the replication group size ({} nodes)",
                self.quorum, self.nodes
            ));
        }
        match errors.len() {
            0 => Ok(()),
            1 => anyhow::bail!("{}", errors.remove(0)),
            n => anyhow::bail!(
                "invalid configuration ({n} errors):\n  - {}",
                errors.join("\n  - ")
            ),
        }
    }

    /// Effective LR at a step (linear warmup → constant).
    pub fn lr_at(&self, step: u64) -> f32 {
        if self.warmup_steps == 0 || step >= self.warmup_steps {
            self.lr
        } else {
            self.lr * (step + 1) as f32 / self.warmup_steps as f32
        }
    }

    /// Serialize for the run directory.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            (
                "artifacts_dir",
                Json::Str(self.artifacts_dir.display().to_string()),
            ),
            ("nodes", Json::Num(self.nodes as f64)),
            ("accels_per_node", Json::Num(self.accels_per_node as f64)),
            ("opt", Json::Str(self.opt.label().to_string())),
            ("repl", Json::Str(self.repl.label())),
            ("lr", Json::Num(self.lr as f64)),
            ("warmup_steps", Json::Num(self.warmup_steps as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("val_every", Json::Num(self.val_every as f64)),
            ("val_batches", Json::Num(self.val_batches as f64)),
            ("inter_bw_bytes_per_s", Json::Num(self.net.inter_bw)),
            ("intra_bw_bytes_per_s", Json::Num(self.net.intra_bw)),
            ("device_flops", Json::Num(self.net.device_flops)),
            ("compute_streams", Json::Num(self.compute_streams as f64)),
            ("overlap", Json::Bool(self.overlap)),
            ("threads", Json::Num(self.threads as f64)),
            (
                "trace_out",
                Json::Str(
                    self.trace_out
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                ),
            ),
            ("bucket_mb", Json::Num(self.bucket_mb)),
            ("staleness", Json::Num(self.staleness() as f64)),
            ("staleness_auto", Json::Bool(self.staleness_auto)),
            (
                "node_staleness",
                Json::Arr(
                    self.node_staleness
                        .iter()
                        .map(|s| s.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
            (
                "late_policy",
                Json::Str(self.late_policy().label().to_string()),
            ),
            ("membership", Json::Str(self.membership.render())),
            ("quorum", Json::Num(self.quorum as f64)),
            (
                "checkpoint_dir",
                Json::Str(
                    self.checkpoint_dir
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                ),
            ),
            ("link_fault", Json::Str(self.link_fault.render())),
            ("topology", Json::Str(self.topology.label())),
            (
                "compress_control",
                Json::Str(self.compress_control.label().to_string()),
            ),
            ("control_window", Json::Num(self.control_window as f64)),
            ("rate_min", Json::Num(self.rate_min)),
            ("rate_max", Json::Num(self.rate_max)),
            ("max_retries", Json::Num(self.max_retries as f64)),
            ("retry_timeout", Json::Num(self.retry_timeout)),
            ("retry_backoff", Json::Num(self.retry_backoff)),
            (
                "stragglers",
                Json::Arr(self.cluster.slowdown.iter().map(|&s| Json::Num(s)).collect()),
            ),
            (
                "node_inter_bw",
                Json::Arr(
                    self.cluster
                        .node_inter_bw
                        .iter()
                        .map(|&b| Json::Num(b))
                        .collect(),
                ),
            ),
        ])
    }

    /// Apply CLI-style overrides (used by the launcher and examples).
    pub fn apply_arg(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "model" => self.model = value.into(),
            "artifacts" => self.artifacts_dir = value.into(),
            "nodes" => self.nodes = value.parse()?,
            "accels" => self.accels_per_node = value.parse()?,
            "opt" => self.opt = OptSpec::parse(value)?,
            "repl" => self.repl = ReplSpec::parse(value)?,
            "lr" => self.lr = value.parse()?,
            "warmup" => self.warmup_steps = value.parse()?,
            "steps" => self.steps = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "val-every" => self.val_every = value.parse()?,
            "val-batches" => self.val_batches = value.parse()?,
            "inter-mbps" => {
                self.net.inter_bw = value.parse::<f64>()? * 1e6 / 8.0;
            }
            "streams" => self.compute_streams = value.parse()?,
            "overlap" => self.overlap = value.parse()?,
            "threads" => self.threads = value.parse()?,
            "trace-out" => {
                self.trace_out = if value.is_empty() {
                    None
                } else {
                    Some(value.into())
                };
            }
            "bucket-mb" => {
                let mb: f64 = value.parse()?;
                anyhow::ensure!(mb >= 0.0 && mb.is_finite(), "bucket-mb must be >= 0");
                self.bucket_mb = mb;
            }
            // Async DiLoCo: apply the periodic sync `S` steps after its
            // launch (S = 0 runs the async path, bit-identical to the
            // synchronous scheme). "auto" derives one S per node from
            // its simulated compute/NIC profile. Recorded as an intent
            // and folded into whichever spec the config ends up with —
            // `--staleness`/`--repl` compose in either order; an intent
            // that never fits is reported by `validate`.
            "staleness" => {
                self.pending_staleness = Some(if value == "auto" {
                    StalenessArg::Auto
                } else {
                    StalenessArg::Fixed(value.parse()?)
                });
            }
            // Per-node staleness overrides (straggler-tolerant async
            // DiLoCo); values are validated against the period at resolve
            // time, scheme compatibility by `validate` — order-free.
            "node-staleness" => self.node_staleness = Self::parse_node_staleness(value)?,
            // What an aggregation does with peer contributions that miss
            // its arrival deadline; "wait" is the harmless default for
            // every scheme. Intent-recorded like --staleness (and like
            // it, an explicit flag beats the `async=S,policy` spec form
            // regardless of flag order).
            "late-policy" => self.pending_late_policy = Some(LatePolicy::parse(value)?),
            // Closed-loop per-node compression control. Cross-checks
            // against the scheme (sparse-only) live in `validate`.
            "compress-control" => self.compress_control = ControlSpec::parse(value)?,
            "control-window" => {
                let w: u64 = value.parse()?;
                anyhow::ensure!(w >= 1, "--control-window must be >= 1 steps");
                self.control_window = w;
            }
            "rate-min" => self.rate_min = parse_rate(value)?,
            "rate-max" => self.rate_max = parse_rate(value)?,
            "straggler" => self.cluster.slowdown = ClusterModel::parse_slowdown(value)?,
            "node-mbps" => self.cluster.node_inter_bw = ClusterModel::parse_node_mbps(value)?,
            // Elastic membership: --churn and --crash both append to one
            // timeline, so the two flags compose. Syntax errors surface
            // here; semantic validation against the mesh shape and step
            // count happens at trainer construction (validate).
            "churn" => self.membership.add_churn_spec(value)?,
            "crash" => self.membership.add_crash_spec(value)?,
            "quorum" => {
                let k: usize = value.parse()?;
                anyhow::ensure!(
                    k >= 1,
                    "--quorum must be >= 1 (omit the flag for deadline-only windows)"
                );
                self.quorum = k;
            }
            "checkpoint-dir" => {
                self.checkpoint_dir = if value.is_empty() {
                    None
                } else {
                    Some(value.into())
                };
            }
            // Link faults: repeated flags append to one timeline, so
            // drop/corrupt/flap/degrade specs compose. Syntax errors
            // surface here; endpoint validation against the mesh happens
            // at trainer construction (validate).
            "link-fault" => self.link_fault.add_spec(value)?,
            // Sync-window exchange topology; shape validation against
            // the mesh happens at trainer construction (validate).
            "topology" => self.topology = SyncTopology::parse(value)?,
            "max-retries" => self.max_retries = value.parse()?,
            "retry-timeout" => {
                let t: f64 = value.parse()?;
                anyhow::ensure!(t >= 0.0 && t.is_finite(), "retry-timeout must be >= 0");
                self.retry_timeout = t;
            }
            "retry-backoff" => {
                let b: f64 = value.parse()?;
                anyhow::ensure!(b >= 0.0 && b.is_finite(), "retry-backoff must be >= 0");
                self.retry_backoff = b;
            }
            other => anyhow::bail!("unknown config key {other:?}"),
        }
        self.fold_pending();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.world_size(), 4);
        assert_eq!(c.lr_at(0), c.lr);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let c = ExperimentConfig {
            warmup_steps: 10,
            lr: 1.0,
            ..Default::default()
        };
        assert!((c.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((c.lr_at(4) - 0.5).abs() < 1e-6);
        assert_eq!(c.lr_at(10), 1.0);
        assert_eq!(c.lr_at(999), 1.0);
    }

    #[test]
    fn apply_args() {
        let mut c = ExperimentConfig::default();
        c.apply_arg("model", "vit-small").unwrap();
        c.apply_arg("nodes", "8").unwrap();
        c.apply_arg("repl", "random:1/16").unwrap();
        c.apply_arg("opt", "adamw").unwrap();
        c.apply_arg("inter-mbps", "100").unwrap();
        assert_eq!(c.model, "vit-small");
        assert_eq!(c.nodes, 8);
        assert_eq!(c.repl.label(), "random-1/16");
        assert!((c.net.inter_bw - 12.5e6).abs() < 1.0);
        assert!(c.apply_arg("bogus", "1").is_err());
    }

    #[test]
    fn staleness_knob_attaches_to_diloco_only() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.staleness(), 0);
        // 0 is a harmless default on non-diloco schemes…
        c.apply_arg("staleness", "0").unwrap();
        c.validate().unwrap();
        // …but a real staleness needs the periodic scheme: the intent is
        // recorded at apply time and reported by validate
        c.apply_arg("staleness", "2").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(
            err.contains("--staleness only applies to the diloco replicator"),
            "{err}"
        );
        c.apply_arg("repl", "diloco:8").unwrap();
        // the pending intent folded into the new spec — order-free
        assert_eq!(c.staleness(), 2);
        assert_eq!(c.repl.label(), "diloco-1/8-async2");
        c.validate().unwrap();
        assert_eq!(c.to_json().get("staleness").unwrap().as_usize(), Some(2));
        // staleness 0 on diloco selects the async implementation (S = 0)
        c.apply_arg("staleness", "0").unwrap();
        assert_eq!(c.staleness(), 0);
        assert_eq!(c.repl.label(), "diloco-1/8-async0");
        // bounded by the period (reported with both numbers)
        c.apply_arg("staleness", "8").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(
            err.contains("staleness 8 must be < diloco period 8"),
            "{err}"
        );
        // the spec keeps its last valid shape while the bad intent waits
        assert_eq!(c.staleness(), 0);
        // garbage values still fail at parse time
        assert!(c.apply_arg("staleness", "-1").is_err());
        assert!(c.apply_arg("staleness", "nan").is_err());
    }

    #[test]
    fn flag_order_is_irrelevant() {
        // The PR-9 ordering hacks are gone: every legal flag set yields
        // the same config whichever order it arrives in.
        let args = [
            ("staleness", "2"),
            ("late-policy", "drop"),
            ("node-staleness", "1:3"),
            ("repl", "diloco:8"),
            ("quorum", "2"),
        ];
        let mut fwd = ExperimentConfig::default();
        for (k, v) in args {
            fwd.apply_arg(k, v).unwrap();
        }
        fwd.validate().unwrap();
        let mut rev = ExperimentConfig::default();
        for (k, v) in args.iter().rev() {
            rev.apply_arg(k, v).unwrap();
        }
        rev.validate().unwrap();
        assert_eq!(fwd.repl, rev.repl);
        assert_eq!(fwd.staleness(), 2);
        assert_eq!(fwd.late_policy(), LatePolicy::Drop);
        assert_eq!(fwd.node_staleness, rev.node_staleness);
        assert_eq!(fwd.to_json().to_string(), rev.to_json().to_string());
    }

    #[test]
    fn validate_reports_all_errors_at_once() {
        let mut c = ExperimentConfig::default();
        c.apply_arg("staleness", "2").unwrap(); // demo scheme: incompatible
        c.apply_arg("topology", "ring").unwrap(); // needs >= 3 nodes, have 2
        c.apply_arg("quorum", "5").unwrap(); // exceeds the 2-node group
        c.apply_arg("compress-control", "aimd").unwrap();
        c.apply_arg("rate-min", "1/4").unwrap();
        c.apply_arg("rate-max", "1/8").unwrap(); // inverted band
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("invalid configuration (4 errors)"), "{err}");
        assert!(
            err.contains("--staleness only applies to the diloco replicator"),
            "{err}"
        );
        assert!(err.contains(">= 3") && err.contains("got 2"), "{err}");
        assert!(err.contains("--quorum 5 exceeds"), "{err}");
        assert!(
            err.contains("need 0 < rate-min <= rate-max <= 1"),
            "{err}"
        );
        // fixing everything clears the report — validate is idempotent
        c.apply_arg("repl", "diloco:8").unwrap();
        c.apply_arg("compress-control", "off").unwrap();
        c.apply_arg("nodes", "5").unwrap();
        c.apply_arg("rate-max", "1/2").unwrap();
        c.validate().unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn compress_control_knobs() {
        let mut c = ExperimentConfig::default();
        assert!(!c.compress_control.is_armed());
        c.validate().unwrap(); // off composes with everything
        // armed: needs a sparse every-step scheme
        c.apply_arg("compress-control", "aimd:add=1/32").unwrap();
        c.apply_arg("repl", "random:1/8").unwrap();
        c.validate().unwrap();
        for repl in ["diloco:8", "full"] {
            c.apply_arg("repl", repl).unwrap();
            let err = c.validate().unwrap_err().to_string();
            assert!(
                err.contains("--compress-control aimd only applies to demo/random/striding"),
                "{err}"
            );
        }
        c.apply_arg("repl", "striding:1/8").unwrap();
        c.validate().unwrap();
        // window and band knobs parse both rate forms and reject nonsense
        c.apply_arg("control-window", "4").unwrap();
        assert_eq!(c.control_window, 4);
        assert!(c.apply_arg("control-window", "0").is_err());
        c.apply_arg("rate-min", "1/64").unwrap();
        c.apply_arg("rate-max", "0.25").unwrap();
        assert_eq!(c.rate_min, 1.0 / 64.0);
        assert_eq!(c.rate_max, 0.25);
        assert!(c.apply_arg("rate-min", "0").is_err());
        assert!(c.apply_arg("rate-max", "1/0").is_err());
        assert!(c.apply_arg("compress-control", "pid").is_err());
        // everything serializes
        let j = c.to_json();
        assert_eq!(j.get("compress_control").unwrap().as_str(), Some("aimd"));
        assert_eq!(j.get("control_window").unwrap().as_usize(), Some(4));
        assert!(j.get("rate_min").is_some() && j.get("rate_max").is_some());
    }

    #[test]
    fn staleness_auto_and_node_table_knobs() {
        let mut c = ExperimentConfig::default();
        // auto / node tables are diloco-only: recorded at apply time,
        // reported by validate with the offending scheme named
        c.apply_arg("staleness", "auto").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(
            err.contains("--staleness auto only applies to the diloco replicator"),
            "{err}"
        );
        c.pending_staleness = None;
        c.apply_arg("node-staleness", "1:2").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(
            err.contains("--node-staleness only applies to the diloco replicator"),
            "{err}"
        );
        c.apply_arg("node-staleness", "").unwrap(); // empty is a no-op
        c.validate().unwrap();
        c.apply_arg("repl", "diloco:8").unwrap();
        c.apply_arg("staleness", "auto").unwrap();
        assert!(c.staleness_auto);
        assert_eq!(c.staleness(), 0); // the table is resolved later
        // an explicit global S turns auto back off
        c.apply_arg("staleness", "2").unwrap();
        assert!(!c.staleness_auto);
        // node overrides parse sparsely, S = 0 allowed (pin to sync)
        c.apply_arg("node-staleness", "1:3,0:0").unwrap();
        assert_eq!(c.node_staleness, vec![Some(0), Some(3)]);
        assert!(c.apply_arg("node-staleness", "1:x").is_err());
        assert!(c.apply_arg("node-staleness", "nope").is_err());

        // resolution: global fill, then overrides; period-bounded
        let table = c.resolve_node_staleness(1e9, 1 << 20).unwrap();
        assert_eq!(table, vec![0, 3]);
        c.apply_arg("node-staleness", "1:8").unwrap(); // == period
        assert!(c.resolve_node_staleness(1e9, 1 << 20).is_err());
        c.apply_arg("node-staleness", "3:1").unwrap(); // node out of range
        assert!(c.resolve_node_staleness(1e9, 1 << 20).is_err());

        // auto derives per-node values within [1, period)
        c.apply_arg("node-staleness", "").unwrap();
        c.apply_arg("staleness", "auto").unwrap();
        c.apply_arg("straggler", "1:4.0").unwrap();
        let table = c.resolve_node_staleness(1e9, 1 << 20).unwrap();
        assert_eq!(table.len(), 2);
        assert!(table.iter().all(|&s| (1..8).contains(&s)));
        // the compute straggler needs no more slack than the fast node
        assert!(table[1] <= table[0]);
    }

    #[test]
    fn late_policy_knob() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.late_policy(), LatePolicy::Wait);
        c.apply_arg("late-policy", "wait").unwrap(); // harmless anywhere
        c.validate().unwrap();
        // a real policy on a non-deferring scheme is a validate error
        c.apply_arg("late-policy", "drop").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(
            err.contains("--late-policy only applies to the diloco replicator"),
            "{err}"
        );
        // …and folds into a diloco spec whichever side of it arrives
        c.apply_arg("repl", "diloco:8").unwrap();
        assert_eq!(c.late_policy(), LatePolicy::Drop);
        c.validate().unwrap();
        c.apply_arg("late-policy", "partial").unwrap();
        assert_eq!(c.late_policy(), LatePolicy::Partial);
        assert!(c.apply_arg("late-policy", "sometimes").is_err());
        // the spec form carries both knobs at once
        c.apply_arg("repl", "diloco:8:async=2,drop").unwrap();
        assert_eq!(c.staleness(), 2);
        assert_eq!(c.late_policy(), LatePolicy::Drop);
        assert_eq!(
            c.to_json().get("late_policy").unwrap().as_str(),
            Some("drop")
        );
        // non-diloco schemes never defer, so they report wait
        c.apply_arg("repl", "full").unwrap();
        assert_eq!(c.late_policy(), LatePolicy::Wait);
        c.validate().unwrap();
    }

    #[test]
    fn elastic_membership_knobs() {
        let mut c = ExperimentConfig::default();
        assert!(c.membership.is_empty());
        assert_eq!(c.quorum, 0);
        assert!(c.checkpoint_dir.is_none());
        c.validate().unwrap(); // defaults always pass

        // --churn and --crash compose into one timeline
        c.apply_arg("churn", "leave:1@4,join:1@8").unwrap();
        c.apply_arg("crash", "1@20:30").unwrap();
        assert_eq!(c.membership.render(), "leave:1@4,join:1@8,crash:1@20,join:1@30");
        c.validate().unwrap();
        // semantic errors surface at validate time, with the mesh known
        c.apply_arg("steps", "25").unwrap();
        assert!(c.validate().is_err()); // join:1@30 past the end
        c.apply_arg("steps", "100").unwrap();
        c.apply_arg("nodes", "1").unwrap();
        assert!(c.validate().is_err()); // node 1 out of range
        c.apply_arg("nodes", "2").unwrap();

        // syntax errors surface at parse time
        assert!(c.apply_arg("churn", "evaporate:1@4").is_err());
        assert!(c.apply_arg("crash", "1@6:3").is_err());

        // quorum: >= 1, bounded by the group size at validate time
        assert!(c.apply_arg("quorum", "0").is_err());
        assert!(c.apply_arg("quorum", "x").is_err());
        c.apply_arg("quorum", "2").unwrap();
        c.validate().unwrap();
        c.apply_arg("quorum", "3").unwrap();
        assert!(c.validate().is_err()); // 3 > 2 nodes
        c.apply_arg("quorum", "1").unwrap();

        // checkpoint-dir: path in, empty clears (trace-out idiom)
        c.apply_arg("checkpoint-dir", "/tmp/ckpt").unwrap();
        assert_eq!(
            c.checkpoint_dir.as_deref(),
            Some(std::path::Path::new("/tmp/ckpt"))
        );
        c.apply_arg("checkpoint-dir", "").unwrap();
        assert!(c.checkpoint_dir.is_none());

        // all four knobs serialize
        let j = c.to_json();
        assert!(j.get("membership").unwrap().as_str().unwrap().contains("crash:1@20"));
        assert_eq!(j.get("quorum").unwrap().as_usize(), Some(1));
        assert!(j.get("checkpoint_dir").is_some());
    }

    #[test]
    fn link_fault_knobs() {
        let mut c = ExperimentConfig::default();
        assert!(c.link_fault.is_empty());
        assert_eq!(c.max_retries, 3);
        c.validate().unwrap(); // defaults always pass

        // repeated flags compose into one timeline
        c.apply_arg("link-fault", "drop:0-1@p0.05").unwrap();
        c.apply_arg("link-fault", "flap:1-0@4..8,degrade:0-*@0.5x").unwrap();
        assert_eq!(
            c.link_fault.render(),
            "drop:0-1@p0.05,flap:1-0@4..8,degrade:0-*@0.5x"
        );
        c.validate().unwrap();
        // semantic errors surface at validate time, with the mesh known
        c.apply_arg("link-fault", "corrupt:5-0@p0.5").unwrap();
        assert!(c.validate().is_err()); // node 5 out of range
        // syntax errors surface at parse time
        assert!(c.apply_arg("link-fault", "melt:0-1@p0.5").is_err());
        assert!(c.apply_arg("link-fault", "drop:0-1@0.5").is_err()); // missing 'p'

        // retry knobs parse and reject nonsense
        c.apply_arg("max-retries", "5").unwrap();
        assert_eq!(c.max_retries, 5);
        assert!(c.apply_arg("max-retries", "-1").is_err());
        c.apply_arg("retry-timeout", "0.25").unwrap();
        assert_eq!(c.retry_timeout, 0.25);
        assert!(c.apply_arg("retry-timeout", "-0.1").is_err());
        assert!(c.apply_arg("retry-timeout", "nan").is_err());
        c.apply_arg("retry-backoff", "0.02").unwrap();
        assert_eq!(c.retry_backoff, 0.02);
        assert!(c.apply_arg("retry-backoff", "inf").is_err());

        // all four knobs serialize
        let j = c.to_json();
        assert!(j.get("link_fault").unwrap().as_str().unwrap().contains("flap:1-0@4..8"));
        assert_eq!(j.get("max_retries").unwrap().as_usize(), Some(5));
        assert!(j.get("retry_timeout").is_some());
        assert!(j.get("retry_backoff").is_some());
    }

    #[test]
    fn topology_knob() {
        let mut c = ExperimentConfig::default();
        assert!(c.topology.is_full());
        c.validate().unwrap(); // defaults always pass

        c.apply_arg("topology", "random-pair").unwrap();
        assert_eq!(c.topology, SyncTopology::RandomPair);
        c.validate().unwrap(); // any group size is fine
        c.apply_arg("topology", "hier:1").unwrap();
        assert_eq!(c.topology, SyncTopology::Hier { fanout: 1 });
        c.validate().unwrap(); // 1 < 2 nodes

        // shape errors surface at validate time, with the mesh known,
        // and carry an actionable message — no panic, no silent clamp
        c.apply_arg("topology", "ring").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains(">= 3") && err.contains("got 2"), "unactionable: {err}");
        c.apply_arg("nodes", "3").unwrap();
        c.validate().unwrap();
        c.apply_arg("topology", "hier:3").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fanout < ") && err.contains('3'), "unactionable: {err}");
        c.apply_arg("nodes", "4").unwrap();
        c.validate().unwrap();

        // syntax errors surface at parse time
        assert!(c.apply_arg("topology", "star").is_err());
        assert!(c.apply_arg("topology", "hier:0").is_err());
        assert!(c.apply_arg("topology", "hier:two").is_err());

        // the knob serializes with its CLI spelling
        let j = c.to_json();
        assert_eq!(j.get("topology").unwrap().as_str(), Some("hier:3"));
    }

    #[test]
    fn to_json_roundtrips_keys() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        assert_eq!(j.get("model").unwrap().as_str(), Some("lm-tiny"));
        assert_eq!(j.get("nodes").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("repl").unwrap().as_str(), Some("demo-1/8"));
        assert!(j.get("overlap").is_some());
        assert!(j.get("stragglers").is_some());
    }

    #[test]
    fn overlap_and_scenario_args() {
        let mut c = ExperimentConfig::default();
        assert!(c.overlap);
        assert_eq!(c.threads, 1);
        assert!(c.cluster.is_uniform());
        c.apply_arg("overlap", "false").unwrap();
        c.apply_arg("threads", "4").unwrap();
        c.apply_arg("straggler", "1:2.0").unwrap();
        c.apply_arg("node-mbps", "0:100").unwrap();
        assert!(!c.overlap);
        assert_eq!(c.threads, 4);
        // bucket knob: defaults off, parses MiB, rejects negatives
        assert_eq!(c.bucket_mb, 0.0);
        assert_eq!(c.bucket_bytes(), 0);
        c.apply_arg("bucket-mb", "0.5").unwrap();
        assert_eq!(c.bucket_bytes(), 1 << 19);
        assert!(c.apply_arg("bucket-mb", "-1").is_err());
        assert!(c.apply_arg("bucket-mb", "nan").is_err());
        c.apply_arg("bucket-mb", "0").unwrap();
        // trace-out: defaults off, parses a path, empty clears
        assert!(c.trace_out.is_none());
        c.apply_arg("trace-out", "/tmp/sched.json").unwrap();
        assert_eq!(
            c.trace_out.as_deref(),
            Some(std::path::Path::new("/tmp/sched.json"))
        );
        c.apply_arg("trace-out", "").unwrap();
        assert!(c.trace_out.is_none());
        assert_eq!(c.cluster.slowdown_of(1), 2.0);
        assert!((c.cluster.node_bw(&c.net, 0) - 12.5e6).abs() < 1.0);
        assert!(c.apply_arg("straggler", "1:-2").is_err());
        assert!(c.apply_arg("overlap", "maybe").is_err());
    }
}
