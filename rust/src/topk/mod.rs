//! Per-chunk top-k selection by magnitude (the DeMo "TopK" hyperparameter,
//! paper Fig 8).
//!
//! Selection is `select_nth_unstable_by` partial selection (expected O(n)
//! per chunk, no full sort) over the **pinned, deterministic total order**
//!
//! > larger `|value|` first; equal magnitudes prefer the **lowest index**.
//!
//! The index tie-break makes the comparator a total order, so partial
//! selection returns the same component set on every platform and at
//! every optimization level — payloads can never silently reorder across
//! ranks (tested below; matches `jax.lax.top_k` / the Python oracle).
//!
//! The `_into` variants reuse caller-owned buffers so the extraction hot
//! path performs zero heap allocations in steady state.

use std::cmp::Ordering;

/// The pinned rank order: descending `|x|`, ties broken toward the lower
/// index. A total order for finite inputs (NaNs degrade to index order).
#[inline]
fn rank(xs: &[f32], a: u32, b: u32) -> Ordering {
    let (xa, xb) = (xs[a as usize].abs(), xs[b as usize].abs());
    match xb.partial_cmp(&xa) {
        Some(Ordering::Less) => Ordering::Less,
        Some(Ordering::Greater) => Ordering::Greater,
        _ => a.cmp(&b),
    }
}

/// Indices of the k largest-|.| entries of `xs`, ascending index order.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<u32> {
    let mut perm = Vec::new();
    let mut out = Vec::new();
    topk_indices_into(xs, k, &mut perm, &mut out);
    out
}

/// [`topk_indices`] into reusable buffers: `perm` is the selection
/// workspace, `out` receives the ascending result. No allocation once
/// both have warmed to capacity.
pub fn topk_indices_into(xs: &[f32], k: usize, perm: &mut Vec<u32>, out: &mut Vec<u32>) {
    let n = xs.len();
    out.clear();
    if k == 0 {
        return;
    }
    if k >= n {
        out.extend(0..n as u32);
        return;
    }
    perm.clear();
    perm.extend(0..n as u32);
    // Partial selection: everything in perm[..k] ranks before perm[k..].
    perm.select_nth_unstable_by(k - 1, |&a, &b| rank(xs, a, b));
    let top = &mut perm[..k];
    top.sort_unstable();
    out.extend_from_slice(top);
}

/// Per-chunk top-k over a flat coefficient buffer.
/// Returns the selected global indices, ascending (k per chunk).
pub fn topk_per_chunk(coeffs: &[f32], chunk: usize, k: usize) -> Vec<u32> {
    let mut perm = Vec::new();
    let mut out = Vec::new();
    topk_per_chunk_into(coeffs, chunk, k, &mut perm, &mut out);
    out
}

/// [`topk_per_chunk`] into reusable buffers (the extraction hot path —
/// zero allocations in steady state).
pub fn topk_per_chunk_into(
    coeffs: &[f32],
    chunk: usize,
    k: usize,
    perm: &mut Vec<u32>,
    out: &mut Vec<u32>,
) {
    assert_eq!(coeffs.len() % chunk, 0);
    out.clear();
    let kk = k.min(chunk);
    if kk == 0 {
        return;
    }
    for (ci, ch) in coeffs.chunks_exact(chunk).enumerate() {
        let base = (ci * chunk) as u32;
        perm.clear();
        perm.extend(0..chunk as u32);
        if kk < chunk {
            perm.select_nth_unstable_by(kk - 1, |&a, &b| rank(ch, a, b));
        }
        let top = &mut perm[..kk];
        top.sort_unstable();
        for &i in top.iter() {
            out.push(base + i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, proptest};

    fn brute_topk(xs: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            xs[b as usize]
                .abs()
                .partial_cmp(&xs[a as usize].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut out = idx[..k.min(xs.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn simple_cases() {
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 1), vec![1]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 2), vec![1, 2]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 3), vec![0, 1, 2]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 9), vec![0, 1, 2]);
        assert_eq!(topk_indices(&[1.0, 2.0], 0), Vec::<u32>::new());
    }

    #[test]
    fn ties_prefer_lower_index() {
        assert_eq!(topk_indices(&[2.0, -2.0, 2.0, 1.0], 2), vec![0, 1]);
        assert_eq!(topk_indices(&[0.0, 0.0, 0.0], 2), vec![0, 1]);
    }

    #[test]
    fn tie_breaking_pinned_lowest_index() {
        // Satellite: the documented determinism contract. Equal-magnitude
        // coefficients (regardless of sign) select the lowest indices, so
        // partial selection cannot reorder payloads across platforms.
        let all_ties = [1.0f32, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        for k in 1..=all_ties.len() {
            assert_eq!(
                topk_indices(&all_ties, k),
                (0..k as u32).collect::<Vec<_>>(),
                "k={k}"
            );
        }
        // mixed magnitudes: the tie at |2.0| resolves to index 0, the
        // winner block {|3.0|} comes regardless of sign
        assert_eq!(topk_indices(&[2.0, -3.0, 3.0, -2.0], 2), vec![1, 2]);
        assert_eq!(topk_indices(&[2.0, -3.0, 3.0, -2.0], 3), vec![0, 1, 2]);
        // per-chunk: both chunks are all-ties; each selects its lowest k
        let xs = [5.0f32, -5.0, 5.0, -5.0, 7.0, -7.0, 7.0, -7.0];
        assert_eq!(topk_per_chunk(&xs, 4, 2), vec![0, 1, 4, 5]);
    }

    #[test]
    fn matches_brute_force_property() {
        proptest(128, |g| {
            let n = g.usize(1, 300);
            let k = g.usize(0, n);
            // Coarse values force plenty of |.| ties.
            let xs: Vec<f32> = (0..n).map(|_| (g.usize(0, 8) as f32) - 4.0).collect();
            let got = topk_indices(&xs, k);
            let want = brute_topk(&xs, k);
            prop_assert(got == want, format!("n={n} k={k}: {got:?} vs {want:?}"));
        });
    }

    #[test]
    fn into_variant_reuses_buffers_and_matches() {
        let mut perm = Vec::new();
        let mut out = Vec::new();
        proptest(32, |g| {
            let chunk = g.pow2(2, 7);
            let n_chunks = g.usize(1, 8);
            let k = g.usize(1, chunk);
            let xs = g.vec_normal(chunk * n_chunks, 1.0);
            topk_per_chunk_into(&xs, chunk, k, &mut perm, &mut out);
            prop_assert(
                out == topk_per_chunk(&xs, chunk, k),
                "reused buffers diverged from fresh",
            );
        });
    }

    #[test]
    fn per_chunk_selects_in_every_chunk() {
        let mut xs = vec![0.0f32; 64];
        xs[3] = 9.0; // chunk 0
        xs[17] = -8.0; // chunk 1
        xs[40] = 7.0; // chunk 2
        xs[63] = 6.5; // chunk 3
        let got = topk_per_chunk(&xs, 16, 1);
        assert_eq!(got, vec![3, 17, 40, 63]);
    }

    #[test]
    fn per_chunk_counts() {
        proptest(32, |g| {
            let chunk = g.pow2(2, 7);
            let n_chunks = g.usize(1, 12);
            let k = g.usize(1, chunk);
            let xs = g.vec_normal(chunk * n_chunks, 1.0);
            let got = topk_per_chunk(&xs, chunk, k);
            prop_assert(got.len() == n_chunks * k, format!("{} != {}", got.len(), n_chunks * k));
            // indices ascend and stay within their chunk
            for w in got.windows(2) {
                prop_assert(w[0] < w[1], "not ascending");
            }
        });
    }
}
