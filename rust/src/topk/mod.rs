//! Per-chunk top-k selection by magnitude (the DeMo "TopK" hyperparameter,
//! paper Fig 8).
//!
//! Selection uses an in-place quickselect over (|value| desc, index asc) —
//! the index tiebreak matches `jax.lax.top_k` / the Python oracle so both
//! sides of the stack keep identical components.

/// Indices of the k largest-|.| entries of `xs`, ascending index order.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<u32> {
    let n = xs.len();
    if k >= n {
        return (0..n as u32).collect();
    }
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    select_top(&mut idx, xs, k);
    let mut out = idx[..k].to_vec();
    out.sort_unstable();
    out
}

/// Rank key: larger |x| first; ties prefer the smaller index.
#[inline]
fn better(xs: &[f32], a: u32, b: u32) -> bool {
    let (xa, xb) = (xs[a as usize].abs(), xs[b as usize].abs());
    xa > xb || (xa == xb && a < b)
}

/// Partially order `idx` so its first k entries are the top-k (quickselect,
/// median-of-three pivot, expected O(n)).
fn select_top(idx: &mut [u32], xs: &[f32], k: usize) {
    let (mut lo, mut hi) = (0usize, idx.len());
    let mut want = k;
    while hi - lo > 1 {
        // median-of-three pivot on (lo, mid, hi-1)
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (idx[lo], idx[mid], idx[hi - 1]);
        let pivot = if better(xs, a, b) == better(xs, a, c) {
            // a is either best or worst of the three -> median is b or c
            if better(xs, b, c) == better(xs, b, a) { c } else { b }
        } else {
            a
        };
        // Partition: entries better than pivot to the left.
        let mut i = lo;
        let mut j = hi;
        let mut p = lo;
        // three-way partition around pivot value
        while p < j {
            if better(xs, idx[p], pivot) {
                idx.swap(i, p);
                i += 1;
                p += 1;
            } else if better(xs, pivot, idx[p]) {
                j -= 1;
                idx.swap(p, j);
            } else {
                p += 1;
            }
        }
        // [lo, i) better; [i, j) equal-to-pivot (only the pivot itself,
        // since keys are unique by index tiebreak); [j, hi) worse.
        let n_better = i - lo;
        let n_eq = j - i;
        if want < n_better {
            hi = i;
        } else if want < n_better + n_eq {
            return; // boundary falls inside the pivot block — done
        } else {
            want -= n_better + n_eq;
            lo = j;
        }
        if want == 0 {
            return;
        }
    }
}

/// Per-chunk top-k over a flat coefficient buffer.
/// Returns (chunk_index, within-chunk indices) pairs flattened as global
/// indices, ascending.
pub fn topk_per_chunk(coeffs: &[f32], chunk: usize, k: usize) -> Vec<u32> {
    assert_eq!(coeffs.len() % chunk, 0);
    let mut out = Vec::with_capacity(coeffs.len() / chunk * k.min(chunk));
    for (ci, ch) in coeffs.chunks_exact(chunk).enumerate() {
        let base = (ci * chunk) as u32;
        for i in topk_indices(ch, k) {
            out.push(base + i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, proptest};

    fn brute_topk(xs: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            xs[b as usize]
                .abs()
                .partial_cmp(&xs[a as usize].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut out = idx[..k.min(xs.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn simple_cases() {
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 1), vec![1]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 2), vec![1, 2]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 3), vec![0, 1, 2]);
        assert_eq!(topk_indices(&[1.0, -5.0, 3.0], 9), vec![0, 1, 2]);
        assert_eq!(topk_indices(&[1.0, 2.0], 0), Vec::<u32>::new());
    }

    #[test]
    fn ties_prefer_lower_index() {
        assert_eq!(topk_indices(&[2.0, -2.0, 2.0, 1.0], 2), vec![0, 1]);
        assert_eq!(topk_indices(&[0.0, 0.0, 0.0], 2), vec![0, 1]);
    }

    #[test]
    fn matches_brute_force_property() {
        proptest(128, |g| {
            let n = g.usize(1, 300);
            let k = g.usize(0, n);
            // Coarse values force plenty of |.| ties.
            let xs: Vec<f32> = (0..n).map(|_| (g.usize(0, 8) as f32) - 4.0).collect();
            let got = topk_indices(&xs, k);
            let want = brute_topk(&xs, k);
            prop_assert(got == want, format!("n={n} k={k}: {got:?} vs {want:?}"));
        });
    }

    #[test]
    fn per_chunk_selects_in_every_chunk() {
        let mut xs = vec![0.0f32; 64];
        xs[3] = 9.0; // chunk 0
        xs[17] = -8.0; // chunk 1
        xs[40] = 7.0; // chunk 2
        xs[63] = 6.5; // chunk 3
        let got = topk_per_chunk(&xs, 16, 1);
        assert_eq!(got, vec![3, 17, 40, 63]);
    }

    #[test]
    fn per_chunk_counts() {
        proptest(32, |g| {
            let chunk = g.pow2(2, 7);
            let n_chunks = g.usize(1, 12);
            let k = g.usize(1, chunk);
            let xs = g.vec_normal(chunk * n_chunks, 1.0);
            let got = topk_per_chunk(&xs, chunk, k);
            prop_assert(got.len() == n_chunks * k, format!("{} != {}", got.len(), n_chunks * k));
            // indices ascend and stay within their chunk
            for w in got.windows(2) {
                prop_assert(w[0] < w[1], "not ascending");
            }
        });
    }
}
