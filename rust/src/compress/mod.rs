//! Payload compression + wire-size accounting for replicated updates.
//!
//! This module is where the paper's bandwidth arithmetic lives:
//! * **sign/ternary packing** (Fig 9): transmitted coefficients become
//!   {-1, 0, +1}, packed 2 bits each (the paper's "ternary system").
//! * **transfer dtype** (Figs 12–14): f32 / bf16 / f16 value payloads.
//! * **index transfer**: the DeMo replicator must ship the selected
//!   indices alongside values; Random/Striding regenerate indices from the
//!   shared seed and ship *values only* — "double the amount of data, on
//!   the same bandwidth" (paper §Replication Schemes).
//!
//! Every payload knows its exact `wire_bytes()`, which is what the
//! simulated network charges (`net::Link::transfer`). Tests pin the
//! paper's claimed ratios (e.g. sign ≈ 16× smaller than f32 values).
//!
//! [`Scratch`] is the per-worker arena threaded through the whole
//! extract→select→encode→decode pipeline: named workspace buffers for
//! the DCT/top-k stages plus small free-lists that payload vectors are
//! drawn from and recycled into, so the steady-state hot path performs
//! **zero heap allocations** (asserted by `benches/compress.rs` with a
//! counting allocator).

use crate::tensor::Dtype;

/// A sparse update payload as it would appear on the wire.
#[derive(Clone, Debug)]
pub struct Payload {
    /// Global indices of the selected components (empty when the receiver
    /// regenerates them — Random/Striding).
    pub indices: Option<Vec<u32>>,
    /// Component values, quantized to `dtype` (stored f32-side for math,
    /// wire size accounted separately). For `sign=true` values are ±1/0.
    pub values: Vec<f32>,
    pub dtype: Dtype,
    pub sign: bool,
    /// Pack signed (ternary) values at 2 bits each instead of shipping
    /// them in `dtype`. The paper transmits signs as ordinary floats and
    /// flags ternary packing as future work ("the ternary system opens up
    /// for the possibility to compress the data even more") — so this is
    /// an opt-in extension (`ReplSpec` suffix `:packed`), off by default.
    pub packed: bool,
    /// Selection hint for heterogeneous-rate decode (4 B on the wire
    /// when present): the one scalar a receiver cannot reconstruct when
    /// peers compress at *different* rates — Striding ships its stride
    /// (Random's k is implied by `values.len()`, DeMo ships indices
    /// anyway). Only attached while the adaptive rate controller is
    /// armed; `None` keeps the fixed-rate wire format bit-identical.
    pub sel: Option<u32>,
}

impl Payload {
    /// Build a payload from selected values, applying sign + dtype
    /// quantization exactly as the wire would.
    pub fn new(indices: Option<Vec<u32>>, mut values: Vec<f32>, dtype: Dtype, sign: bool) -> Payload {
        if let Some(ix) = &indices {
            assert_eq!(ix.len(), values.len());
        }
        if sign {
            for v in values.iter_mut() {
                *v = if *v > 0.0 {
                    1.0
                } else if *v < 0.0 {
                    -1.0
                } else {
                    0.0
                };
            }
        } else {
            for v in values.iter_mut() {
                *v = dtype.quantize(*v);
            }
        }
        Payload {
            indices,
            values,
            dtype,
            sign,
            packed: false,
            sel: None,
        }
    }

    /// Enable the 2-bit ternary wire format (extension; see `packed`).
    pub fn with_packing(mut self) -> Payload {
        self.packed = true;
        self
    }

    /// Attach a selection hint (see `sel`; adds 4 B to the wire size).
    pub fn with_sel(mut self, sel: u32) -> Payload {
        self.sel = Some(sel);
        self
    }

    /// Exact wire size in bytes: selection hint + index block + value
    /// block.
    ///
    /// * selection hint: 4 B (u32), only under adaptive rate control.
    /// * indices: 4 B each (u32), omitted when regenerable.
    /// * values: `dtype.bytes()` each (sign values ride as ±1.0 in
    ///   `dtype`, exactly like the paper's implementation) — unless the
    ///   `packed` ternary extension is on: then 2 bits each.
    pub fn wire_bytes(&self) -> u64 {
        let sel = if self.sel.is_some() { 4 } else { 0 };
        let idx = self.indices.as_ref().map_or(0, |ix| 4 * ix.len() as u64);
        let vals = if self.sign && self.packed {
            (self.values.len() as u64 + 3) / 4
        } else {
            (self.dtype.bytes() * self.values.len()) as u64
        };
        sel + idx + vals
    }

    /// Serialize the value block to bytes (what actually crosses the link
    /// in the simulator — kept real so corruption tests can flip bits).
    pub fn encode_values(&self) -> Vec<u8> {
        if self.sign && self.packed {
            pack_ternary(&self.values)
        } else {
            match self.dtype {
                Dtype::F32 => self
                    .values
                    .iter()
                    .flat_map(|v| v.to_le_bytes())
                    .collect(),
                Dtype::Bf16 => self
                    .values
                    .iter()
                    .flat_map(|&v| crate::tensor::f32_to_bf16(v).to_le_bytes())
                    .collect(),
                Dtype::F16 => self
                    .values
                    .iter()
                    .flat_map(|&v| crate::tensor::f32_to_f16(v).to_le_bytes())
                    .collect(),
            }
        }
    }

    /// CRC-32 over the payload's wire image (index block, then value
    /// block) — the cheap end-to-end integrity check the self-healing
    /// transfer layer verifies at decode. Any single-bit corruption of
    /// the wire bytes is guaranteed detected (CRC property), so a
    /// corrupted transfer is retried instead of silently averaged into
    /// the model.
    pub fn checksum(&self) -> u32 {
        crate::util::crc32(&self.wire_image())
    }

    /// The exact byte sequence this payload puts on the wire (selection
    /// hint, then index block, then value block) — what
    /// [`Self::checksum`] covers, and what the fault layer flips bits of
    /// to model corruption.
    pub fn wire_image(&self) -> Vec<u8> {
        let mut wire = Vec::with_capacity(self.wire_bytes() as usize);
        if let Some(sel) = self.sel {
            wire.extend_from_slice(&sel.to_le_bytes());
        }
        if let Some(ix) = &self.indices {
            for &i in ix {
                wire.extend_from_slice(&i.to_le_bytes());
            }
        }
        wire.extend_from_slice(&self.encode_values());
        wire
    }

    /// Decode a value block produced by `encode_values`.
    pub fn decode_values(bytes: &[u8], n: usize, dtype: Dtype, sign_packed: bool) -> Vec<f32> {
        if sign_packed {
            unpack_ternary(bytes, n)
        } else {
            match dtype {
                Dtype::F32 => bytes
                    .chunks_exact(4)
                    .take(n)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
                Dtype::Bf16 => bytes
                    .chunks_exact(2)
                    .take(n)
                    .map(|b| crate::tensor::bf16_to_f32(u16::from_le_bytes([b[0], b[1]])))
                    .collect(),
                Dtype::F16 => bytes
                    .chunks_exact(2)
                    .take(n)
                    .map(|b| crate::tensor::f16_to_f32(u16::from_le_bytes([b[0], b[1]])))
                    .collect(),
            }
        }
    }
}

/// Pack ternary values {-1, 0, +1} at 2 bits each: 00=0, 01=+1, 10=-1.
pub fn pack_ternary(values: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; (values.len() + 3) / 4];
    for (i, &v) in values.iter().enumerate() {
        let code: u8 = if v > 0.0 {
            0b01
        } else if v < 0.0 {
            0b10
        } else {
            0b00
        };
        out[i / 4] |= code << ((i % 4) * 2);
    }
    out
}

/// Inverse of `pack_ternary`.
pub fn unpack_ternary(bytes: &[u8], n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let code = (bytes[i / 4] >> ((i % 4) * 2)) & 0b11;
        out.push(match code {
            0b01 => 1.0,
            0b10 => -1.0,
            _ => 0.0,
        });
    }
    out
}

/// Bandwidth bookkeeping for one replication round (per rank), feeding the
/// Fig 12/13 bandwidth-usage plots and the network simulator.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireStats {
    pub payload_bytes: u64,
    pub index_bytes: u64,
    pub value_count: u64,
}

impl WireStats {
    pub fn of(p: &Payload) -> WireStats {
        WireStats {
            payload_bytes: p.wire_bytes(),
            index_bytes: p.indices.as_ref().map_or(0, |ix| 4 * ix.len() as u64),
            value_count: p.values.len() as u64,
        }
    }
}

/// Pool size cap — enough for every vector the pipeline keeps in flight
/// per step without letting a pathological caller hoard memory.
const POOL_CAP: usize = 16;

/// Reusable per-worker workspace for the compression pipeline.
///
/// One instance per rank (the trainer keeps one in each `RankState`),
/// threaded through [`crate::replicate::Replicator::extract`]/`decode`.
/// Two kinds of storage live here:
///
/// * **named stage buffers** (`coeffs`, `removed`, `sel`, `perm`, `idx`,
///   `dct`) that a single extract/decode call owns for its duration;
/// * **free-lists** (`take_f32`/`take_u32` + `put_*`) that outliving
///   vectors — payload values/indices, the locally-decoded `q` — are
///   drawn from. Callers return consumed payloads via
///   [`Scratch::recycle_payload`] so the next step reuses the capacity.
///
/// After one warm-up step every buffer has reached steady-state capacity
/// and extraction allocates nothing.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Chunked DCT-II coefficients of the buffer being extracted.
    pub coeffs: Vec<f32>,
    /// Dense reconstruction of the kept mass (residual subtraction).
    pub removed: Vec<f32>,
    /// Selected global indices of the current extraction.
    pub sel: Vec<u32>,
    /// Per-chunk permutation workspace for partial top-k selection.
    pub perm: Vec<u32>,
    /// Index-set workspace for seed-regenerated schemes (Random).
    pub idx: Vec<usize>,
    /// Blocked-transform workspace for the DCT (serial paths).
    pub dct: crate::dct::DctScratch,
    /// Per-worker DCT arenas for pool-dispatched block batches (one per
    /// pool execution slot; see [`Scratch::ensure_dct_workers`]).
    pub dct_workers: Vec<crate::dct::DctScratch>,
    /// The worker pool pooled pipelines dispatch onto (inline default).
    pub pool: crate::parallel::PoolHandle,
    pool_f32: Vec<Vec<f32>>,
    pool_u32: Vec<Vec<u32>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A scratch arena whose pipelines dispatch onto `pool`.
    pub fn with_pool(pool: crate::parallel::PoolHandle) -> Scratch {
        Scratch {
            pool,
            ..Scratch::default()
        }
    }

    /// Make sure one [`crate::dct::DctScratch`] exists per pool slot
    /// (grow-only; a one-time allocation per trainer, not per step).
    pub fn ensure_dct_workers(&mut self) {
        let w = self.pool.get().width();
        if self.dct_workers.len() < w {
            self.dct_workers.resize_with(w, Default::default);
        }
    }

    /// An empty f32 vector from the pool (capacity retained across reuse).
    pub fn take_f32(&mut self) -> Vec<f32> {
        let mut v = self.pool_f32.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// A zero-filled f32 vector of `len` from the pool.
    pub fn take_f32_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32();
        v.resize(len, 0.0);
        v
    }

    /// An empty u32 vector from the pool.
    pub fn take_u32(&mut self) -> Vec<u32> {
        let mut v = self.pool_u32.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return an f32 vector to the pool (dropped if the pool is full).
    pub fn put_f32(&mut self, v: Vec<f32>) {
        if self.pool_f32.len() < POOL_CAP {
            self.pool_f32.push(v);
        }
    }

    /// Return a u32 vector to the pool.
    pub fn put_u32(&mut self, v: Vec<u32>) {
        if self.pool_u32.len() < POOL_CAP {
            self.pool_u32.push(v);
        }
    }

    /// Return a consumed payload's buffers to the pools.
    pub fn recycle_payload(&mut self, p: Payload) {
        if let Some(ix) = p.indices {
            self.put_u32(ix);
        }
        self.put_f32(p.values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, proptest};

    #[test]
    fn ternary_pack_roundtrip() {
        let vals = vec![1.0, -1.0, 0.0, 1.0, -1.0, 0.0, 0.0, 1.0, -1.0];
        let packed = pack_ternary(&vals);
        assert_eq!(packed.len(), 3); // ceil(9/4)
        assert_eq!(unpack_ternary(&packed, 9), vals);
    }

    #[test]
    fn payload_checksum_detects_wire_corruption() {
        let p = Payload::new(Some(vec![3, 9, 11]), vec![0.5, -2.0, 1.25], Dtype::F32, false);
        // stable across calls, sensitive to every field on the wire
        assert_eq!(p.checksum(), p.checksum());
        let mut q = p.clone();
        q.values[1] = -2.5;
        assert_ne!(p.checksum(), q.checksum());
        let mut q = p.clone();
        q.indices.as_mut().unwrap()[0] = 4;
        assert_ne!(p.checksum(), q.checksum());
        // a single flipped bit anywhere in the encoded value block is
        // detected (what the corrupt fault injects)
        let wire = p.encode_values();
        let base = crate::util::crc32(&wire);
        for byte in 0..wire.len() {
            let mut flipped = wire.clone();
            flipped[byte] ^= 0x10;
            assert_ne!(crate::util::crc32(&flipped), base, "flip at byte {byte}");
        }
        // packed ternary payloads checksum their packed image
        let t = Payload::new(None, vec![1.0, -1.0, 0.0, 1.0], Dtype::F32, true).with_packing();
        assert_eq!(t.checksum(), crate::util::crc32(&t.encode_values()));
    }

    #[test]
    fn ternary_roundtrip_property() {
        proptest(64, |g| {
            let n = g.usize(0, 500);
            let vals: Vec<f32> = (0..n)
                .map(|_| *g.choose(&[-1.0f32, 0.0, 1.0]))
                .collect();
            let back = unpack_ternary(&pack_ternary(&vals), n);
            prop_assert(back == vals, "ternary roundtrip");
        });
    }

    #[test]
    fn sign_values_ride_in_dtype_by_default() {
        // Paper behaviour: signs are ordinary ±1.0 floats on the wire.
        let vals = vec![0.5f32; 4096];
        let signed = Payload::new(None, vals.clone(), Dtype::F32, true);
        let full = Payload::new(None, vals, Dtype::F32, false);
        assert_eq!(signed.wire_bytes(), full.wire_bytes());
    }

    #[test]
    fn packed_ternary_extension_is_16x_smaller_than_f32() {
        // The paper's future-work ternary system: 2 bits vs 32 = 16x.
        let vals = vec![0.5f32; 4096];
        let packed = Payload::new(None, vals.clone(), Dtype::F32, true).with_packing();
        let full = Payload::new(None, vals, Dtype::F32, false);
        assert_eq!(full.wire_bytes(), 16384);
        assert_eq!(packed.wire_bytes(), 1024);
        assert_eq!(full.wire_bytes() / packed.wire_bytes(), 16);
    }

    #[test]
    fn index_block_doubles_demo_cost_at_f32() {
        // DeMo ships (u32 index + f32 value) = 8 B/component; Random ships
        // 4 B/component — exactly the paper's "double the amount of data,
        // on the same bandwidth".
        let ix: Vec<u32> = (0..1000).collect();
        let vals = vec![1.0f32; 1000];
        let demo = Payload::new(Some(ix), vals.clone(), Dtype::F32, false);
        let random = Payload::new(None, vals, Dtype::F32, false);
        assert_eq!(demo.wire_bytes(), 2 * random.wire_bytes());
    }

    #[test]
    fn sel_hint_costs_four_bytes_and_is_checksummed() {
        // The adaptive-control selection hint is honest: 4 B on the wire,
        // covered by the checksum — and absent by default, so fixed-rate
        // payloads are bit-identical to the pre-controller format.
        let base = Payload::new(None, vec![1.0f32; 64], Dtype::F32, false);
        let hinted = base.clone().with_sel(8);
        assert_eq!(base.sel, None);
        assert_eq!(hinted.wire_bytes(), base.wire_bytes() + 4);
        assert_eq!(hinted.wire_image().len() as u64, hinted.wire_bytes());
        assert_ne!(base.checksum(), hinted.checksum());
        // the hint value itself is covered, not just its presence
        assert_ne!(hinted.checksum(), base.clone().with_sel(9).checksum());
    }

    #[test]
    fn dtype_halves_value_block() {
        let vals = vec![1.5f32; 256];
        let f32p = Payload::new(None, vals.clone(), Dtype::F32, false);
        let bf16p = Payload::new(None, vals.clone(), Dtype::Bf16, false);
        let f16p = Payload::new(None, vals, Dtype::F16, false);
        assert_eq!(f32p.wire_bytes(), 1024);
        assert_eq!(bf16p.wire_bytes(), 512);
        assert_eq!(f16p.wire_bytes(), 512);
    }

    #[test]
    fn payload_quantizes_on_construction() {
        let p = Payload::new(None, vec![1.0 + 1e-4], Dtype::Bf16, false);
        // bf16 has ~3 decimal digits: 1.0001 rounds to 1.0
        assert_eq!(p.values[0], 1.0);
        let s = Payload::new(None, vec![0.3, -0.7, 0.0], Dtype::F32, true);
        assert_eq!(s.values, vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn encode_decode_roundtrip_all_dtypes() {
        proptest(48, |g| {
            let n = g.usize(0, 200);
            let vals = g.vec_normal(n, 2.0);
            let sign = g.bool();
            let packed = sign && g.bool();
            let dtype = *g.choose(&[Dtype::F32, Dtype::Bf16, Dtype::F16]);
            let mut p = Payload::new(None, vals, dtype, sign);
            if packed {
                p = p.with_packing();
            }
            let bytes = p.encode_values();
            let back = Payload::decode_values(&bytes, n, dtype, packed);
            prop_assert(
                back == p.values,
                format!("dtype={dtype:?} sign={sign} packed={packed}"),
            );
        });
    }

    #[test]
    fn wire_stats_split() {
        let p = Payload::new(Some(vec![1, 2, 3]), vec![1.0, 2.0, 3.0], Dtype::F16, false);
        let s = WireStats::of(&p);
        assert_eq!(s.index_bytes, 12);
        assert_eq!(s.payload_bytes, 12 + 6);
        assert_eq!(s.value_count, 3);
    }

    #[test]
    fn scratch_pool_recycles_capacity() {
        let mut s = Scratch::new();
        let mut v = s.take_f32();
        v.extend_from_slice(&[1.0; 100]);
        let cap = v.capacity();
        s.put_f32(v);
        let v2 = s.take_f32();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap, "pooled capacity lost");
        // zeroed take really zeroes reused storage
        let mut v3 = s.take_f32_zeroed(8);
        assert_eq!(v3, vec![0.0; 8]);
        v3[0] = 5.0;
        s.put_f32(v3);
        assert_eq!(s.take_f32_zeroed(8), vec![0.0; 8]);
    }

    #[test]
    fn scratch_recycles_payload_buffers() {
        let mut s = Scratch::new();
        let p = Payload::new(Some(vec![1, 2, 3]), vec![1.0, 2.0, 3.0], Dtype::F32, false);
        s.recycle_payload(p);
        assert!(s.take_u32().capacity() >= 3);
        assert!(s.take_f32().capacity() >= 3);
    }
}
