//! Sync-window exchange topologies — who talks to whom, per window.
//!
//! Every replication scheme historically synchronized over the *full*
//! R-group, so per-window inter-node cost grows O(g) with the mesh.
//! NoLoCo (Kolehmainen et al. 2025) shows gossip averaging — each node
//! exchanging with a tiny, varying peer set — still converges, turning
//! the per-window sync into O(1). [`SyncTopology`] is that knob:
//! `--topology full|ring|random-pair|hier:<F>` selects, per sync window,
//! the peer subset each group member exchanges its payload with.
//!
//! * `full` — today's whole-group exchange. The default, and bit-frozen:
//!   every dispatch path, event schedule, and averaging denominator is
//!   exactly the pre-topology trainer (pinned by proptest).
//! * `ring` — fixed neighbor averaging: member *i* exchanges with
//!   *i ± 1* (mod g) in the window's group order. Two peers per member
//!   regardless of g.
//! * `random-pair` — NoLoCo's actual scheme: a seeded perfect matching
//!   per window pairs members two by two; an odd group leaves exactly
//!   one member self-paired (it averages only itself that window). The
//!   matching is a pure function of (seed, step, shard) — *no* shared
//!   RNG stream is consumed, so arming the topology perturbs nothing
//!   else and reruns are bit-reproducible.
//! * `hier:<F>` — two-level: level 1 is the existing intra-node fabric
//!   reduce (unchanged — it is how each member's payload already
//!   aggregates its node), level 2 replaces the dense inter-node
//!   exchange with a sparse symmetric overlay of `F` fanout links per
//!   member, built from window-rotating circulant offsets so coverage
//!   rotates across windows.
//!
//! Peer sets are always **symmetric** (j ∈ peers(i) ⟺ i ∈ peers(j)) —
//! an exchange is two half-duplex sends, and both ends must agree to
//! admit each other's payload into the mean. They are computed over
//! *positions* in the (churn re-formed) window group, so a departed
//! member simply vanishes and the ring/matching re-links over the
//! survivors at the next window.

use crate::util::rng::{Rng, SplitMix64};

/// Which peers each R-group member exchanges with per sync window. See
/// the module docs for the four shapes. `Full` is the default and is
/// bit-frozen to the pre-topology trainer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncTopology {
    /// Whole-group exchange (the legacy path, bit-frozen).
    Full,
    /// Fixed ±1 neighbor ring over the window's group order.
    Ring,
    /// Seeded perfect matching per window (NoLoCo gossip); odd group
    /// size leaves one member self-paired.
    RandomPair,
    /// Two-level: intra-node fabric reduce, then a sparse inter-node
    /// circulant overlay of `fanout` links per member.
    Hier {
        /// Inter-node links per member (validated `1 ≤ F < g`).
        fanout: usize,
    },
}

impl Default for SyncTopology {
    fn default() -> Self {
        SyncTopology::Full
    }
}

impl SyncTopology {
    /// Parse a `--topology` value: `full`, `ring`, `random-pair`, or
    /// `hier:<F>`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "full" => Ok(SyncTopology::Full),
            "ring" => Ok(SyncTopology::Ring),
            "random-pair" => Ok(SyncTopology::RandomPair),
            _ => {
                if let Some(f) = s.strip_prefix("hier:") {
                    let fanout: usize = f.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "--topology hier:<F>: fanout {f:?} is not an integer (e.g. hier:2)"
                        )
                    })?;
                    anyhow::ensure!(
                        fanout >= 1,
                        "--topology hier:<F>: fanout must be >= 1 (hier:0 exchanges nothing; \
                         use a larger F or a different topology)"
                    );
                    Ok(SyncTopology::Hier { fanout })
                } else {
                    anyhow::bail!(
                        "unknown --topology {s:?}: expected full, ring, random-pair, or hier:<F>"
                    )
                }
            }
        }
    }

    /// Canonical CLI spelling (round-trips through [`Self::parse`]).
    pub fn label(&self) -> String {
        match self {
            SyncTopology::Full => "full".into(),
            SyncTopology::Ring => "ring".into(),
            SyncTopology::RandomPair => "random-pair".into(),
            SyncTopology::Hier { fanout } => format!("hier:{fanout}"),
        }
    }

    /// The bit-frozen legacy path?
    pub fn is_full(&self) -> bool {
        matches!(self, SyncTopology::Full)
    }

    /// Static validation against the configured replication-group size
    /// (one member per node in the hybrid mesh). Rejects shapes that
    /// cannot do what they promise instead of panicking or silently
    /// clamping; churn shrinking a group *below* these floors at runtime
    /// is handled gracefully by [`Self::peer_sets`].
    pub fn validate(&self, group_size: usize) -> anyhow::Result<()> {
        match *self {
            SyncTopology::Ring => anyhow::ensure!(
                group_size >= 3,
                "--topology ring needs a replication group of >= 3 nodes (got {group_size}): \
                 a 2-node ring is just the full exchange and a 1-node ring is a no-op; \
                 use --topology full (or random-pair) on meshes this small"
            ),
            SyncTopology::Hier { fanout } => anyhow::ensure!(
                fanout < group_size,
                "--topology hier:{fanout} needs fanout < the replication group size \
                 ({group_size} node{}): {fanout} inter-node links per member would \
                 reach the whole group — lower F or use --topology full",
                if group_size == 1 { "" } else { "s" }
            ),
            SyncTopology::Full | SyncTopology::RandomPair => {}
        }
        Ok(())
    }

    /// The window's exchange sets: for each member *position* `i` in a
    /// group of `g`, the sorted peer positions it exchanges payloads
    /// with (`i` itself excluded — a member always averages its own
    /// contribution). Symmetric by construction for every variant, and a
    /// pure function of `(seed, step, shard, g)`: no RNG stream is
    /// consumed, so identical inputs give identical sets on every rank,
    /// thread count, and rerun.
    pub fn peer_sets(&self, seed: u64, step: u64, shard: u64, g: usize) -> Vec<Vec<usize>> {
        match *self {
            SyncTopology::Full => (0..g).map(|i| (0..g).filter(|&j| j != i).collect()).collect(),
            SyncTopology::Ring => {
                // Churn can shrink a validated group below 3; degrade to
                // the dense exchange (g ≤ 2 ring = full) rather than
                // refusing to sync.
                (0..g)
                    .map(|i| {
                        let mut p = vec![(i + g - 1) % g, (i + 1) % g];
                        p.sort_unstable();
                        p.dedup();
                        p.retain(|&j| j != i);
                        p
                    })
                    .collect()
            }
            SyncTopology::RandomPair => {
                let mut perm: Vec<usize> = (0..g).collect();
                // A *locally* seeded generator: the stream is derived
                // from (seed, step, shard) and dropped afterwards, so
                // the experiment's shared streams never advance.
                Rng::new(mix(seed, step, shard, 0x70_61_69_72)).shuffle(&mut perm);
                let mut peers = vec![Vec::new(); g];
                for pair in perm.chunks_exact(2) {
                    peers[pair[0]] = vec![pair[1]];
                    peers[pair[1]] = vec![pair[0]];
                }
                // Odd g: perm's last element is unmatched — it keeps an
                // empty peer set and averages only itself this window.
                peers
            }
            SyncTopology::Hier { fanout } => {
                let mut degree = fanout.min(g.saturating_sub(1));
                let mut offsets: Vec<usize> = Vec::new();
                if degree % 2 == 1 {
                    if g % 2 == 0 {
                        // The diameter offset g/2 is its own inverse:
                        // one link, keeping the overlay symmetric at an
                        // odd degree.
                        offsets.push(g / 2);
                        degree -= 1;
                    } else {
                        // No odd-degree regular graph exists on an odd
                        // node count; round the degree up to the next
                        // even value (capped at g−1, which is even here)
                        // so hier:1 still exchanges something.
                        degree = (degree + 1).min(g - 1);
                    }
                }
                let pairs = degree / 2;
                let avail = (g - 1) / 2;
                if pairs > 0 && avail > 0 {
                    // Rotate the circulant strides per window so sparse
                    // overlays still mix information across the whole
                    // group over time.
                    let start = (mix(seed, step, shard, 0x68_69_65_72) % avail as u64) as usize;
                    for j in 0..pairs {
                        offsets.push(1 + (start + j) % avail);
                    }
                }
                (0..g)
                    .map(|i| {
                        let mut p: Vec<usize> = offsets
                            .iter()
                            .flat_map(|&o| [(i + o) % g, (i + g - o) % g])
                            .filter(|&j| j != i)
                            .collect();
                        p.sort_unstable();
                        p.dedup();
                        p
                    })
                    .collect()
            }
        }
    }
}

impl std::fmt::Display for SyncTopology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// One SplitMix64 draw over the window coordinates — the same
/// pure-hash-of-(seed, step, …) idiom the fault timeline uses, with a
/// per-use tag so topology draws never collide with other consumers.
fn mix(seed: u64, step: u64, shard: u64, tag: u64) -> u64 {
    SplitMix64::new(
        seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ shard.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
            ^ tag.rotate_left(31),
    )
    .next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn check_symmetric(peers: &[Vec<usize>]) {
        for (i, ps) in peers.iter().enumerate() {
            for &j in ps {
                assert_ne!(i, j, "member {i} lists itself");
                assert!(
                    peers[j].contains(&i),
                    "asymmetric: {i} lists {j} but not vice versa ({peers:?})"
                );
            }
        }
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        for s in ["full", "ring", "random-pair", "hier:2", "hier:7"] {
            assert_eq!(SyncTopology::parse(s).unwrap().label(), s);
        }
        assert!(SyncTopology::parse("mesh").is_err());
        assert!(SyncTopology::parse("hier:").is_err());
        assert!(SyncTopology::parse("hier:x").is_err());
        let err = SyncTopology::parse("hier:0").unwrap_err().to_string();
        assert!(err.contains(">= 1"), "unactionable: {err}");
    }

    #[test]
    fn validate_rejects_tiny_ring_and_wide_hier() {
        let err = SyncTopology::Ring.validate(2).unwrap_err().to_string();
        assert!(err.contains(">= 3") && err.contains("full"), "unactionable: {err}");
        SyncTopology::Ring.validate(3).unwrap();
        let err = SyncTopology::Hier { fanout: 4 }
            .validate(4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fanout < "), "unactionable: {err}");
        SyncTopology::Hier { fanout: 3 }.validate(4).unwrap();
        SyncTopology::Full.validate(1).unwrap();
        SyncTopology::RandomPair.validate(1).unwrap();
    }

    #[test]
    fn full_is_everyone_else() {
        let peers = SyncTopology::Full.peer_sets(1, 2, 3, 4);
        assert_eq!(peers, vec![vec![1, 2, 3], vec![0, 2, 3], vec![0, 1, 3], vec![0, 1, 2]]);
    }

    #[test]
    fn ring_is_both_neighbors_and_degrades_small() {
        let peers = SyncTopology::Ring.peer_sets(0, 0, 0, 5);
        assert_eq!(peers[0], vec![1, 4]);
        assert_eq!(peers[2], vec![1, 3]);
        check_symmetric(&peers);
        // Churn-shrunk groups: g = 2 degrades to the pair, g = 1 to
        // nothing — no panic, no self-loop.
        assert_eq!(SyncTopology::Ring.peer_sets(0, 0, 0, 2), vec![vec![1], vec![0]]);
        assert_eq!(SyncTopology::Ring.peer_sets(0, 0, 0, 1), vec![Vec::<usize>::new()]);
    }

    #[test]
    fn random_pair_is_a_perfect_matching() {
        proptest(200, |gen| {
            let g = gen.usize(1, 33);
            let seed = gen.u64();
            let step = gen.u64() % 1000;
            let t = SyncTopology::RandomPair;
            let peers = t.peer_sets(seed, step, 2, g);
            check_symmetric(&peers);
            let selfies = peers.iter().filter(|p| p.is_empty()).count();
            crate::util::proptest::prop_assert(
                selfies == g % 2,
                &format!("odd-one-out count {selfies} for g={g}"),
            );
            for p in &peers {
                crate::util::proptest::prop_assert(p.len() <= 1, "matching degree > 1");
            }
            // Pure hash: a rerun (fresh call, no shared state) is
            // bit-identical.
            crate::util::proptest::prop_assert(
                peers == t.peer_sets(seed, step, 2, g),
                "matching not reproducible",
            );
        });
    }

    #[test]
    fn random_pair_varies_across_windows() {
        // Not a fixed pairing: across many windows of an 8-group each
        // member meets more than one distinct partner.
        let t = SyncTopology::RandomPair;
        let mut partners: Vec<std::collections::HashSet<usize>> =
            (0..8).map(|_| Default::default()).collect();
        for step in 0..32 {
            for (i, p) in t.peer_sets(42, step, 0, 8).iter().enumerate() {
                partners[i].extend(p.iter().copied());
            }
        }
        assert!(partners.iter().all(|s| s.len() >= 3), "{partners:?}");
    }

    #[test]
    fn hier_is_symmetric_sparse_and_rotates() {
        proptest(200, |gen| {
            let g = gen.usize(2, 33);
            let fanout = gen.usize(1, g);
            let seed = gen.u64();
            let step = gen.u64() % 1000;
            let t = SyncTopology::Hier { fanout };
            let peers = t.peer_sets(seed, step, 1, g);
            check_symmetric(&peers);
            for p in &peers {
                // Odd F on an odd g rounds up by one; never denser than
                // the full group.
                crate::util::proptest::prop_assert(
                    p.len() <= (fanout + 1).min(g - 1),
                    &format!("degree {} exceeds fanout {fanout} (g={g})", p.len()),
                );
                crate::util::proptest::prop_assert(
                    fanout < g - 1 || p.len() == g - 1,
                    "fanout g-1 must reach everyone",
                );
            }
            crate::util::proptest::prop_assert(
                peers == t.peer_sets(seed, step, 1, g),
                "overlay not reproducible",
            );
        });
        // The stride rotates with the step: on a large group, some pair
        // of windows must differ.
        let t = SyncTopology::Hier { fanout: 2 };
        let first = t.peer_sets(7, 0, 0, 16);
        assert!((1..8).any(|s| t.peer_sets(7, s, 0, 16) != first));
    }

    #[test]
    fn peer_sets_are_sorted_dedup_in_range() {
        proptest(200, |gen| {
            let g = gen.usize(1, 20);
            let fanout = 1 + gen.usize(0, g.max(2) - 2);
            let t = *gen.choose(&[
                SyncTopology::Full,
                SyncTopology::Ring,
                SyncTopology::RandomPair,
                SyncTopology::Hier { fanout },
            ]);
            let peers = t.peer_sets(gen.u64(), gen.u64(), gen.u64(), g);
            crate::util::proptest::prop_assert(peers.len() == g, "wrong member count");
            for (i, p) in peers.iter().enumerate() {
                for w in p.windows(2) {
                    crate::util::proptest::prop_assert(w[0] < w[1], "unsorted or dup");
                }
                crate::util::proptest::prop_assert(
                    p.iter().all(|&j| j < g && j != i),
                    "peer out of range or self",
                );
            }
        });
    }
}
