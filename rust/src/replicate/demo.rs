//! DeMo replication: chunked DCT-II → per-chunk top-k → (sign) →
//! all-gather — the selector from Peng et al. 2024, generalized here to
//! operate on FSDP shards (FlexDeMo).
//!
//! Wire format: per shard, the global coefficient indices (u32) plus the
//! selected coefficient values (sign-packed ternary or dtype-quantized).
//! Unlike Random/Striding, the indices depend on the *data* and must be
//! shipped — this is exactly the 2× bandwidth handicap the paper measures
//! (Fig 10: "DeMo transferring twice the amount of data, at the same
//! compression rate").
//!
//! The whole extract path runs allocation-free in steady state: the
//! chunked forward uses the blocked DCT kernel over `Scratch`'s arena,
//! selection is partial (`select_nth_unstable_by`) into reused index
//! buffers, and the kept-mass residual is reconstructed by **direct
//! k-term basis accumulation** (`Dct::inverse_sparse`, O(k·chunk) per
//! chunk) instead of materializing a dense coefficient buffer — all
//! bit-identical to the original dense pipeline (pinned by
//! `extract_bit_identical_to_dense_reference`).
//!
//! The forward DCT block batches, the residual scatter, and the decode
//! scatter all dispatch onto the scratch's worker pool (per-slot
//! `DctScratch` arenas, fixed chunk granules) — bit-identical at any
//! `--threads N` by construction.

use super::{ReplCtx, Replicator};
use crate::compress::{Payload, Scratch};
use crate::dct::Dct;
use crate::parallel::{self, SlicePtr};
use crate::tensor::Dtype;
use crate::topk;

/// DCT chunks per pool task: batch enough chunks that a task covers one
/// grid chunk's worth of elements. Fixed by (CHUNK, n) — independent of
/// worker count, so the parallel scatter is bit-identical at any width.
fn chunk_granule(n: usize) -> usize {
    (parallel::CHUNK / n).max(1)
}

#[derive(Debug)]
pub struct DemoReplicator {
    pub chunk: usize,
    pub k: usize,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
}

impl DemoReplicator {
    pub fn new(chunk: usize, k: usize, sign: bool, dtype: Dtype) -> DemoReplicator {
        assert!(k >= 1 && k <= chunk, "k={k} chunk={chunk}");
        DemoReplicator {
            chunk,
            k,
            sign,
            dtype,
            is_packed: false,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }

    /// Paper parameterization: compression rate = fraction of momentum
    /// components selected (k/chunk). Fig 8's TopK and Fig 11's chunk-size
    /// sweeps fix one and vary the other.
    pub fn from_rate(rate: f64, chunk: usize, sign: bool, dtype: Dtype) -> DemoReplicator {
        let k = ((chunk as f64 * rate).round() as usize).clamp(1, chunk);
        DemoReplicator::new(chunk, k, sign, dtype)
    }
}

impl Replicator for DemoReplicator {
    fn name(&self) -> String {
        format!(
            "demo-k{}c{}{}{}",
            self.k,
            self.chunk,
            if self.sign { "-sign" } else { "" },
            if self.dtype != Dtype::F32 {
                format!("-{}", self.dtype.name())
            } else {
                String::new()
            }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        let n = self.chunk;
        assert_eq!(
            buf.len() % n,
            0,
            "shard {} not divisible by chunk {}",
            buf.len(),
            n
        );
        let d = Dct::plan(n);
        scratch.ensure_dct_workers();

        // 1. chunked DCT-II into the reusable coefficient buffer — block
        // batches dispatched across the worker pool.
        {
            let Scratch {
                coeffs,
                dct_workers,
                pool,
                ..
            } = &mut *scratch;
            coeffs.clear();
            coeffs.resize(buf.len(), 0.0);
            d.forward_chunked_pooled(buf, coeffs, pool.get(), dct_workers);
        }

        // 2. partial-select top-k per chunk (pinned tie-breaking).
        topk::topk_per_chunk_into(
            &scratch.coeffs,
            n,
            self.k,
            &mut scratch.perm,
            &mut scratch.sel,
        );
        let mut values = scratch.take_f32();
        values.extend(scratch.sel.iter().map(|&i| scratch.coeffs[i as usize]));

        // 3. residual: reconstruct the kept mass chunk-by-chunk via the
        // direct k-term accumulation — chunk batches fan out across the
        // pool (fixed granule, bit-identical at any width) — and
        // subtract it from the buffer.
        let kk = self.k.min(n);
        let n_chunks = buf.len() / n;
        {
            let Scratch {
                removed,
                sel,
                dct_workers,
                pool,
                ..
            } = &mut *scratch;
            removed.clear();
            removed.resize(buf.len(), 0.0);
            let granule = chunk_granule(n);
            let n_tasks = n_chunks.div_ceil(granule);
            let remp = SlicePtr::new(removed);
            let wsp = SlicePtr::new(dct_workers);
            let values = &values;
            let sel = &*sel;
            pool.get().run(n_tasks, |w, t| {
                let c0 = t * granule;
                let c1 = (c0 + granule).min(n_chunks);
                // Safety: chunk ranges are disjoint per task; slot `w`
                // is owned by one thread for the job's duration.
                let s = unsafe { &mut wsp.range(w, w + 1)[0] };
                for ci in c0..c1 {
                    let lo = ci * kk;
                    d.inverse_sparse(
                        (ci * n) as u32,
                        &sel[lo..lo + kk],
                        &values[lo..lo + kk],
                        unsafe { remp.range(ci * n, (ci + 1) * n) },
                        s,
                    );
                }
            });
            parallel::zip_chunks(pool.get(), buf, removed, |bs, rs| {
                parallel::lanes::sub_assign(bs, rs);
            });
        }

        // 4. wire payload + locally-decoded dense update, pool-backed.
        let mut indices = scratch.take_u32();
        indices.extend_from_slice(&scratch.sel);
        let payload = self.mk_payload(Some(indices), values);
        let mut q_local = scratch.take_f32_zeroed(buf.len());
        self.decode(ctx, &payload, &mut q_local, scratch);
        (q_local, Some(payload))
    }

    fn decode(&self, _ctx: &ReplCtx, payload: &Payload, out: &mut [f32], scratch: &mut Scratch) {
        let n = self.chunk;
        assert_eq!(out.len() % n, 0);
        let d = Dct::plan(n);
        let indices = payload
            .indices
            .as_ref()
            .expect("demo payload carries indices");
        // Indices ascend (the selection emits them that way): each pool
        // task binary-searches its first chunk's boundary, then pointer-
        // walks its own chunk batch. Chunk batches are disjoint and
        // fixed-granule, so the scatter is bit-identical at any width.
        scratch.ensure_dct_workers();
        let Scratch {
            dct_workers, pool, ..
        } = &mut *scratch;
        let n_chunks = out.len() / n;
        let granule = chunk_granule(n);
        let n_tasks = n_chunks.div_ceil(granule);
        let outp = SlicePtr::new(out);
        let wsp = SlicePtr::new(dct_workers);
        pool.get().run(n_tasks, |w, t| {
            let c0 = t * granule;
            let c1 = (c0 + granule).min(n_chunks);
            // Safety: disjoint chunk ranges per task; slot `w` is owned
            // by one thread for the job's duration.
            let s = unsafe { &mut wsp.range(w, w + 1)[0] };
            let mut p = indices.partition_point(|&i| i < (c0 * n) as u32);
            for ci in c0..c1 {
                let hi = ((ci + 1) * n) as u32;
                let lo = p;
                while p < indices.len() && indices[p] < hi {
                    p += 1;
                }
                d.inverse_sparse(
                    (ci * n) as u32,
                    &indices[lo..p],
                    &payload.values[lo..p],
                    unsafe { outp.range(ci * n, (ci + 1) * n) },
                    s,
                );
            }
        });
    }

    fn rate(&self) -> f64 {
        self.k as f64 / self.chunk as f64
    }

    fn set_rate(&mut self, rate: f64) -> bool {
        assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");
        // Same quantization as `from_rate`: decode needs no hint either
        // way — DeMo payloads carry their indices.
        self.k = ((self.chunk as f64 * rate).round() as usize).clamp(1, self.chunk);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};
    use crate::util::rng::Rng;

    fn ctx() -> ReplCtx {
        ReplCtx {
            step: 0,
            shard: 0,
            seed: 1,
        }
    }

    #[test]
    fn extract_reduces_buffer_energy() {
        let mut rng = Rng::new(2);
        let mut buf: Vec<f32> = (0..512).map(|_| rng.normal_f32(1.0)).collect();
        let before: f64 = buf.iter().map(|&x| (x as f64).powi(2)).sum();
        let mut r = DemoReplicator::new(64, 8, true, Dtype::F32);
        let (_q, p) = r.extract(&ctx(), &mut buf, &mut Scratch::new());
        assert!(p.is_some());
        let after: f64 = buf.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn residual_plus_kept_reconstructs_nosign() {
        // Without sign, decode(payload) + residual == original buffer.
        proptest(24, |g| {
            let chunk = g.pow2(3, 7);
            let n_chunks = g.usize(1, 6);
            let k = g.usize(1, chunk);
            let orig = g.vec_normal(chunk * n_chunks, 1.0);
            let mut buf = orig.clone();
            let mut r = DemoReplicator::new(chunk, k, false, Dtype::F32);
            let (q, _) = r.extract(&ctx(), &mut buf, &mut Scratch::new());
            let recon: Vec<f32> = buf.iter().zip(&q).map(|(r, q)| r + q).collect();
            prop_assert(
                approx_slice_eq(&recon, &orig, 2e-3),
                format!("chunk={chunk} k={k}"),
            );
        });
    }

    #[test]
    fn extract_bit_identical_to_dense_reference() {
        // The zero-alloc pipeline (blocked forward, partial selection,
        // k-term residual accumulation) must match the original dense
        // reference — dense kept-mass buffer + chunked inverse — to the
        // last bit, payload and residual alike.
        proptest(16, |g| {
            let chunk = g.pow2(3, 7);
            let n_chunks = g.usize(1, 5);
            let k = g.usize(1, chunk);
            let orig = g.vec_normal(chunk * n_chunks, 1.0);

            // Reference: the pre-Scratch pipeline, spelled out.
            let d = Dct::plan(chunk);
            let mut coeffs = vec![0.0f32; orig.len()];
            d.forward_chunked(&orig, &mut coeffs);
            let indices = crate::topk::topk_per_chunk(&coeffs, chunk, k);
            let values: Vec<f32> = indices.iter().map(|&i| coeffs[i as usize]).collect();
            let mut kept = vec![0.0f32; orig.len()];
            for (&i, &v) in indices.iter().zip(&values) {
                kept[i as usize] = v;
            }
            let mut removed = vec![0.0f32; orig.len()];
            d.inverse_chunked(&kept, &mut removed);
            let mut want_buf = orig.clone();
            for (b, r) in want_buf.iter_mut().zip(&removed) {
                *b -= r;
            }
            let mut want_q = vec![0.0f32; orig.len()];
            d.inverse_chunked(&kept, &mut want_q);

            // New pipeline (nosign so payload values stay raw).
            let mut buf = orig.clone();
            let mut r = DemoReplicator::new(chunk, k, false, Dtype::F32);
            let (q, p) = r.extract(&ctx(), &mut buf, &mut Scratch::new());
            let p = p.unwrap();
            prop_assert(buf == want_buf, format!("chunk={chunk} k={k}: residual"));
            prop_assert(
                *p.indices.as_ref().unwrap() == indices,
                format!("chunk={chunk} k={k}: indices"),
            );
            prop_assert(p.values == values, format!("chunk={chunk} k={k}: values"));
            prop_assert(q == want_q, format!("chunk={chunk} k={k}: q"));
        });
    }

    #[test]
    fn k_equals_chunk_extracts_everything() {
        let mut rng = Rng::new(3);
        let mut buf: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(64, 64, false, Dtype::F32);
        let _ = r.extract(&ctx(), &mut buf, &mut Scratch::new());
        assert!(buf.iter().all(|&x| x.abs() < 1e-4));
    }

    #[test]
    fn payload_carries_k_per_chunk_indices() {
        let mut rng = Rng::new(4);
        let mut buf: Vec<f32> = (0..1024).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(128, 16, true, Dtype::F32);
        let (_, p) = r.extract(&ctx(), &mut buf, &mut Scratch::new());
        let p = p.unwrap();
        assert_eq!(p.indices.as_ref().unwrap().len(), 8 * 16);
        assert_eq!(p.values.len(), 8 * 16);
        // signed: values ternary
        assert!(p.values.iter().all(|&v| v == 1.0 || v == -1.0 || v == 0.0));
    }

    #[test]
    fn from_rate_picks_k() {
        let r = DemoReplicator::from_rate(1.0 / 8.0, 64, true, Dtype::F32);
        assert_eq!(r.k, 8);
        let r = DemoReplicator::from_rate(1.0 / 128.0, 64, true, Dtype::F32);
        assert_eq!(r.k, 1); // clamped to at least one component
        assert!((r.rate() - 1.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn decode_matches_q_local() {
        let mut rng = Rng::new(5);
        let mut buf: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(32, 4, true, Dtype::F32);
        let c = ctx();
        let mut s = Scratch::new();
        let (q, p) = r.extract(&c, &mut buf, &mut s);
        let mut out = vec![0.0f32; 256];
        r.decode(&c, &p.unwrap(), &mut out, &mut s);
        assert_eq!(q, out);
    }

    #[test]
    fn matches_python_oracle_structure() {
        // The sign payload decodes to a vector whose DCT is ternary with
        // exactly k nonzeros per chunk (mirrors the python kernel test
        // test_extract_transmit_is_ternary_decode_when_signed).
        let mut rng = Rng::new(6);
        let mut buf: Vec<f32> = (0..512).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(64, 8, true, Dtype::F32);
        let c = ctx();
        let (q, _) = r.extract(&c, &mut buf, &mut Scratch::new());
        let d = Dct::plan(64);
        let mut coeffs = vec![0.0f32; 512];
        d.forward_chunked(&q, &mut coeffs);
        for ch in coeffs.chunks_exact(64) {
            let nz = ch.iter().filter(|v| v.abs() > 1e-4).count();
            assert_eq!(nz, 8);
            for &v in ch.iter().filter(|v| v.abs() > 1e-4) {
                assert!((v.abs() - 1.0).abs() < 1e-3, "{v}");
            }
        }
    }
}
