//! DeMo replication: chunked DCT-II → per-chunk top-k → (sign) →
//! all-gather — the selector from Peng et al. 2024, generalized here to
//! operate on FSDP shards (FlexDeMo).
//!
//! Wire format: per shard, the global coefficient indices (u32) plus the
//! selected coefficient values (sign-packed ternary or dtype-quantized).
//! Unlike Random/Striding, the indices depend on the *data* and must be
//! shipped — this is exactly the 2× bandwidth handicap the paper measures
//! (Fig 10: "DeMo transferring twice the amount of data, at the same
//! compression rate").

use super::{ReplCtx, Replicator};
use crate::compress::Payload;
use crate::dct::Dct;
use crate::tensor::Dtype;
use crate::topk;

#[derive(Debug)]
pub struct DemoReplicator {
    pub chunk: usize,
    pub k: usize,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
}

impl DemoReplicator {
    pub fn new(chunk: usize, k: usize, sign: bool, dtype: Dtype) -> DemoReplicator {
        assert!(k >= 1 && k <= chunk, "k={k} chunk={chunk}");
        DemoReplicator {
            chunk,
            k,
            sign,
            dtype,
            is_packed: false,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }


    /// Paper parameterization: compression rate = fraction of momentum
    /// components selected (k/chunk). Fig 8's TopK and Fig 11's chunk-size
    /// sweeps fix one and vary the other.
    pub fn from_rate(rate: f64, chunk: usize, sign: bool, dtype: Dtype) -> DemoReplicator {
        let k = ((chunk as f64 * rate).round() as usize).clamp(1, chunk);
        DemoReplicator::new(chunk, k, sign, dtype)
    }

    /// DCT of the buffer → (indices, kept values), and subtract the kept
    /// components from the buffer (residual momentum).
    fn transform_select(&self, buf: &mut [f32]) -> (Vec<u32>, Vec<f32>) {
        let d = Dct::plan(self.chunk);
        let mut coeffs = vec![0.0f32; buf.len()];
        d.forward_chunked(buf, &mut coeffs);
        let indices = topk::topk_per_chunk(&coeffs, self.chunk, self.k);
        let values: Vec<f32> = indices.iter().map(|&i| coeffs[i as usize]).collect();
        // Residual: zero all but the kept coefficients, inverse-transform
        // the kept mass, subtract from the buffer.
        let mut kept = vec![0.0f32; buf.len()];
        for (&i, &v) in indices.iter().zip(&values) {
            kept[i as usize] = v;
        }
        let mut removed = vec![0.0f32; buf.len()];
        d.inverse_chunked(&kept, &mut removed);
        for (b, r) in buf.iter_mut().zip(&removed) {
            *b -= r;
        }
        (indices, values)
    }
}

impl Replicator for DemoReplicator {
    fn name(&self) -> String {
        format!(
            "demo-k{}c{}{}{}",
            self.k,
            self.chunk,
            if self.sign { "-sign" } else { "" },
            if self.dtype != Dtype::F32 {
                format!("-{}", self.dtype.name())
            } else {
                String::new()
            }
        )
    }

    fn extract(&mut self, ctx: &ReplCtx, buf: &mut [f32]) -> (Vec<f32>, Option<Payload>) {
        assert_eq!(
            buf.len() % self.chunk,
            0,
            "shard {} not divisible by chunk {}",
            buf.len(),
            self.chunk
        );
        let (indices, values) = self.transform_select(buf);
        let payload = self.mk_payload(Some(indices), values);
        let mut q_local = vec![0.0f32; buf.len()];
        self.decode(ctx, &payload, &mut q_local);
        (q_local, Some(payload))
    }

    fn decode(&self, _ctx: &ReplCtx, payload: &Payload, out: &mut [f32]) {
        let d = Dct::plan(self.chunk);
        let mut coeffs = vec![0.0f32; out.len()];
        let indices = payload
            .indices
            .as_ref()
            .expect("demo payload carries indices");
        for (&i, &v) in indices.iter().zip(&payload.values) {
            coeffs[i as usize] = v;
        }
        d.inverse_chunked(&coeffs, out);
    }

    fn rate(&self) -> f64 {
        self.k as f64 / self.chunk as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};
    use crate::util::rng::Rng;

    fn ctx() -> ReplCtx {
        ReplCtx {
            step: 0,
            shard: 0,
            seed: 1,
        }
    }

    #[test]
    fn extract_reduces_buffer_energy() {
        let mut rng = Rng::new(2);
        let mut buf: Vec<f32> = (0..512).map(|_| rng.normal_f32(1.0)).collect();
        let before: f64 = buf.iter().map(|&x| (x as f64).powi(2)).sum();
        let mut r = DemoReplicator::new(64, 8, true, Dtype::F32);
        let (_q, p) = r.extract(&ctx(), &mut buf);
        assert!(p.is_some());
        let after: f64 = buf.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn residual_plus_kept_reconstructs_nosign() {
        // Without sign, decode(payload) + residual == original buffer.
        proptest(24, |g| {
            let chunk = g.pow2(3, 7);
            let n_chunks = g.usize(1, 6);
            let k = g.usize(1, chunk);
            let orig = g.vec_normal(chunk * n_chunks, 1.0);
            let mut buf = orig.clone();
            let mut r = DemoReplicator::new(chunk, k, false, Dtype::F32);
            let (q, _) = r.extract(&ctx(), &mut buf);
            let recon: Vec<f32> = buf.iter().zip(&q).map(|(r, q)| r + q).collect();
            prop_assert(
                approx_slice_eq(&recon, &orig, 2e-3),
                format!("chunk={chunk} k={k}"),
            );
        });
    }

    #[test]
    fn k_equals_chunk_extracts_everything() {
        let mut rng = Rng::new(3);
        let mut buf: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(64, 64, false, Dtype::F32);
        let _ = r.extract(&ctx(), &mut buf);
        assert!(buf.iter().all(|&x| x.abs() < 1e-4));
    }

    #[test]
    fn payload_carries_k_per_chunk_indices() {
        let mut rng = Rng::new(4);
        let mut buf: Vec<f32> = (0..1024).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(128, 16, true, Dtype::F32);
        let (_, p) = r.extract(&ctx(), &mut buf);
        let p = p.unwrap();
        assert_eq!(p.indices.as_ref().unwrap().len(), 8 * 16);
        assert_eq!(p.values.len(), 8 * 16);
        // signed: values ternary
        assert!(p.values.iter().all(|&v| v == 1.0 || v == -1.0 || v == 0.0));
    }

    #[test]
    fn from_rate_picks_k() {
        let r = DemoReplicator::from_rate(1.0 / 8.0, 64, true, Dtype::F32);
        assert_eq!(r.k, 8);
        let r = DemoReplicator::from_rate(1.0 / 128.0, 64, true, Dtype::F32);
        assert_eq!(r.k, 1); // clamped to at least one component
        assert!((r.rate() - 1.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn decode_matches_q_local() {
        let mut rng = Rng::new(5);
        let mut buf: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(32, 4, true, Dtype::F32);
        let c = ctx();
        let (q, p) = r.extract(&c, &mut buf);
        let mut out = vec![0.0f32; 256];
        r.decode(&c, &p.unwrap(), &mut out);
        assert_eq!(q, out);
    }

    #[test]
    fn matches_python_oracle_structure() {
        // The sign payload decodes to a vector whose DCT is ternary with
        // exactly k nonzeros per chunk (mirrors the python kernel test
        // test_extract_transmit_is_ternary_decode_when_signed).
        let mut rng = Rng::new(6);
        let mut buf: Vec<f32> = (0..512).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = DemoReplicator::new(64, 8, true, Dtype::F32);
        let c = ctx();
        let (q, _) = r.extract(&c, &mut buf);
        let d = Dct::plan(64);
        let mut coeffs = vec![0.0f32; 512];
        d.forward_chunked(&q, &mut coeffs);
        for ch in coeffs.chunks_exact(64) {
            let nz = ch.iter().filter(|v| v.abs() > 1e-4).count();
            assert_eq!(nz, 8);
            for &v in ch.iter().filter(|v| v.abs() > 1e-4) {
                assert!((v.abs() - 1.0).abs() < 1e-3, "{v}");
            }
        }
    }
}
