//! Replication schemes — the DeToNATION framework's core abstraction
//! (paper §Methods, §Replication Schemes).
//!
//! A [`Replicator`] decides *which components* of a rank's decoupled
//! update buffer are exchanged across the replication group R (one group
//! per shard index, spanning nodes) and *when*. The framework ships:
//!
//! | scheme      | selection                         | indices on wire? | when        |
//! |-------------|-----------------------------------|------------------|-------------|
//! | DeMo        | chunked DCT-II → top-k per chunk  | yes (4 B each)   | every step  |
//! | Random      | seeded random subset              | no (regenerated) | every step  |
//! | Striding    | every n-th index (rotating offset)| no (regenerated) | every step  |
//! | DiLoCo      | everything                        | no               | every n-th  |
//! | async DiLoCo| everything                        | no               | every n-th, applied `S` steps late |
//! | Full        | everything                        | no               | every step  |
//!
//! Random/Striding regenerate their index sets from `(seed, step, shard)`
//! on every rank of the R-group — bit-identical by construction (tested) —
//! which is the paper's "share double the amount of data, on the same
//! bandwidth" property.
//!
//! ## Protocol per training step (per shard / R-group)
//!
//! 1. [`Replicator::extract`] pulls this step's components out of the
//!    buffer (mutating it to keep the *residual* — decoupling) and returns
//!    `(q_local, Option<Payload>)`;
//! 2. if `Some(payload)`, the trainer all-gathers payloads across R
//!    (naive blocking gather — DeMo's primitive, the Fig 6 bottleneck),
//!    decodes each via [`Replicator::decode`], and averages;
//! 3. [`Replicator::finalize`] turns `(q_local, mean)` into the update Q
//!    the optimizer applies. DiLoCo uses this hook to re-synchronize
//!    parameter trajectories after local-only steps. A scheme with a
//!    non-zero [`Replicator::sync_delay`] (async DiLoCo's `--staleness`)
//!    gets its mean *deferred*: the trainer parks the gathered payloads
//!    at the launch step and hands the decoded mean to `finalize` S
//!    steps later, while local steps keep running. On heterogeneous
//!    clusters the window is additionally governed by a [`LatePolicy`]:
//!    contributions that miss a node's arrival deadline are waited for
//!    (PR 4 semantics), dropped from the mean (NoLoCo-style, denominator
//!    corrected to the contributing set — [`mean_decoded_refs`]), or
//!    carried into that node's next window.
//!
//! Every hook threads a per-worker [`Scratch`] arena: extraction draws
//! its payload/`q` vectors from the arena's pools and hot-path stage
//! buffers, and the caller recycles consumed payloads back
//! ([`Scratch::recycle_payload`]). The DeMo hot path is allocation-free
//! in steady state (asserted by `benches/compress.rs`); Random still
//! builds its seeded sample set internally (`Rng::sample_indices_into`
//! is honest about this), so only its output vectors are pooled.
//!
//! ## Per-node construction and adaptive rates
//!
//! Every rank's replicator is instantiated through one entry point,
//! [`ReplSpec::build_for_node`], which reads that rank's node-local
//! staleness window and compression rate out of a [`ReplBuildCtx`] —
//! heterogeneous clusters get per-node schedules *and* per-node rates
//! from the same construction site. At runtime the closed-loop
//! [`control::RateController`] (`--compress-control aimd`) watches each
//! node's NIC occupancy and exposed-comm ratio and retunes its rate via
//! [`Replicator::set_rate`] — no accumulator rebuild. Decode stays
//! correct under heterogeneous k because every every-step scheme
//! recovers its selection from the payload itself (DeMo ships indices,
//! Random implies k by `values.len()`, Striding ships its stride as the
//! payload's `sel` hint while the controller is armed).

pub mod control;
mod demo;
mod diloco;
mod full;
mod random;
mod striding;
pub mod topology;

pub use control::{AimdParams, ControlSpec, RateController};
pub use demo::DemoReplicator;
pub use diloco::{AsyncDiLoCoReplicator, DiLoCoReplicator};
pub use full::FullReplicator;
pub use random::RandomReplicator;
pub use striding::StridingReplicator;
pub use topology::SyncTopology;

use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

/// Per-step, per-shard context. Everything a replicator may condition on
/// must come from here so all ranks of an R-group agree.
#[derive(Clone, Copy, Debug)]
pub struct ReplCtx {
    pub step: u64,
    /// Shard index (= accelerator index in the hybrid mesh).
    pub shard: usize,
    /// Experiment seed (shared across ranks).
    pub seed: u64,
}

impl ReplCtx {
    /// The RNG stream shared by every rank replicating this shard at this
    /// step (the fixed-seed reproducibility trick from the paper).
    pub fn shared_rng(&self) -> crate::util::rng::Rng {
        crate::util::rng::Rng::new(
            self.seed
                ^ self.step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (self.shard as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
    }
}

/// A replication scheme instance (one per rank; may hold rank-local state
/// such as DiLoCo's displacement accumulator).
pub trait Replicator: Send {
    /// Human-readable name used in metrics/figures (e.g. "demo-1/8").
    fn name(&self) -> String;

    /// Extract this step's update from the buffer (mutating it to the
    /// residual). Returns the locally-decoded dense update `q_local` and
    /// the wire payload if this step replicates. Payload and `q_local`
    /// vectors come from `scratch`'s pools — recycle them when consumed.
    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>);

    /// Decode one gathered payload into a dense shard-sized vector
    /// (`out` is zeroed by the caller).
    fn decode(&self, ctx: &ReplCtx, payload: &Payload, out: &mut [f32], scratch: &mut Scratch);

    /// Produce the final update from the local extraction and the mean of
    /// all decoded payloads across R (None when this step didn't sync).
    /// Default: synchronized mean when present, else the local update;
    /// the vector not returned goes back to the scratch pool.
    fn finalize(
        &mut self,
        _ctx: &ReplCtx,
        q_local: Vec<f32>,
        mean: Option<Vec<f32>>,
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        match mean {
            Some(m) => {
                scratch.put_f32(q_local);
                m
            }
            None => q_local,
        }
    }

    /// Fraction of components selected per replicating step (reporting).
    fn rate(&self) -> f64;

    /// Retune the selection fraction in place — the adaptive controller's
    /// hook (`--compress-control aimd`), called between steps so no
    /// accumulator is rebuilt. Returns `true` if the scheme honoured the
    /// new rate; the default (`false`) is for schemes whose "rate" is
    /// structural (DiLoCo's period, Full's everything) and is ignored.
    fn set_rate(&mut self, _rate: f64) -> bool {
        false
    }

    /// Steps between a payload-emitting step and the application of its
    /// gathered mean for *this instance*. 0 (the default for every
    /// synchronous scheme) means the mean lands in the same step's
    /// [`Replicator::finalize`]; S > 0 is async DiLoCo's staleness
    /// window. The trainer is the source of truth for the schedule — it
    /// resolves one window per node (`--staleness [auto]`,
    /// `--node-staleness`) and constructs each rank's replicator with
    /// its node's value via [`ReplSpec::build_for_node`], so this
    /// method reports that window rather than driving it. Must be
    /// strictly smaller than the interval between payload-emitting
    /// steps.
    fn sync_delay(&self) -> u64 {
        0
    }

    /// How payloads cross the replication group. Sparse schemes use DeMo's
    /// naive blocking all-gather (the Fig 6 non-scaling primitive); the
    /// Full baseline uses the ring all-reduce NCCL/RCCL would.
    fn gather_mode(&self) -> GatherMode {
        GatherMode::NaiveAllGather
    }

    /// Snapshot the replicator's mutable state for checkpointing. The
    /// every-step schemes (DeMo/Random/Striding/Full) are stateless —
    /// their residual lives in the optimizer buffer — so the default is
    /// the empty snapshot; DiLoCo overrides it to carry its displacement
    /// accumulator (and async DiLoCo its in-flight launch snapshot).
    fn export_state(&self) -> ReplState {
        ReplState::default()
    }

    /// Restore an [`Replicator::export_state`] snapshot taken on a
    /// replicator of the same kind and shard length.
    fn import_state(&mut self, st: ReplState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.is_empty(),
            "{} is stateless but its snapshot carries {} accumulator elements",
            self.name(),
            st.delta_acc.len()
        );
        Ok(())
    }
}

/// A serializable snapshot of one replicator's mutable state: DiLoCo's
/// displacement accumulator plus async DiLoCo's in-flight launch
/// snapshot. Empty (the [`Default`]) for the stateless schemes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplState {
    pub delta_acc: Vec<f32>,
    pub in_flight: Option<Vec<f32>>,
}

impl ReplState {
    pub fn is_empty(&self) -> bool {
        self.delta_acc.is_empty() && self.in_flight.is_none()
    }
}

/// What an async DiLoCo aggregation does with peer contributions that
/// miss its arrival deadline (`--late-policy`, or the `async=S,policy`
/// spec component). Only meaningful when a staleness window exists; the
/// synchronous scheme never has late arrivals.
///
/// * [`LatePolicy::Wait`] — PR 4 semantics: the arrival blocks the next
///   backward until the *whole* group gather has landed (the slowest
///   member's reduce-scatter plus the full send queue gates everyone).
/// * [`LatePolicy::Drop`] — NoLoCo-style: the window finalizes from the
///   quorum that arrived by the deadline; late deltas are discarded and
///   the averaging denominator is the contributing set, not the group.
/// * [`LatePolicy::Partial`] — like `Drop` for time, but late deltas are
///   carried and folded into that node's *next* window mean instead of
///   being lost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LatePolicy {
    #[default]
    Wait,
    Drop,
    Partial,
}

impl LatePolicy {
    pub fn parse(s: &str) -> anyhow::Result<LatePolicy> {
        match s {
            "wait" => Ok(LatePolicy::Wait),
            "drop" => Ok(LatePolicy::Drop),
            "partial" => Ok(LatePolicy::Partial),
            other => anyhow::bail!("unknown late policy {other:?} (wait|drop|partial)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LatePolicy::Wait => "wait",
            LatePolicy::Drop => "drop",
            LatePolicy::Partial => "partial",
        }
    }
}

/// Transport algorithm for replication payloads (cost model selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherMode {
    /// Every rank sends its payload to every peer: received volume grows
    /// linearly with |R| — matches `dist.all_gather` of opaque tensors.
    NaiveAllGather,
    /// Ring all-reduce of the dense buffer: per-rank volume ~2·B,
    /// group-size independent — what full gradient sync uses.
    RingAllReduce,
}

impl GatherMode {
    /// Build the replication-phase cost event for `payload_bytes[i]` owned
    /// by group member `i`, crossing `link`. This is the single place a
    /// replicator's transport choice turns into a schedulable
    /// [`crate::collectives::CommEvent`].
    pub fn comm_event(
        self,
        link: &crate::collectives::Link,
        payload_bytes: &[u64],
    ) -> crate::collectives::CommEvent {
        match self {
            GatherMode::NaiveAllGather => {
                crate::collectives::naive_all_gather_event(link, payload_bytes)
            }
            GatherMode::RingAllReduce => crate::collectives::ring_all_reduce_event(
                link,
                payload_bytes.len(),
                payload_bytes.first().copied().unwrap_or(0),
            ),
        }
    }

    /// Record this transport's who-sends-to-whom byte pattern.
    pub fn record_traffic(
        self,
        traffic: &crate::net::TrafficMatrix,
        topo: &crate::net::Topology,
        group: &[usize],
        payload_bytes: &[u64],
    ) {
        let g = group.len();
        if g <= 1 {
            return;
        }
        match self {
            GatherMode::NaiveAllGather => {
                for (i, &bytes_i) in payload_bytes.iter().enumerate() {
                    for j in 0..g {
                        if i != j {
                            traffic.record(
                                topo.node_of(group[i]),
                                topo.node_of(group[j]),
                                bytes_i,
                            );
                        }
                    }
                }
            }
            GatherMode::RingAllReduce => {
                let chunk = payload_bytes.first().copied().unwrap_or(0) / g as u64;
                crate::collectives::record_ring_traffic(traffic, topo, group, 2 * (g - 1), chunk);
            }
        }
    }
}

/// Everything [`ReplSpec::build_for_node`] needs to instantiate one
/// rank's replicator on a heterogeneous cluster: the shard geometry plus
/// the optional per-*node* parameter tables (indexed by
/// `rank / accels`). `None` tables mean "uniform, straight from the
/// spec" — [`ReplBuildCtx::uniform`] is the homogeneous build.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplBuildCtx<'a> {
    /// Elements in the shard this replicator covers.
    pub shard_len: usize,
    /// Accelerators per node (maps a rank to its node; 0 acts as 1).
    pub accels: usize,
    /// Per-node staleness windows (diloco-only; resolved by the trainer
    /// from `--staleness [auto]` / `--node-staleness`).
    pub staleness: Option<&'a [u64]>,
    /// Per-node compression rates (demo/random/striding-only; seeded and
    /// then retuned by the [`control::RateController`]).
    pub rates: Option<&'a [f64]>,
    /// True while the adaptive controller is armed: schemes whose decode
    /// needs a selection hint under heterogeneous rates (Striding) ship
    /// it on the wire. Off keeps the wire format bit-identical.
    pub adaptive: bool,
}

impl ReplBuildCtx<'static> {
    /// Homogeneous build: every rank gets the spec's own parameters.
    pub fn uniform(shard_len: usize) -> ReplBuildCtx<'static> {
        ReplBuildCtx {
            shard_len,
            accels: 1,
            staleness: None,
            rates: None,
            adaptive: false,
        }
    }
}

/// Which scheme to build (config / CLI surface).
#[derive(Clone, Debug, PartialEq)]
pub enum ReplSpec {
    Demo {
        rate: f64,
        chunk: usize,
        sign: bool,
        dtype: Dtype,
        packed: bool,
    },
    Random {
        rate: f64,
        sign: bool,
        dtype: Dtype,
        packed: bool,
    },
    Striding {
        rate: f64,
        sign: bool,
        dtype: Dtype,
        packed: bool,
    },
    DiLoCo {
        /// Sync every `period` steps (paper: rate = 1/period).
        period: u64,
        sign: bool,
        dtype: Dtype,
        packed: bool,
        /// `None` = today's synchronous scheme; `Some(S)` = async DiLoCo
        /// applying the gathered mean S steps after the launch
        /// (`--staleness S`, or the `async=S` spec component; `Some(0)`
        /// runs the async implementation, bit-identical to `None`).
        staleness: Option<u64>,
        /// Late-arrival handling for the async window (`--late-policy`,
        /// or the `async=S,policy` spec component). Inert while the
        /// resolved staleness is 0 everywhere.
        policy: LatePolicy,
    },
    Full {
        sign: bool,
        dtype: Dtype,
        packed: bool,
    },
}

impl ReplSpec {
    /// Parse "demo:1/8", "random:1/16", "striding:1/32", "diloco:32",
    /// "full" (+ optional ":nosign" / ":sign" / ":bf16" / ":chunk=128";
    /// diloco additionally takes ":async=S" for the stale-sync variant —
    /// see `--staleness` — with an optional late policy suffix,
    /// ":async=S,drop" / ":async=S,partial" — see `--late-policy`).
    pub fn parse(s: &str) -> anyhow::Result<ReplSpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let mut rate = 1.0 / 8.0;
        let mut period = 8u64;
        let mut sign = true;
        let mut dtype = Dtype::F32;
        let mut chunk = 64usize;
        let mut packed = false;
        let mut staleness = None;
        let mut policy = LatePolicy::Wait;
        for p in parts {
            if let Some(r) = p.strip_prefix("1/") {
                let c: f64 = r.parse()?;
                rate = 1.0 / c;
                period = c as u64;
            } else if let Some(c) = p.strip_prefix("chunk=") {
                chunk = c.parse()?;
            } else if let Some(a) = p.strip_prefix("async=") {
                let (st, pol) = match a.split_once(',') {
                    Some((st, pol)) => (st, Some(pol)),
                    None => (a, None),
                };
                staleness = Some(st.parse()?);
                if let Some(pol) = pol {
                    policy = LatePolicy::parse(pol)?;
                }
            } else if p == "nosign" {
                sign = false;
            } else if p == "sign" {
                sign = true;
            } else if p == "packed" {
                // Extension: 2-bit ternary wire format (paper future work).
                packed = true;
            } else if let Some(d) = Dtype::parse(p) {
                dtype = d;
            } else if let Ok(c) = p.parse::<u64>() {
                period = c;
                rate = 1.0 / c as f64;
            } else {
                anyhow::bail!("bad replicator component {p:?} in {s:?}");
            }
        }
        if let Some(st) = staleness {
            anyhow::ensure!(
                kind == "diloco",
                "async={st} only applies to the diloco replicator, not {kind:?}"
            );
            anyhow::ensure!(
                st < period,
                "staleness {st} must be < diloco period {period} \
                 (one gather in flight at a time)"
            );
        }
        Ok(match kind {
            "demo" => ReplSpec::Demo {
                rate,
                chunk,
                sign,
                dtype,
                packed,
            },
            "random" => ReplSpec::Random {
                rate,
                sign,
                dtype,
                packed,
            },
            "striding" => ReplSpec::Striding {
                rate,
                sign,
                dtype,
                packed,
            },
            "diloco" => ReplSpec::DiLoCo {
                period,
                sign,
                dtype,
                packed,
                staleness,
                policy,
            },
            // Full-sync baseline ships raw gradients (no sign) by default;
            // "full:sign" gives the signed variant (Fig 10's full-repl arm).
            "full" => ReplSpec::Full {
                sign: s.contains(":sign"),
                dtype,
                packed,
            },
            _ => anyhow::bail!("unknown replicator {kind:?} (demo|random|striding|diloco|full)"),
        })
    }

    /// Instantiate this spec for the rank at `rank` — the single
    /// construction entry point. The [`ReplBuildCtx`] carries everything
    /// per-node: the rank's node is `rank / ctx.accels`, and that node's
    /// staleness window / compression rate (when the respective tables
    /// are armed) parameterize the instance. `ReplBuildCtx::uniform`
    /// reproduces the old homogeneous build exactly.
    pub fn build_for_node(
        &self,
        rank: usize,
        ctx: &ReplBuildCtx,
    ) -> anyhow::Result<Box<dyn Replicator>> {
        let node = rank / ctx.accels.max(1);
        let pick = |table: Option<&[f64]>| -> anyhow::Result<Option<f64>> {
            match table {
                None => Ok(None),
                Some(t) => Ok(Some(*t.get(node).ok_or_else(|| {
                    anyhow::anyhow!("rate table has {} entries but rank {rank} is on node {node}", t.len())
                })?)),
            }
        };
        if ctx.staleness.is_some() && !matches!(self, ReplSpec::DiLoCo { .. }) {
            anyhow::bail!(
                "per-node staleness only applies to the diloco replicator (got {:?})",
                self.label()
            );
        }
        if ctx.rates.is_some() && matches!(self, ReplSpec::DiLoCo { .. } | ReplSpec::Full { .. }) {
            anyhow::bail!(
                "per-node compression rates only apply to demo/random/striding (got {:?})",
                self.label()
            );
        }
        let shard_len = ctx.shard_len;
        Ok(match *self {
            ReplSpec::Demo {
                rate,
                chunk,
                sign,
                dtype,
                packed,
            } => {
                let rate = pick(ctx.rates)?.unwrap_or(rate);
                Box::new(DemoReplicator::from_rate(rate, chunk, sign, dtype).packed(packed))
            }
            ReplSpec::Random {
                rate,
                sign,
                dtype,
                packed,
            } => {
                let rate = pick(ctx.rates)?.unwrap_or(rate);
                Box::new(RandomReplicator::new(rate, sign, dtype).packed(packed))
            }
            ReplSpec::Striding {
                rate,
                sign,
                dtype,
                packed,
            } => {
                let rate = pick(ctx.rates)?.unwrap_or(rate);
                Box::new(
                    StridingReplicator::new(rate, sign, dtype)
                        .packed(packed)
                        .adaptive(ctx.adaptive),
                )
            }
            ReplSpec::DiLoCo {
                period,
                sign,
                dtype,
                packed,
                staleness,
                ..
            } => {
                // Per-node table wins over the spec's uniform window; a
                // spec-level `async=S` without a table is the uniform
                // per-node build.
                let window = match ctx.staleness {
                    Some(t) => Some(*t.get(node).ok_or_else(|| {
                        anyhow::anyhow!(
                            "staleness table has {} entries but rank {rank} is on node {node}",
                            t.len()
                        )
                    })?),
                    None => staleness,
                };
                match window {
                    Some(s) => {
                        anyhow::ensure!(
                            s < period,
                            "staleness {s} must be < diloco period {period} \
                             (one gather in flight at a time)"
                        );
                        Box::new(
                            AsyncDiLoCoReplicator::new(period, sign, dtype, shard_len, s)
                                .packed(packed),
                        )
                    }
                    None => Box::new(
                        DiLoCoReplicator::new(period, sign, dtype, shard_len).packed(packed),
                    ),
                }
            }
            ReplSpec::Full {
                sign,
                dtype,
                packed,
            } => Box::new(FullReplicator::new(sign, dtype).packed(packed)),
        })
    }

    pub fn label(&self) -> String {
        match self {
            ReplSpec::Demo { rate, .. } => format!("demo-1/{:.0}", 1.0 / rate),
            ReplSpec::Random { rate, .. } => format!("random-1/{:.0}", 1.0 / rate),
            ReplSpec::Striding { rate, .. } => format!("striding-1/{:.0}", 1.0 / rate),
            ReplSpec::DiLoCo {
                period,
                staleness: Some(s),
                policy,
                ..
            } => {
                let pol = match policy {
                    LatePolicy::Wait => String::new(),
                    p => format!("-{}", p.label()),
                };
                format!("diloco-1/{period}-async{s}{pol}")
            }
            ReplSpec::DiLoCo { period, .. } => format!("diloco-1/{period}"),
            ReplSpec::Full { .. } => "full".to_string(),
        }
    }

    /// The configured compression rate of a sparse scheme — the rate
    /// controller's per-node starting point. `None` for DiLoCo/Full,
    /// whose "rate" is structural (period / everything) rather than a
    /// retunable fraction.
    pub fn base_rate(&self) -> Option<f64> {
        match self {
            ReplSpec::Demo { rate, .. }
            | ReplSpec::Random { rate, .. }
            | ReplSpec::Striding { rate, .. } => Some(*rate),
            ReplSpec::DiLoCo { .. } | ReplSpec::Full { .. } => None,
        }
    }
}

/// Dense mean of decoded payloads (helper used by the trainer). The
/// result vector comes from `scratch`'s pool — recycle it after applying.
/// Decode and accumulation run chunk-parallel on the scratch's worker
/// pool (payload order stays sequential, so numerics are unchanged).
pub fn mean_decoded(
    repl: &dyn Replicator,
    ctx: &ReplCtx,
    payloads: &[Payload],
    shard_len: usize,
    scratch: &mut Scratch,
) -> Vec<f32> {
    let refs: Vec<&Payload> = payloads.iter().collect();
    mean_decoded_refs(repl, ctx, &refs, shard_len, scratch)
}

/// [`mean_decoded`] over borrowed payloads — the straggler-tolerant
/// aggregation path assembles an arbitrary contributing set (the on-time
/// quorum, plus any deltas carried from the previous window under
/// [`LatePolicy::Partial`]) and the **denominator is the contributing
/// count**, not the full group size (the NoLoCo correction: dropping a
/// straggler must not shrink the surviving deltas toward zero). The
/// float chain is identical to [`mean_decoded`] for the same payload
/// sequence, so the full-group case stays bit-for-bit unchanged.
pub fn mean_decoded_refs(
    repl: &dyn Replicator,
    ctx: &ReplCtx,
    payloads: &[&Payload],
    shard_len: usize,
    scratch: &mut Scratch,
) -> Vec<f32> {
    let mut acc = scratch.take_f32_zeroed(shard_len);
    let mut tmp = scratch.take_f32_zeroed(shard_len);
    let pool = scratch.pool.clone();
    for p in payloads {
        tmp.fill(0.0);
        repl.decode(ctx, p, &mut tmp, scratch);
        crate::tensor::axpy_pooled(pool.get(), &mut acc, 1.0, &tmp);
    }
    scratch.put_f32(tmp);
    let inv = 1.0 / payloads.len().max(1) as f32;
    for x in acc.iter_mut() {
        *x *= inv;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(
            ReplSpec::parse("demo:1/8").unwrap(),
            ReplSpec::Demo {
                rate: 0.125,
                chunk: 64,
                sign: true,
                dtype: Dtype::F32,
                packed: false
            }
        );
        assert_eq!(
            ReplSpec::parse("random:1/16:nosign:bf16").unwrap(),
            ReplSpec::Random {
                rate: 1.0 / 16.0,
                sign: false,
                dtype: Dtype::Bf16,
                packed: false
            }
        );
        assert!(matches!(
            ReplSpec::parse("diloco:32").unwrap(),
            ReplSpec::DiLoCo { period: 32, staleness: None, .. }
        ));
        assert!(matches!(
            ReplSpec::parse("diloco:8:async=2").unwrap(),
            ReplSpec::DiLoCo { period: 8, staleness: Some(2), policy: LatePolicy::Wait, .. }
        ));
        // async=S takes an optional late-policy suffix
        assert!(matches!(
            ReplSpec::parse("diloco:8:async=2,drop").unwrap(),
            ReplSpec::DiLoCo { period: 8, staleness: Some(2), policy: LatePolicy::Drop, .. }
        ));
        assert!(matches!(
            ReplSpec::parse("diloco:8:async=1,partial").unwrap(),
            ReplSpec::DiLoCo { staleness: Some(1), policy: LatePolicy::Partial, .. }
        ));
        assert!(matches!(
            ReplSpec::parse("diloco:8:async=1,wait").unwrap(),
            ReplSpec::DiLoCo { policy: LatePolicy::Wait, .. }
        ));
        assert!(ReplSpec::parse("diloco:8:async=1,sometimes").is_err());
        // staleness must stay below the period, and is diloco-only
        assert!(ReplSpec::parse("diloco:4:async=4").is_err());
        assert!(ReplSpec::parse("demo:1/8:async=1").is_err());
        assert!(matches!(
            ReplSpec::parse("full").unwrap(),
            ReplSpec::Full { .. }
        ));
        assert!(matches!(
            ReplSpec::parse("demo:1/8:chunk=128").unwrap(),
            ReplSpec::Demo { chunk: 128, .. }
        ));
        assert!(ReplSpec::parse("bogus:1/2").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(ReplSpec::parse("demo:1/8").unwrap().label(), "demo-1/8");
        assert_eq!(ReplSpec::parse("diloco:16").unwrap().label(), "diloco-1/16");
        assert_eq!(
            ReplSpec::parse("diloco:8:async=2").unwrap().label(),
            "diloco-1/8-async2"
        );
        assert_eq!(
            ReplSpec::parse("diloco:8:async=2,drop").unwrap().label(),
            "diloco-1/8-async2-drop"
        );
        assert_eq!(
            ReplSpec::parse("diloco:8:async=2,partial").unwrap().label(),
            "diloco-1/8-async2-partial"
        );
        assert_eq!(ReplSpec::parse("full").unwrap().label(), "full");
    }

    #[test]
    fn build_for_node_staleness_is_diloco_only_and_bounded() {
        let spec = ReplSpec::parse("diloco:4").unwrap();
        let with = |table: &'static [u64]| ReplBuildCtx {
            staleness: Some(table),
            ..ReplBuildCtx::uniform(8)
        };
        let r = spec.build_for_node(0, &with(&[2])).unwrap();
        assert_eq!(r.sync_delay(), 2);
        let err = spec.build_for_node(0, &with(&[4])).unwrap_err().to_string();
        assert!(err.contains("must be < diloco period"), "{err}");
        let err = ReplSpec::parse("demo:1/8")
            .unwrap()
            .build_for_node(0, &with(&[1]))
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("per-node staleness only applies to the diloco replicator"),
            "{err}"
        );
        // S = 0 builds the async implementation (bit-identical to sync)
        assert_eq!(spec.build_for_node(0, &with(&[0])).unwrap().sync_delay(), 0);
        // a rank beyond the table is a hard error, not a silent default
        assert!(spec.build_for_node(3, &with(&[2, 0])).is_err());
    }

    #[test]
    fn build_for_node_rates_map_ranks_to_nodes() {
        // 2 accels/node: ranks {0,1} read rates[0], ranks {2,3} rates[1].
        let rates: &[f64] = &[1.0 / 32.0, 1.0 / 8.0];
        let ctx = ReplBuildCtx {
            accels: 2,
            rates: Some(rates),
            adaptive: true,
            ..ReplBuildCtx::uniform(128)
        };
        for spec in ["demo:1/16", "random:1/16", "striding:1/16"] {
            let spec = ReplSpec::parse(spec).unwrap();
            let slow = spec.build_for_node(1, &ctx).unwrap();
            let fast = spec.build_for_node(2, &ctx).unwrap();
            assert!(
                slow.rate() < fast.rate(),
                "{}: {} !< {}",
                slow.name(),
                slow.rate(),
                fast.rate()
            );
        }
        // rate tables are meaningless for period/full schemes — loud error
        for spec in ["diloco:4", "full"] {
            let err = ReplSpec::parse(spec)
                .unwrap()
                .build_for_node(0, &ctx)
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("per-node compression rates only apply"),
                "{err}"
            );
        }
        // and a uniform ctx reproduces the spec's own rate
        let uni = ReplSpec::parse("random:1/16")
            .unwrap()
            .build_for_node(0, &ReplBuildCtx::uniform(128))
            .unwrap();
        assert_eq!(uni.rate(), 1.0 / 16.0);
    }

    #[test]
    fn set_rate_retunes_sparse_schemes_and_ignores_structural_ones() {
        let ctx = ReplBuildCtx::uniform(256);
        for spec in ["demo:1/8", "random:1/8", "striding:1/8"] {
            let mut r = ReplSpec::parse(spec).unwrap().build_for_node(0, &ctx).unwrap();
            let before = r.rate();
            assert!(r.set_rate(1.0 / 32.0), "{spec} refused set_rate");
            assert!(r.rate() < before, "{spec}: rate did not drop");
        }
        for spec in ["diloco:4", "full"] {
            let mut r = ReplSpec::parse(spec).unwrap().build_for_node(0, &ctx).unwrap();
            let before = r.rate();
            assert!(!r.set_rate(1.0 / 32.0), "{spec} claimed to retune");
            assert_eq!(r.rate(), before);
        }
    }

    #[test]
    fn mean_decoded_refs_heterogeneous_k_matches_dense_reference() {
        // Satellite: peers running different compression rates (the
        // adaptive controller's steady state, e.g. 1/8 vs 1/32) must
        // average bit-exactly against a dense per-element reference, at
        // every dtype and thread count.
        use crate::parallel::{PoolHandle, WorkerPool};
        use crate::util::proptest::{prop_assert, proptest};
        proptest(6, |g| {
            for dtype in ["f32", "bf16"] {
                for threads in [1usize, 2, 4] {
                    for kind in ["demo", "random", "striding"] {
                        let len = 128 * g.usize(1, 2);
                        let ctx = ReplCtx {
                            step: g.usize(0, 7) as u64,
                            shard: 0,
                            seed: 11,
                        };
                        let mut scratch =
                            Scratch::with_pool(PoolHandle::new(WorkerPool::new(threads)));
                        // Build one encoder per peer at heterogeneous
                        // rates, plus a decoder at the slow rate (decode
                        // must be rate-agnostic: payload-driven).
                        let bctx = |rate: &'static str| {
                            ReplSpec::parse(&format!("{kind}:{rate}:{dtype}"))
                                .unwrap()
                                .build_for_node(
                                    0,
                                    &ReplBuildCtx {
                                        adaptive: true,
                                        ..ReplBuildCtx::uniform(len)
                                    },
                                )
                                .unwrap()
                        };
                        let mut peers = [bctx("1/8"), bctx("1/32")];
                        let decoder = bctx("1/32");
                        let mut payloads = Vec::new();
                        for r in peers.iter_mut() {
                            let mut buf = g.vec_normal(len, 1.0);
                            let (q, p) = r.extract(&ctx, &mut buf, &mut scratch);
                            scratch.put_f32(q);
                            payloads.push(p.expect("every-step scheme must emit"));
                        }
                        let refs: Vec<&Payload> = payloads.iter().collect();
                        let got = mean_decoded_refs(&*decoder, &ctx, &refs, len, &mut scratch);
                        // dense reference: decode each payload alone,
                        // then the same sequential add + 1/n scale
                        let mut want = vec![0.0f32; len];
                        for p in &refs {
                            let mut tmp = vec![0.0f32; len];
                            decoder.decode(&ctx, p, &mut tmp, &mut scratch);
                            for (w, t) in want.iter_mut().zip(&tmp) {
                                *w += *t;
                            }
                        }
                        let inv = 1.0 / refs.len() as f32;
                        for w in want.iter_mut() {
                            *w *= inv;
                        }
                        prop_assert(
                            got == want,
                            format!("{kind}/{dtype}/t{threads}: heterogeneous-k mean diverged"),
                        );
                        scratch.put_f32(got);
                    }
                }
            }
        });
    }

    #[test]
    fn gather_modes_emit_matching_events() {
        use crate::collectives::{Link, naive_all_gather_event, ring_all_reduce_event};
        use crate::net::{LinkClass, NetModel, Topology, TrafficMatrix};
        let link = Link::of(&NetModel::hpc(), LinkClass::InterNode);
        let sizes = [1000u64, 1000, 1000];

        let ev = GatherMode::NaiveAllGather.comm_event(&link, &sizes);
        assert_eq!(ev, naive_all_gather_event(&link, &sizes));
        let ev = GatherMode::RingAllReduce.comm_event(&link, &sizes);
        assert_eq!(ev, ring_all_reduce_event(&link, 3, 1000));

        // traffic: naive is all-to-all of full payloads, ring is
        // neighbor-chunked — the ring moves fewer inter-node bytes at g=3.
        let topo = Topology::new(3, 1);
        let group = [0usize, 1, 2];
        let naive = TrafficMatrix::new(3);
        GatherMode::NaiveAllGather.record_traffic(&naive, &topo, &group, &sizes);
        assert_eq!(naive.inter_node_bytes(), 6 * 1000);
        let ring = TrafficMatrix::new(3);
        GatherMode::RingAllReduce.record_traffic(&ring, &topo, &group, &sizes);
        assert_eq!(ring.inter_node_bytes(), 3 * 4 * (1000 / 3));
        assert!(ring.inter_node_bytes() < naive.inter_node_bytes());
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch_for_all_replicators() {
        // Satellite: a Scratch reused across steps (the trainer's steady
        // state) must produce bit-identical extractions/decodes to a
        // fresh arena per call, for every scheme.
        use crate::util::proptest::{prop_assert, proptest};
        proptest(10, |g| {
            for spec in [
                "demo:1/8",
                "random:1/8",
                "striding:1/8",
                "diloco:2",
                "diloco:4:async=1",
                "full",
            ] {
                let len = 128 * g.usize(1, 3);
                let mut reused = Scratch::new();
                let bctx = ReplBuildCtx::uniform(len);
                let mut ra = ReplSpec::parse(spec).unwrap().build_for_node(0, &bctx).unwrap();
                let mut rb = ReplSpec::parse(spec).unwrap().build_for_node(0, &bctx).unwrap();
                for step in 0..4u64 {
                    let data = g.vec_normal(len, 1.0);
                    let ctx = ReplCtx {
                        step,
                        shard: 0,
                        seed: 9,
                    };
                    let mut buf_a = data.clone();
                    let mut buf_b = data;
                    let (qa, pa) = ra.extract(&ctx, &mut buf_a, &mut reused);
                    let (qb, pb) = rb.extract(&ctx, &mut buf_b, &mut Scratch::new());
                    prop_assert(qa == qb, format!("{spec} step {step}: q diverged"));
                    prop_assert(buf_a == buf_b, format!("{spec} step {step}: residual"));
                    match (&pa, &pb) {
                        (Some(a), Some(b)) => {
                            prop_assert(
                                a.values == b.values && a.indices == b.indices,
                                format!("{spec} step {step}: payload diverged"),
                            );
                            let mut da = vec![0.0f32; len];
                            let mut db = vec![0.0f32; len];
                            ra.decode(&ctx, a, &mut da, &mut reused);
                            rb.decode(&ctx, b, &mut db, &mut Scratch::new());
                            prop_assert(da == db, format!("{spec} step {step}: decode"));
                        }
                        (None, None) => {}
                        _ => prop_assert(false, format!("{spec} step {step}: sync split")),
                    }
                    if let Some(p) = pa {
                        reused.recycle_payload(p);
                    }
                    reused.put_f32(qa);
                }
            }
        });
    }

    #[test]
    fn shared_rng_agrees_across_ctx_copies() {
        let a = ReplCtx {
            step: 7,
            shard: 3,
            seed: 42,
        };
        let b = a;
        assert_eq!(a.shared_rng().next_u64(), b.shared_rng().next_u64());
        // and differs across steps/shards
        let c = ReplCtx { step: 8, ..a };
        assert_ne!(a.shared_rng().next_u64(), c.shared_rng().next_u64());
        let d = ReplCtx { shard: 4, ..a };
        assert_ne!(a.shared_rng().next_u64(), d.shared_rng().next_u64());
    }
}
