//! Closed-loop bandwidth-adaptive compression control (`--compress-control`).
//!
//! DeMo fixes one global top-k rate for the whole run; the DeToNATION
//! paper challenges exactly that choice, and on a heterogeneous cluster
//! it is untenable — a 100 Mbps node should ship 1/32 of its momentum
//! while 1 Gbps peers ship 1/8. The [`RateController`] closes the loop:
//! once per `--control-window` sync windows it reads each node's NIC
//! busy fraction (from the engine's `net::Timeline` occupancy taps) and
//! the run's exposed-comm ratio, and retunes that node's
//! DeMo/Random/Striding rate via AIMD — **a**dditive **i**ncrease while
//! the NIC has headroom, **m**ultiplicative **d**ecrease while it is
//! saturated *and* communication is actually exposed (a busy NIC whose
//! transfers hide behind compute costs nothing and is left alone).
//! Rates stay inside `[--rate-min, --rate-max]`; the fixed point is
//! water-filling — congested nodes back off until they leave the
//! critical path, unconstrained nodes rise to the cap.
//!
//! `--compress-control off` (and the flag absent) never constructs a
//! controller: builds are uniform, no `sel` hints ride the wire, and
//! the run is bit-identical to the fixed-rate trainer (prop-tested in
//! `tests/integration.rs`).

/// Parse a compression rate written either as `1/N` or as a bare float
/// (`0.125`). Shared by the controller spec and the `--rate-min` /
/// `--rate-max` CLI knobs.
pub fn parse_rate(s: &str) -> anyhow::Result<f64> {
    let r = match s.strip_prefix("1/") {
        Some(den) => 1.0 / den.parse::<f64>()?,
        None => s.parse::<f64>()?,
    };
    anyhow::ensure!(
        r.is_finite() && r > 0.0 && r <= 1.0,
        "rate {s:?} must land in (0, 1]"
    );
    Ok(r)
}

/// AIMD tuning knobs (the `aimd:key=val` spec components).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AimdParams {
    /// Additive step per window while the NIC has headroom (`add=1/64`).
    pub add: f64,
    /// Multiplicative factor on congestion (`mul=0.5`), in (0, 1).
    pub mul: f64,
    /// NIC busy fraction above which a node counts as congested (`hi=`).
    pub hi: f64,
    /// NIC busy fraction below which a node has headroom (`lo=`).
    pub lo: f64,
    /// Exposed-comm ratio (exposed seconds / window sim seconds) below
    /// which congestion is ignored — hidden communication is free
    /// (`exposed=`).
    pub exposed: f64,
}

impl Default for AimdParams {
    fn default() -> AimdParams {
        AimdParams {
            add: 1.0 / 64.0,
            mul: 0.5,
            hi: 0.75,
            lo: 0.5,
            exposed: 0.02,
        }
    }
}

/// `--compress-control` surface: `off` (bit-frozen default) or
/// `aimd[:add=1/64][:mul=0.5][:hi=0.75][:lo=0.5][:exposed=0.02]`.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ControlSpec {
    #[default]
    Off,
    Aimd(AimdParams),
}

impl ControlSpec {
    pub fn parse(s: &str) -> anyhow::Result<ControlSpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        match kind {
            "off" => {
                anyhow::ensure!(
                    parts.next().is_none(),
                    "compress-control off takes no parameters (got {s:?})"
                );
                Ok(ControlSpec::Off)
            }
            "aimd" => {
                let mut p = AimdParams::default();
                for part in parts {
                    let (k, v) = part.split_once('=').ok_or_else(|| {
                        anyhow::anyhow!("bad aimd component {part:?} (want key=value)")
                    })?;
                    match k {
                        "add" => p.add = parse_rate(v)?,
                        "mul" => p.mul = v.parse()?,
                        "hi" => p.hi = v.parse()?,
                        "lo" => p.lo = v.parse()?,
                        "exposed" => p.exposed = v.parse()?,
                        other => anyhow::bail!(
                            "unknown aimd parameter {other:?} (add|mul|hi|lo|exposed)"
                        ),
                    }
                }
                anyhow::ensure!(
                    p.mul > 0.0 && p.mul < 1.0,
                    "aimd mul {} must be in (0, 1)",
                    p.mul
                );
                anyhow::ensure!(
                    0.0 <= p.lo && p.lo < p.hi && p.hi <= 1.0,
                    "aimd thresholds need 0 <= lo < hi <= 1 (lo={}, hi={})",
                    p.lo,
                    p.hi
                );
                anyhow::ensure!(
                    p.exposed >= 0.0 && p.exposed.is_finite(),
                    "aimd exposed threshold {} must be finite and >= 0",
                    p.exposed
                );
                Ok(ControlSpec::Aimd(p))
            }
            other => anyhow::bail!("unknown compress-control {other:?} (off|aimd[:key=val...])"),
        }
    }

    pub fn is_armed(&self) -> bool {
        !matches!(self, ControlSpec::Off)
    }

    pub fn label(&self) -> &'static str {
        match self {
            ControlSpec::Off => "off",
            ControlSpec::Aimd(_) => "aimd",
        }
    }
}

/// The controller's serializable snapshot (checkpoint v4): rates plus
/// the in-window measurement baselines, so a rejoining node resumes the
/// loop mid-window bit-identically.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ControlState {
    pub rates: Vec<f64>,
    pub exposed_acc: f64,
    pub sim0: f64,
    pub busy0: Vec<f64>,
}

/// Per-node AIMD rate loop. The trainer owns one (when
/// `--compress-control aimd`), calls [`RateController::note_step`] every
/// step with that step's exposed-comm seconds, and every
/// `--control-window` steps hands it the cumulative per-node NIC busy
/// seconds + the sim clock; [`RateController::retune`] turns the window
/// deltas into occupancy fractions and nudges each node's rate.
#[derive(Clone, Debug)]
pub struct RateController {
    params: AimdParams,
    rate_min: f64,
    rate_max: f64,
    rates: Vec<f64>,
    exposed_acc: f64,
    sim0: f64,
    busy0: Vec<f64>,
}

impl RateController {
    /// `nodes` control loops seeded at `init_rate` (the spec's uniform
    /// rate), clamped into `[rate_min, rate_max]`.
    pub fn new(
        params: AimdParams,
        rate_min: f64,
        rate_max: f64,
        nodes: usize,
        init_rate: f64,
    ) -> anyhow::Result<RateController> {
        anyhow::ensure!(
            0.0 < rate_min && rate_min <= rate_max && rate_max <= 1.0,
            "need 0 < rate-min <= rate-max <= 1 (got {rate_min} / {rate_max})"
        );
        Ok(RateController {
            params,
            rate_min,
            rate_max,
            rates: vec![init_rate.clamp(rate_min, rate_max); nodes.max(1)],
            exposed_acc: 0.0,
            sim0: 0.0,
            busy0: vec![0.0; nodes.max(1)],
        })
    }

    /// Current per-node rates (indexed by node).
    pub fn rates(&self) -> &[f64] {
        &self.rates
    }

    /// Accumulate one step's exposed-communication seconds.
    pub fn note_step(&mut self, exposed_s: f64) {
        self.exposed_acc += exposed_s.max(0.0);
    }

    /// Close the window: `busy[n]` is node n's *cumulative* NIC busy
    /// seconds, `now` the sim clock. Returns `true` if any rate moved
    /// (the trainer then pushes rates into the replicators via
    /// [`super::Replicator::set_rate`]).
    pub fn retune(&mut self, busy: &[f64], now: f64) -> bool {
        let dt = now - self.sim0;
        if dt <= 0.0 {
            return false;
        }
        let exposed_ratio = self.exposed_acc / dt;
        let mut moved = false;
        for (n, rate) in self.rates.iter_mut().enumerate() {
            let busy_frac = ((busy.get(n).copied().unwrap_or(0.0)
                - self.busy0.get(n).copied().unwrap_or(0.0))
                / dt)
                .clamp(0.0, 1.0);
            let next = if busy_frac > self.params.hi && exposed_ratio > self.params.exposed {
                *rate * self.params.mul
            } else if busy_frac < self.params.lo {
                *rate + self.params.add
            } else {
                *rate
            }
            .clamp(self.rate_min, self.rate_max);
            if next != *rate {
                *rate = next;
                moved = true;
            }
        }
        self.exposed_acc = 0.0;
        self.sim0 = now;
        self.busy0.clear();
        self.busy0.extend_from_slice(busy);
        self.busy0.resize(self.rates.len(), 0.0);
        moved
    }

    /// Per-node rates as a `;`-joined metrics label (the steps-CSV
    /// `rate` column), e.g. `0.1250;0.0312`.
    pub fn label(&self) -> String {
        self.rates
            .iter()
            .map(|r| format!("{r:.4}"))
            .collect::<Vec<_>>()
            .join(";")
    }

    pub fn export_state(&self) -> ControlState {
        ControlState {
            rates: self.rates.clone(),
            exposed_acc: self.exposed_acc,
            sim0: self.sim0,
            busy0: self.busy0.clone(),
        }
    }

    pub fn import_state(&mut self, st: ControlState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.rates.len() == self.rates.len() && st.busy0.len() == self.busy0.len(),
            "controller snapshot is for {} nodes, this run has {}",
            st.rates.len(),
            self.rates.len()
        );
        self.rates = st.rates;
        self.exposed_acc = st.exposed_acc;
        self.sim0 = st.sim0;
        self.busy0 = st.busy0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aimd(spec: &str) -> AimdParams {
        match ControlSpec::parse(spec).unwrap() {
            ControlSpec::Aimd(p) => p,
            ControlSpec::Off => panic!("expected aimd"),
        }
    }

    #[test]
    fn parse_specs_and_errors() {
        assert_eq!(ControlSpec::parse("off").unwrap(), ControlSpec::Off);
        assert!(!ControlSpec::parse("off").unwrap().is_armed());
        assert_eq!(aimd("aimd"), AimdParams::default());
        let p = aimd("aimd:add=1/32:mul=0.7:hi=0.8:lo=0.3:exposed=0.05");
        assert_eq!(p.add, 1.0 / 32.0);
        assert_eq!(p.mul, 0.7);
        assert_eq!(p.hi, 0.8);
        assert_eq!(p.lo, 0.3);
        assert_eq!(p.exposed, 0.05);
        assert!(ControlSpec::parse("aimd").unwrap().is_armed());
        assert_eq!(ControlSpec::parse("aimd").unwrap().label(), "aimd");
        assert_eq!(ControlSpec::parse("off").unwrap().label(), "off");
        // loud errors, each naming the offending piece
        for bad in [
            "pid",
            "off:x=1",
            "aimd:mul=1.5",
            "aimd:mul=0",
            "aimd:lo=0.9:hi=0.8",
            "aimd:bogus=1",
            "aimd:add",
            "aimd:add=0",
            "aimd:exposed=-1",
        ] {
            assert!(ControlSpec::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn parse_rate_forms() {
        assert_eq!(parse_rate("1/8").unwrap(), 0.125);
        assert_eq!(parse_rate("0.25").unwrap(), 0.25);
        assert!(parse_rate("0").is_err());
        assert!(parse_rate("2.0").is_err());
        assert!(parse_rate("1/0").is_err());
        assert!(parse_rate("x").is_err());
    }

    fn ctl(nodes: usize) -> RateController {
        RateController::new(AimdParams::default(), 1.0 / 64.0, 0.25, nodes, 1.0 / 8.0).unwrap()
    }

    #[test]
    fn congested_node_backs_off_only_when_comm_is_exposed() {
        let mut c = ctl(2);
        // node 0 saturated, node 1 in the dead band; comm is exposed
        c.note_step(0.5);
        assert!(c.retune(&[0.9, 0.6], 1.0));
        assert_eq!(c.rates()[0], 0.125 * 0.5);
        assert_eq!(c.rates()[1], 0.125);
        // same occupancy but comm fully hidden: congestion is free, hold
        let mut c = ctl(2);
        assert!(!c.retune(&[0.9, 0.6], 1.0));
        assert_eq!(c.rates(), &[0.125, 0.125]);
    }

    #[test]
    fn idle_node_rises_additively_to_the_cap() {
        let mut c = ctl(1);
        let mut prev = c.rates()[0];
        for w in 1..=20u32 {
            c.retune(&[0.0], w as f64);
            let r = c.rates()[0];
            assert!(r >= prev, "window {w}: rate fell with headroom");
            assert!(r <= 0.25, "window {w}: cap breached");
            prev = r;
        }
        assert_eq!(prev, 0.25, "never reached rate-max");
    }

    #[test]
    fn floor_and_window_deltas_are_respected() {
        let mut c = ctl(1);
        // repeated congestion pins at the floor, never below
        for w in 1..=20u32 {
            c.note_step(1.0);
            c.retune(&[w as f64 * 0.95], w as f64);
        }
        assert_eq!(c.rates()[0], 1.0 / 64.0);
        // busy is *cumulative*: a node busy in window 1 but idle in
        // window 2 must read as idle in window 2 (delta, not total)
        let mut c = ctl(1);
        c.note_step(0.5);
        c.retune(&[0.9], 1.0); // decrease
        let after_congestion = c.rates()[0];
        c.retune(&[0.9], 2.0); // same cumulative busy => idle window
        assert!(c.rates()[0] > after_congestion, "window delta ignored");
        // zero-length window is a no-op
        assert!(!c.retune(&[0.9], 2.0));
    }

    #[test]
    fn water_filling_on_a_mixed_cluster_converges() {
        // Toy closed loop: node 0's NIC takes 4x as long per shipped
        // byte as its three peers (a 4x mixed-NIC profile). Model each
        // window's busy fraction as rate-proportional and iterate; the
        // slow node must settle strictly below the fast ones, everyone
        // inside the band.
        let mut c = ctl(4);
        let mut cum = [0.0f64; 4];
        for w in 1..=40u32 {
            let r = c.rates().to_vec();
            for (n, b) in cum.iter_mut().enumerate() {
                let per_byte = if n == 0 { 4.0 } else { 1.0 };
                *b += (r[n] * 8.0 * per_byte).min(1.0);
            }
            c.note_step(0.2);
            c.retune(&cum, w as f64);
        }
        let r = c.rates();
        assert!(
            r[0] < r[1] && r[0] < r[2] && r[0] < r[3],
            "slow node not below fast peers: {r:?}"
        );
        for (n, &x) in r.iter().enumerate() {
            assert!((1.0 / 64.0..=0.25).contains(&x), "node {n} out of band");
        }
        assert_eq!(c.label().split(';').count(), 4);
    }

    #[test]
    fn state_roundtrip_resumes_mid_window() {
        let mut a = ctl(3);
        a.note_step(0.3);
        a.retune(&[0.9, 0.1, 0.6], 1.0);
        a.note_step(0.7);
        let st = a.export_state();
        let mut b = ctl(3);
        b.import_state(st.clone()).unwrap();
        assert_eq!(a.export_state(), b.export_state());
        // identical future behaviour
        assert_eq!(a.retune(&[1.8, 0.2, 1.2], 2.0), b.retune(&[1.8, 0.2, 1.2], 2.0));
        assert_eq!(a.rates(), b.rates());
        // wrong-geometry snapshots are refused
        let mut wrong = ctl(2);
        assert!(wrong.import_state(st).is_err());
    }

    #[test]
    fn controller_bounds_are_validated() {
        assert!(RateController::new(AimdParams::default(), 0.0, 0.5, 2, 0.1).is_err());
        assert!(RateController::new(AimdParams::default(), 0.5, 0.25, 2, 0.1).is_err());
        assert!(RateController::new(AimdParams::default(), 0.1, 2.0, 2, 0.1).is_err());
        // init rate outside the band is clamped in, not rejected
        let c = RateController::new(AimdParams::default(), 0.1, 0.2, 2, 0.5).unwrap();
        assert_eq!(c.rates(), &[0.2, 0.2]);
    }
}
