//! Random replication (introduced by DeToNATION): a seeded random subset
//! of buffer components is exchanged each step.
//!
//! The index set is regenerated from `(seed, step, shard)` on every rank
//! (see [`ReplCtx::shared_rng`]) so **no indices cross the wire** — at the
//! same component count Random ships half of DeMo's f32 bytes ("enabling
//! us to share double the amount of data, on the same bandwidth").
//! The paper finds this scheme superior for encoder-decoder translation
//! (Figs 1, 2a) and competitive-but-worse for ViT/causal-LM (Figs 2b, 3).

use super::{ReplCtx, Replicator};
use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

#[derive(Debug)]
pub struct RandomReplicator {
    pub rate: f64,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
}

impl RandomReplicator {
    pub fn new(rate: f64, sign: bool, dtype: Dtype) -> RandomReplicator {
        assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");
        RandomReplicator {
            rate,
            sign,
            dtype,
            is_packed: false,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }


    /// The deterministic per-(step, shard) index set: every rank of the
    /// R-group computes the identical set.
    pub fn indices(&self, ctx: &ReplCtx, len: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.indices_into(ctx, len, &mut out);
        out
    }

    /// [`RandomReplicator::indices`] into a reusable buffer.
    pub fn indices_into(&self, ctx: &ReplCtx, len: usize, out: &mut Vec<usize>) {
        Self::indices_into_k(ctx, len, Self::k_for(self.rate, len), out);
    }

    fn k_for(rate: f64, len: usize) -> usize {
        ((len as f64 * rate).round() as usize).clamp(1, len)
    }

    /// The shared index set at an explicit component count: the same
    /// `(seed, step, shard, len, k)` always yields the same set, so a
    /// decoder regenerates *any* peer's selection from its payload's
    /// value count — heterogeneous rates decode without shipping
    /// indices, and at uniform rates this is exactly the encoder's own
    /// call (bit-identical to the fixed-rate path).
    fn indices_into_k(ctx: &ReplCtx, len: usize, k: usize, out: &mut Vec<usize>) {
        ctx.shared_rng().sample_indices_into(len, k.clamp(1, len), out);
    }
}

impl Replicator for RandomReplicator {
    fn name(&self) -> String {
        format!(
            "random-1/{:.0}{}",
            1.0 / self.rate,
            if self.sign { "-sign" } else { "" }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        self.indices_into(ctx, buf.len(), &mut scratch.idx);
        let mut values = scratch.take_f32();
        values.extend(scratch.idx.iter().map(|&i| buf[i]));
        for &i in &scratch.idx {
            buf[i] = 0.0; // residual: selected components leave the buffer
        }
        let payload = self.mk_payload(None, values);
        let mut q_local = scratch.take_f32_zeroed(buf.len());
        self.decode(ctx, &payload, &mut q_local, scratch);
        (q_local, Some(payload))
    }

    fn decode(&self, ctx: &ReplCtx, payload: &Payload, out: &mut [f32], scratch: &mut Scratch) {
        // k comes from the payload, not this instance's rate: a peer may
        // run a different controller-tuned rate and its selection is
        // still recoverable (same shared stream, its value count).
        Self::indices_into_k(ctx, out.len(), payload.values.len(), &mut scratch.idx);
        debug_assert_eq!(scratch.idx.len(), payload.values.len());
        for (&i, &v) in scratch.idx.iter().zip(&payload.values) {
            out[i] = v;
        }
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn set_rate(&mut self, rate: f64) -> bool {
        assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");
        self.rate = rate;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, proptest};
    use crate::util::rng::Rng;

    fn ctx(step: u64) -> ReplCtx {
        ReplCtx {
            step,
            shard: 2,
            seed: 99,
        }
    }

    #[test]
    fn indices_identical_across_ranks_differ_across_steps() {
        let r = RandomReplicator::new(1.0 / 16.0, true, Dtype::F32);
        // "Two ranks" = two independent calls with the same ctx.
        let a = r.indices(&ctx(5), 4096);
        let b = r.indices(&ctx(5), 4096);
        assert_eq!(a, b);
        let c = r.indices(&ctx(6), 4096);
        assert_ne!(a, c);
    }

    #[test]
    fn extract_zeroes_selected_keeps_rest() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..1024).map(|_| rng.normal_f32(1.0) + 3.0).collect();
        let mut buf = orig.clone();
        let mut r = RandomReplicator::new(1.0 / 8.0, false, Dtype::F32);
        let c = ctx(0);
        let (q, p) = r.extract(&c, &mut buf, &mut Scratch::new());
        let idx = r.indices(&c, 1024);
        assert_eq!(idx.len(), 128);
        for i in 0..1024 {
            if idx.contains(&i) {
                assert_eq!(buf[i], 0.0);
                assert_eq!(q[i], orig[i]);
            } else {
                assert_eq!(buf[i], orig[i]);
                assert_eq!(q[i], 0.0);
            }
        }
        assert!(p.unwrap().indices.is_none(), "random ships no indices");
    }

    #[test]
    fn roundtrip_extract_decode_property() {
        proptest(32, |g| {
            let len = g.usize(8, 2000);
            let rate = 1.0 / g.pow2(0, 5) as f64;
            let sign = g.bool();
            let orig = g.vec_normal(len, 1.0);
            let mut buf = orig.clone();
            let mut r = RandomReplicator::new(rate, sign, Dtype::F32);
            let c = ReplCtx {
                step: g.u64() % 1000,
                shard: g.usize(0, 8),
                seed: 7,
            };
            let mut s = Scratch::new();
            let (q, p) = r.extract(&c, &mut buf, &mut s);
            let mut out = vec![0.0f32; len];
            r.decode(&c, &p.unwrap(), &mut out, &mut s);
            prop_assert(out == q, "decode must equal local q");
            // residual + q == original when unsigned
            if !sign {
                for i in 0..len {
                    prop_assert(
                        (buf[i] + q[i] - orig[i]).abs() < 1e-6,
                        format!("i={i}"),
                    );
                }
            }
        });
    }

    #[test]
    fn signed_values_are_ternary() {
        let mut rng = Rng::new(2);
        let mut buf: Vec<f32> = (0..512).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = RandomReplicator::new(1.0 / 4.0, true, Dtype::F32);
        let (_, p) = r.extract(&ctx(3), &mut buf, &mut Scratch::new());
        assert!(p
            .unwrap()
            .values
            .iter()
            .all(|&v| v == 1.0 || v == -1.0 || v == 0.0));
    }

    #[test]
    fn decode_is_rate_agnostic_for_heterogeneous_peers() {
        // A peer tuned to 1/32 by the controller ships fewer values; any
        // decoder instance (whatever its own rate) must regenerate that
        // peer's exact selection from the value count alone.
        let mut rng = Rng::new(4);
        let orig: Vec<f32> = (0..2048).map(|_| rng.normal_f32(1.0)).collect();
        let mut buf = orig.clone();
        let mut slow = RandomReplicator::new(1.0 / 32.0, false, Dtype::F32);
        let c = ctx(6);
        let mut s = Scratch::new();
        let (q, p) = slow.extract(&c, &mut buf, &mut s);
        let p = p.unwrap();
        assert_eq!(p.values.len(), 64);
        let fast = RandomReplicator::new(1.0 / 8.0, false, Dtype::F32);
        let mut via_fast = vec![0.0f32; 2048];
        fast.decode(&c, &p, &mut via_fast, &mut s);
        assert_eq!(via_fast, q, "decoder rate leaked into the selection");
        // retuning an instance mid-run changes its *next* extraction only
        assert!(slow.set_rate(1.0 / 8.0));
        let mut buf2 = orig.clone();
        let (_, p2) = slow.extract(&c, &mut buf2, &mut s);
        assert_eq!(p2.unwrap().values.len(), 256);
    }

    #[test]
    fn wire_bytes_half_of_demo_at_same_count() {
        // 128 components: random = 128·4 B; demo would be 128·(4+4) B.
        let mut rng = Rng::new(3);
        let mut buf: Vec<f32> = (0..1024).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = RandomReplicator::new(1.0 / 8.0, false, Dtype::F32);
        let (_, p) = r.extract(&ctx(0), &mut buf, &mut Scratch::new());
        assert_eq!(p.unwrap().wire_bytes(), 128 * 4);
    }
}
