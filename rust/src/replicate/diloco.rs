//! DiLoCo replication (Douillard et al. 2023, recast as a DeToNATION
//! replication scheme): workers step locally and synchronize after every
//! `period`-th optimization step (steps are 0-indexed internally, so the
//! sync fires on steps `period − 1, 2·period − 1, …` — see
//! [`DiLoCoReplicator::is_sync_step`] for the pinned convention).
//!
//! Mechanics here follow the federated-averaging identity: a worker that
//! applied local updates δ_i since the last sync can jump onto the
//! averaged trajectory by applying `mean_j(δ_j) − δ_i` at the sync point.
//! The replicator therefore
//! * on non-sync steps: extracts the whole buffer as a *local* update
//!   (no payload) and accumulates it into `delta_acc`;
//! * on sync steps: ships `delta_acc + q_t` and finalizes with
//!   `mean − delta_acc_own` so every rank lands on the average trajectory
//!   (exact for unsigned f32; approximate under sign/dtype quantization,
//!   which the paper also applies).
//!
//! Average bandwidth = full buffer / period → "compression rate" 1/period.
//!
//! ## Async DiLoCo ([`AsyncDiLoCoReplicator`])
//!
//! The synchronous scheme blocks every rank at the periodic gather. The
//! async variant instead *launches* the gather on a sync step and keeps
//! taking local steps while it is in flight; the averaged delta lands
//! `S` steps later (`--staleness S`, `0 ≤ S < period`). The
//! federated-averaging correction is computed against the **snapshot of
//! the accumulator at launch** — deltas accumulated while the gather was
//! in flight belong to the *next* window's payload and survive the
//! arrival, so each rank lands on `θ_base + mean_j(δ_j) + d_i` where
//! `d_i` is its own since-launch displacement. With `S = 0` the launch
//! and arrival coincide and the update chain is bit-identical to
//! [`DiLoCoReplicator`] (prop-tested here and in the integration suite).

use super::{ReplCtx, ReplState, Replicator};
use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

pub struct DiLoCoReplicator {
    pub period: u64,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
    /// Sum of locally-applied updates since the last synchronization.
    delta_acc: Vec<f32>,
}

impl DiLoCoReplicator {
    pub fn new(period: u64, sign: bool, dtype: Dtype, shard_len: usize) -> DiLoCoReplicator {
        assert!(period >= 1);
        DiLoCoReplicator {
            period,
            sign,
            dtype,
            is_packed: false,
            delta_acc: vec![0.0; shard_len],
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }


    /// Whether `step` replicates. The sync fires after every
    /// `period`-th optimization step *counting from 1*: steps are
    /// 0-indexed, so the first window covers steps `0..period` and syncs
    /// on step `period − 1` (the convention `(step + 1) % period == 0`
    /// pins — every rank of an R-group must agree on it bit-for-bit).
    ///
    /// ```
    /// use detonation::replicate::DiLoCoReplicator;
    /// use detonation::tensor::Dtype;
    /// let r = DiLoCoReplicator::new(4, false, Dtype::F32, 8);
    /// let syncs: Vec<u64> = (0..12).filter(|&s| r.is_sync_step(s)).collect();
    /// assert_eq!(syncs, vec![3, 7, 11]);
    /// assert!(DiLoCoReplicator::new(1, false, Dtype::F32, 8).is_sync_step(0));
    /// ```
    pub fn is_sync_step(&self, step: u64) -> bool {
        (step + 1) % self.period == 0
    }
}

impl Replicator for DiLoCoReplicator {
    fn name(&self) -> String {
        format!(
            "diloco-n{}{}",
            self.period,
            if self.sign { "-sign" } else { "" }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        assert_eq!(buf.len(), self.delta_acc.len());
        // Local step: the whole buffer becomes this step's update.
        let mut q_local = scratch.take_f32();
        q_local.extend_from_slice(buf);
        buf.fill(0.0);
        crate::tensor::axpy(&mut self.delta_acc, 1.0, &q_local);
        if self.is_sync_step(ctx.step) {
            let mut values = scratch.take_f32();
            values.extend_from_slice(&self.delta_acc);
            let payload = self.mk_payload(None, values);
            (q_local, Some(payload))
        } else {
            (q_local, None)
        }
    }

    fn decode(&self, _ctx: &ReplCtx, payload: &Payload, out: &mut [f32], _scratch: &mut Scratch) {
        out.copy_from_slice(&payload.values);
    }

    fn finalize(
        &mut self,
        ctx: &ReplCtx,
        q_local: Vec<f32>,
        mean: Option<Vec<f32>>,
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        match mean {
            None => q_local, // local-only step
            Some(mean_delta) => {
                // Jump from the local trajectory onto the averaged one:
                //   θ has already absorbed (delta_acc − q_local); applying
                //   q_final = mean(δ) − delta_acc + q_local lands θ on
                //   θ_start − η·mean(δ) (for the SGD-style apply θ−=η·q).
                debug_assert!(self.is_sync_step(ctx.step));
                let mut q = mean_delta;
                crate::tensor::axpy(&mut q, -1.0, &self.delta_acc);
                crate::tensor::axpy(&mut q, 1.0, &q_local);
                self.delta_acc.fill(0.0);
                scratch.put_f32(q_local);
                q
            }
        }
    }

    fn rate(&self) -> f64 {
        1.0 / self.period as f64
    }

    fn export_state(&self) -> ReplState {
        ReplState {
            delta_acc: self.delta_acc.clone(),
            in_flight: None,
        }
    }

    fn import_state(&mut self, st: ReplState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.delta_acc.len() == self.delta_acc.len(),
            "diloco snapshot accumulator has {} elements, shard has {}",
            st.delta_acc.len(),
            self.delta_acc.len()
        );
        anyhow::ensure!(
            st.in_flight.is_none(),
            "synchronous diloco cannot restore an in-flight gather \
             (snapshot was taken on the async variant)"
        );
        self.delta_acc = st.delta_acc;
        Ok(())
    }
}

/// Async DiLoCo: the periodic sync gather is *launched* on the sync step
/// and its averaged delta is applied `staleness` steps later, while local
/// optimization keeps running (see the module docs for the exact
/// federated-averaging correction).
///
/// Protocol differences from [`DiLoCoReplicator`]:
/// * [`Replicator::extract`] on a sync step additionally **snapshots**
///   the shipped accumulator and opens the next window immediately —
///   deltas taken while the gather is in flight feed the next payload;
/// * [`Replicator::sync_delay`] returns `staleness`, telling the trainer
///   to park the gathered payloads and hand the mean to
///   [`Replicator::finalize`] on step `launch + staleness`;
/// * [`Replicator::finalize`] with a mean corrects against the launch
///   snapshot (not the live accumulator), so since-launch local progress
///   is preserved.
///
/// `staleness` must satisfy `staleness < period` so at most one gather is
/// in flight per shard (enforced at construction). `staleness == 0`
/// reproduces the synchronous scheme bit-for-bit.
pub struct AsyncDiLoCoReplicator {
    inner: DiLoCoReplicator,
    staleness: u64,
    /// Snapshot of the accumulator shipped by the in-flight gather
    /// (Some between the launch step and the arrival step).
    in_flight: Option<Vec<f32>>,
}

impl AsyncDiLoCoReplicator {
    pub fn new(
        period: u64,
        sign: bool,
        dtype: Dtype,
        shard_len: usize,
        staleness: u64,
    ) -> AsyncDiLoCoReplicator {
        assert!(
            staleness < period,
            "staleness {staleness} must be < period {period} (one gather in flight at a time)"
        );
        AsyncDiLoCoReplicator {
            inner: DiLoCoReplicator::new(period, sign, dtype, shard_len),
            staleness,
            in_flight: None,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.inner = self.inner.packed(packed);
        self
    }

    /// Whether a launched gather has not yet been applied.
    pub fn sync_in_flight(&self) -> bool {
        self.in_flight.is_some()
    }
}

impl Replicator for AsyncDiLoCoReplicator {
    fn name(&self) -> String {
        format!("async-{}-s{}", self.inner.name(), self.staleness)
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        assert_eq!(buf.len(), self.inner.delta_acc.len());
        let mut q_local = scratch.take_f32();
        q_local.extend_from_slice(buf);
        buf.fill(0.0);
        crate::tensor::axpy(&mut self.inner.delta_acc, 1.0, &q_local);
        if self.inner.is_sync_step(ctx.step) {
            assert!(
                self.in_flight.is_none(),
                "step {}: previous gather still in flight (staleness must be < period)",
                ctx.step
            );
            let mut values = scratch.take_f32();
            values.extend_from_slice(&self.inner.delta_acc);
            // Snapshot the shipped window and open the next one: the
            // arrival correction subtracts this snapshot, while deltas
            // accumulated in flight stay in `delta_acc` for the next
            // payload.
            let mut snap = scratch.take_f32();
            snap.extend_from_slice(&self.inner.delta_acc);
            self.in_flight = Some(snap);
            self.inner.delta_acc.fill(0.0);
            let payload = self.inner.mk_payload(None, values);
            (q_local, Some(payload))
        } else {
            (q_local, None)
        }
    }

    fn decode(&self, ctx: &ReplCtx, payload: &Payload, out: &mut [f32], scratch: &mut Scratch) {
        self.inner.decode(ctx, payload, out, scratch);
    }

    fn finalize(
        &mut self,
        _ctx: &ReplCtx,
        q_local: Vec<f32>,
        mean: Option<Vec<f32>>,
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        match mean {
            None => q_local, // local step (launch step included)
            Some(mean_delta) => {
                // Arrival: jump onto the averaged trajectory while
                // keeping since-launch local progress — the same float
                // chain as the synchronous finalize, against the launch
                // snapshot instead of the live accumulator.
                //
                // The finalize is quorum-agnostic by construction: `mean`
                // may average any contributing set (the full group, or a
                // NoLoCo-style on-time quorum under `--late-policy drop` /
                // `partial`, assembled via `mean_decoded_refs` with the
                // denominator corrected to the contributing count). The
                // correction only ever subtracts this rank's own launch
                // snapshot, and a rank's own payload is always in its
                // quorum (it never crosses the wire), so the identity
                // `θ_base + mean(contributing δ) + d_own` holds for every
                // quorum shape.
                let snap = self
                    .in_flight
                    .take()
                    .expect("arrival without a launched gather");
                let mut q = mean_delta;
                crate::tensor::axpy(&mut q, -1.0, &snap);
                crate::tensor::axpy(&mut q, 1.0, &q_local);
                scratch.put_f32(snap);
                scratch.put_f32(q_local);
                q
            }
        }
    }

    fn rate(&self) -> f64 {
        self.inner.rate()
    }

    fn sync_delay(&self) -> u64 {
        self.staleness
    }

    fn export_state(&self) -> ReplState {
        ReplState {
            delta_acc: self.inner.delta_acc.clone(),
            in_flight: self.in_flight.clone(),
        }
    }

    fn import_state(&mut self, st: ReplState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.delta_acc.len() == self.inner.delta_acc.len(),
            "async-diloco snapshot accumulator has {} elements, shard has {}",
            st.delta_acc.len(),
            self.inner.delta_acc.len()
        );
        if let Some(snap) = &st.in_flight {
            anyhow::ensure!(
                snap.len() == self.inner.delta_acc.len(),
                "async-diloco in-flight snapshot has {} elements, shard has {}",
                snap.len(),
                self.inner.delta_acc.len()
            );
        }
        self.inner.delta_acc = st.delta_acc;
        self.in_flight = st.in_flight;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replicate::mean_decoded;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};

    fn ctx(step: u64) -> ReplCtx {
        ReplCtx {
            step,
            shard: 0,
            seed: 3,
        }
    }

    #[test]
    fn syncs_exactly_every_period() {
        let mut r = DiLoCoReplicator::new(4, false, Dtype::F32, 8);
        let mut s = Scratch::new();
        let mut synced = Vec::new();
        for step in 0..12 {
            let mut buf = vec![1.0f32; 8];
            let (_, p) = r.extract(&ctx(step), &mut buf, &mut s);
            if let Some(p) = p {
                synced.push(step);
                // keep state consistent for the next window
                let _ = r.finalize(&ctx(step), vec![1.0; 8], Some(p.values), &mut s);
            }
        }
        assert_eq!(synced, vec![3, 7, 11]);
    }

    #[test]
    fn local_steps_apply_whole_buffer() {
        let mut r = DiLoCoReplicator::new(10, false, Dtype::F32, 4);
        let mut buf = vec![2.0f32, -1.0, 0.5, 0.0];
        let (q, p) = r.extract(&ctx(0), &mut buf, &mut Scratch::new());
        assert!(p.is_none());
        assert_eq!(q, vec![2.0, -1.0, 0.5, 0.0]);
        assert_eq!(buf, vec![0.0; 4]);
    }

    #[test]
    fn two_workers_land_on_average_trajectory() {
        // Simulate 2 ranks over one sync window with distinct updates and
        // check the federated-averaging identity: Σ applied updates equals
        // the mean of the two workers' total displacements.
        proptest(16, |g| {
            let period = g.usize(1, 6) as u64;
            let len = g.usize(1, 40);
            let mut ra = DiLoCoReplicator::new(period, false, Dtype::F32, len);
            let mut rb = DiLoCoReplicator::new(period, false, Dtype::F32, len);
            let mut sa = Scratch::new();
            let mut sb = Scratch::new();
            let mut applied_a = vec![0.0f32; len];
            let mut applied_b = vec![0.0f32; len];
            let mut total_a = vec![0.0f32; len];
            let mut total_b = vec![0.0f32; len];
            for step in 0..period {
                let ua = g.vec_normal(len, 1.0);
                let ub = g.vec_normal(len, 1.0);
                crate::tensor::axpy(&mut total_a, 1.0, &ua);
                crate::tensor::axpy(&mut total_b, 1.0, &ub);
                let mut bufa = ua.clone();
                let mut bufb = ub.clone();
                let c = ctx(step);
                let (qa, pa) = ra.extract(&c, &mut bufa, &mut sa);
                let (qb, pb) = rb.extract(&c, &mut bufb, &mut sb);
                let (fa, fb) = match (pa, pb) {
                    (Some(pa), Some(pb)) => {
                        let payloads = vec![pa, pb];
                        let ma = mean_decoded(&ra, &c, &payloads, len, &mut sa);
                        let mb = ma.clone();
                        (
                            ra.finalize(&c, qa, Some(ma), &mut sa),
                            rb.finalize(&c, qb, Some(mb), &mut sb),
                        )
                    }
                    (None, None) => (
                        ra.finalize(&c, qa, None, &mut sa),
                        rb.finalize(&c, qb, None, &mut sb),
                    ),
                    _ => panic!("ranks must agree on sync steps"),
                };
                crate::tensor::axpy(&mut applied_a, 1.0, &fa);
                crate::tensor::axpy(&mut applied_b, 1.0, &fb);
            }
            // After the window both ranks applied the same total: the mean.
            let mean: Vec<f32> = total_a
                .iter()
                .zip(&total_b)
                .map(|(a, b)| 0.5 * (a + b))
                .collect();
            prop_assert(
                approx_slice_eq(&applied_a, &mean, 1e-4),
                format!("rank a off average (period={period})"),
            );
            prop_assert(
                approx_slice_eq(&applied_b, &mean, 1e-4),
                format!("rank b off average (period={period})"),
            );
        });
    }

    #[test]
    fn average_bandwidth_matches_rate() {
        let r = DiLoCoReplicator::new(32, false, Dtype::F32, 64);
        assert!((r.rate() - 1.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "staleness")]
    fn async_rejects_staleness_at_or_above_period() {
        let _ = AsyncDiLoCoReplicator::new(4, false, Dtype::F32, 8, 4);
    }

    /// Tentpole pin: with `staleness = 0` the async replicator's whole
    /// visible behaviour — q, residual, payload, and finalized update —
    /// is bit-identical to the synchronous [`DiLoCoReplicator`], for
    /// random periods, lengths, and update sequences.
    #[test]
    fn prop_staleness_zero_bit_identical_to_sync() {
        proptest(24, |g| {
            let period = g.usize(1, 6) as u64;
            let len = g.usize(1, 64);
            let mut sync = DiLoCoReplicator::new(period, true, Dtype::F32, len);
            let mut asyn = AsyncDiLoCoReplicator::new(period, true, Dtype::F32, len, 0);
            let mut ss = Scratch::new();
            let mut sa = Scratch::new();
            for step in 0..3 * period {
                let u = g.vec_normal(len, 1.0);
                let c = ctx(step);
                let mut buf_s = u.clone();
                let mut buf_a = u;
                let (qs, ps) = sync.extract(&c, &mut buf_s, &mut ss);
                let (qa, pa) = asyn.extract(&c, &mut buf_a, &mut sa);
                prop_assert(qs == qa, format!("step {step}: q diverged"));
                prop_assert(buf_s == buf_a, format!("step {step}: residual diverged"));
                let (fs, fa) = match (ps, pa) {
                    (Some(ps), Some(pa)) => {
                        prop_assert(
                            ps.values == pa.values,
                            format!("step {step}: payload diverged"),
                        );
                        let payloads = vec![ps];
                        let ms = mean_decoded(&sync, &c, &payloads, len, &mut ss);
                        let pay_a = vec![pa];
                        let ma = mean_decoded(&asyn, &c, &pay_a, len, &mut sa);
                        prop_assert(ms == ma, format!("step {step}: mean diverged"));
                        (
                            sync.finalize(&c, qs, Some(ms), &mut ss),
                            asyn.finalize(&c, qa, Some(ma), &mut sa),
                        )
                    }
                    (None, None) => (
                        sync.finalize(&c, qs, None, &mut ss),
                        asyn.finalize(&c, qa, None, &mut sa),
                    ),
                    _ => panic!("step {step}: ranks must agree on sync steps"),
                };
                prop_assert(fs == fa, format!("step {step}: finalize diverged"));
                ss.put_f32(fs);
                sa.put_f32(fa);
            }
        });
    }

    /// Straggler-tolerance pin: when a member is dropped from the
    /// aggregation (NoLoCo's late-arrival policy), the averaging
    /// denominator must be the **contributing count**, not the group
    /// size — `mean_decoded_refs` over the quorum divides by the quorum
    /// size, and the surviving rank still lands on the quorum's averaged
    /// trajectory.
    #[test]
    fn dropped_member_corrects_the_averaging_denominator() {
        use crate::replicate::mean_decoded_refs;
        let len = 6;
        let period = 2u64;
        let mut ra = AsyncDiLoCoReplicator::new(period, false, Dtype::F32, len, 1);
        let mut rb = AsyncDiLoCoReplicator::new(period, false, Dtype::F32, len, 1);
        let mut rc = AsyncDiLoCoReplicator::new(period, false, Dtype::F32, len, 1);
        let mut sa = Scratch::new();
        let (mut sb, mut sc) = (Scratch::new(), Scratch::new());
        let da = vec![1.0f32; len];
        let db = vec![3.0f32; len];
        let dc = vec![100.0f32; len]; // the straggler's (dropped) window
        let launch = ctx(period - 1);
        // one-step window: the whole buffer is the window delta
        let mut bufs = [da.clone(), db.clone(), dc.clone()];
        let (qa, pa) = ra.extract(&launch, &mut bufs[0], &mut sa);
        let (_, pb) = rb.extract(&launch, &mut bufs[1], &mut sb);
        let (_, pc) = rc.extract(&launch, &mut bufs[2], &mut sc);
        let (pa, pb, pc) = (pa.unwrap(), pb.unwrap(), pc.unwrap());

        // Full-group mean divides by 3…
        let full = mean_decoded_refs(&ra, &launch, &[&pa, &pb, &pc], len, &mut sa);
        assert!(full.iter().all(|&x| (x - (1.0 + 3.0 + 100.0) / 3.0).abs() < 1e-5));
        sa.put_f32(full);
        // …but with c dropped, the denominator is the quorum size 2,
        // bit-for-bit the same float chain as averaging a 2-group.
        let quorum = mean_decoded_refs(&ra, &launch, &[&pa, &pb], len, &mut sa);
        assert_eq!(quorum, vec![(1.0f32 + 3.0) * 0.5; len]);

        // The surviving rank lands on the quorum average: finalize at the
        // arrival (next step, zero local update) applies mean − snap, so
        // total applied = δ_a + (mean − δ_a) = mean of {a, b}.
        let arrival = ctx(period);
        let mut zero = vec![0.0f32; len];
        let (q2, none) = ra.extract(&arrival, &mut zero, &mut sa);
        assert!(none.is_none());
        let fin = ra.finalize(&arrival, q2, Some(quorum), &mut sa);
        let mut applied = qa;
        crate::tensor::axpy(&mut applied, 1.0, &fin);
        assert_eq!(applied, vec![(1.0f32 + 3.0) * 0.5; len]);
        assert!(!ra.sync_in_flight());
        sc.recycle_payload(pc);
    }

    /// Checkpoint pin: exporting mid-window state and importing it into a
    /// fresh replicator continues the window bit-identically — including
    /// an async gather that was in flight at the snapshot.
    #[test]
    fn state_roundtrip_continues_window_bit_identically() {
        let len = 8;
        let mut s = Scratch::new();
        // Sync DiLoCo: snapshot after 2 of 4 local steps.
        let mut a = DiLoCoReplicator::new(4, false, Dtype::F32, len);
        for step in 0..2u64 {
            let mut buf = vec![step as f32 + 1.0; len];
            let (q, p) = a.extract(&ctx(step), &mut buf, &mut s);
            assert!(p.is_none());
            s.put_f32(q);
        }
        let mut b = DiLoCoReplicator::new(4, false, Dtype::F32, len);
        b.import_state(a.export_state()).unwrap();
        for step in 2..4u64 {
            let mut ba = vec![0.5; len];
            let mut bb = vec![0.5; len];
            let (qa, pa) = a.extract(&ctx(step), &mut ba, &mut s);
            let (qb, pb) = b.extract(&ctx(step), &mut bb, &mut s);
            assert_eq!(qa, qb);
            assert_eq!(pa.as_ref().map(|p| &p.values), pb.as_ref().map(|p| &p.values));
        }
        // Async: snapshot while a gather is in flight; the restored copy
        // must finalize the arrival with the same correction.
        let mut a = AsyncDiLoCoReplicator::new(2, false, Dtype::F32, len, 1);
        let mut buf = vec![1.0; len];
        let (q0, _) = a.extract(&ctx(0), &mut buf, &mut s);
        s.put_f32(q0);
        let mut buf = vec![2.0; len];
        let (q1, p1) = a.extract(&ctx(1), &mut buf, &mut s);
        assert!(p1.is_some() && a.sync_in_flight());
        s.put_f32(q1);
        let mut b = AsyncDiLoCoReplicator::new(2, false, Dtype::F32, len, 1);
        b.import_state(a.export_state()).unwrap();
        assert!(b.sync_in_flight());
        let mean = vec![7.0f32; len];
        let fa = a.finalize(&ctx(2), vec![0.25; len], Some(mean.clone()), &mut s);
        let fb = b.finalize(&ctx(2), vec![0.25; len], Some(mean), &mut s);
        assert_eq!(fa, fb);
        // Shape/kind mismatches are rejected with context.
        let mut wrong = DiLoCoReplicator::new(4, false, Dtype::F32, len + 1);
        assert!(wrong.import_state(a.export_state()).is_err());
        let mut sync = DiLoCoReplicator::new(2, false, Dtype::F32, len);
        let mut with_flight = ReplState {
            delta_acc: vec![0.0; len],
            in_flight: Some(vec![0.0; len]),
        };
        assert!(sync.import_state(with_flight.clone()).is_err());
        // …and the stateless default refuses any non-empty snapshot.
        let mut demo = crate::replicate::ReplSpec::parse("demo:1/8")
            .unwrap()
            .build_for_node(0, &crate::replicate::ReplBuildCtx::uniform(len))
            .unwrap();
        assert!(demo.export_state().is_empty());
        with_flight.in_flight = None;
        assert!(demo.import_state(with_flight).is_err());
        assert!(demo.import_state(ReplState::default()).is_ok());
    }

    /// The async federated-averaging identity: after a stale arrival,
    /// each rank sits at `mean(window δ) + its own since-launch deltas` —
    /// the averaged trajectory plus preserved local progress.
    #[test]
    fn prop_stale_arrival_preserves_since_launch_progress() {
        proptest(16, |g| {
            let period = g.usize(2, 6) as u64;
            let staleness = g.usize(1, period as usize - 1) as u64;
            let len = g.usize(1, 40);
            let mut ra = AsyncDiLoCoReplicator::new(period, false, Dtype::F32, len, staleness);
            let mut rb = AsyncDiLoCoReplicator::new(period, false, Dtype::F32, len, staleness);
            let mut sa = Scratch::new();
            let mut sb = Scratch::new();
            let launch = period - 1;
            let arrival = launch + staleness;
            let mut applied_a = vec![0.0f32; len];
            let mut applied_b = vec![0.0f32; len];
            let mut window_a = vec![0.0f32; len]; // δ_a over steps 0..period
            let mut window_b = vec![0.0f32; len];
            let mut since_a = vec![0.0f32; len]; // d_a over steps launch+1..=arrival
            let mut since_b = vec![0.0f32; len];
            let mut parked: Option<Vec<Payload>> = None;
            for step in 0..=arrival {
                let ua = g.vec_normal(len, 1.0);
                let ub = g.vec_normal(len, 1.0);
                if step < period {
                    crate::tensor::axpy(&mut window_a, 1.0, &ua);
                    crate::tensor::axpy(&mut window_b, 1.0, &ub);
                } else {
                    crate::tensor::axpy(&mut since_a, 1.0, &ua);
                    crate::tensor::axpy(&mut since_b, 1.0, &ub);
                }
                let c = ctx(step);
                let mut bufa = ua.clone();
                let mut bufb = ub.clone();
                let (qa, pa) = ra.extract(&c, &mut bufa, &mut sa);
                let (qb, pb) = rb.extract(&c, &mut bufb, &mut sb);
                if let (Some(pa), Some(pb)) = (pa, pb) {
                    assert_eq!(step, launch);
                    assert!(ra.sync_in_flight() && rb.sync_in_flight());
                    parked = Some(vec![pa, pb]);
                }
                let (fa, fb) = if step == arrival {
                    let payloads = parked.take().expect("gather parked at launch");
                    let ma = mean_decoded(&ra, &c, &payloads, len, &mut sa);
                    let mb = ma.clone();
                    (
                        ra.finalize(&c, qa, Some(ma), &mut sa),
                        rb.finalize(&c, qb, Some(mb), &mut sb),
                    )
                } else {
                    (
                        ra.finalize(&c, qa, None, &mut sa),
                        rb.finalize(&c, qb, None, &mut sb),
                    )
                };
                crate::tensor::axpy(&mut applied_a, 1.0, &fa);
                crate::tensor::axpy(&mut applied_b, 1.0, &fb);
            }
            assert!(!ra.sync_in_flight() && !rb.sync_in_flight());
            // applied − since-launch deltas = mean of the shipped window
            let mean: Vec<f32> = window_a
                .iter()
                .zip(&window_b)
                .map(|(a, b)| 0.5 * (a + b))
                .collect();
            let land_a: Vec<f32> = applied_a.iter().zip(&since_a).map(|(x, d)| x - d).collect();
            let land_b: Vec<f32> = applied_b.iter().zip(&since_b).map(|(x, d)| x - d).collect();
            prop_assert(
                approx_slice_eq(&land_a, &mean, 1e-4),
                format!("rank a off averaged trajectory (p={period} s={staleness})"),
            );
            prop_assert(
                approx_slice_eq(&land_b, &mean, 1e-4),
                format!("rank b off averaged trajectory (p={period} s={staleness})"),
            );
        });
    }
}
