//! DiLoCo replication (Douillard et al. 2023, recast as a DeToNATION
//! replication scheme): workers step locally and synchronize every n-th
//! optimization step.
//!
//! Mechanics here follow the federated-averaging identity: a worker that
//! applied local updates δ_i since the last sync can jump onto the
//! averaged trajectory by applying `mean_j(δ_j) − δ_i` at the sync point.
//! The replicator therefore
//! * on non-sync steps: extracts the whole buffer as a *local* update
//!   (no payload) and accumulates it into `delta_acc`;
//! * on sync steps: ships `delta_acc + q_t` and finalizes with
//!   `mean − delta_acc_own` so every rank lands on the average trajectory
//!   (exact for unsigned f32; approximate under sign/dtype quantization,
//!   which the paper also applies).
//!
//! Average bandwidth = full buffer / period → "compression rate" 1/period.

use super::{ReplCtx, Replicator};
use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

pub struct DiLoCoReplicator {
    pub period: u64,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
    /// Sum of locally-applied updates since the last synchronization.
    delta_acc: Vec<f32>,
}

impl DiLoCoReplicator {
    pub fn new(period: u64, sign: bool, dtype: Dtype, shard_len: usize) -> DiLoCoReplicator {
        assert!(period >= 1);
        DiLoCoReplicator {
            period,
            sign,
            dtype,
            is_packed: false,
            delta_acc: vec![0.0; shard_len],
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }


    pub fn is_sync_step(&self, step: u64) -> bool {
        (step + 1) % self.period == 0
    }
}

impl Replicator for DiLoCoReplicator {
    fn name(&self) -> String {
        format!(
            "diloco-n{}{}",
            self.period,
            if self.sign { "-sign" } else { "" }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        assert_eq!(buf.len(), self.delta_acc.len());
        // Local step: the whole buffer becomes this step's update.
        let mut q_local = scratch.take_f32();
        q_local.extend_from_slice(buf);
        buf.fill(0.0);
        crate::tensor::axpy(&mut self.delta_acc, 1.0, &q_local);
        if self.is_sync_step(ctx.step) {
            let mut values = scratch.take_f32();
            values.extend_from_slice(&self.delta_acc);
            let payload = self.mk_payload(None, values);
            (q_local, Some(payload))
        } else {
            (q_local, None)
        }
    }

    fn decode(&self, _ctx: &ReplCtx, payload: &Payload, out: &mut [f32], _scratch: &mut Scratch) {
        out.copy_from_slice(&payload.values);
    }

    fn finalize(
        &mut self,
        ctx: &ReplCtx,
        q_local: Vec<f32>,
        mean: Option<Vec<f32>>,
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        match mean {
            None => q_local, // local-only step
            Some(mean_delta) => {
                // Jump from the local trajectory onto the averaged one:
                //   θ has already absorbed (delta_acc − q_local); applying
                //   q_final = mean(δ) − delta_acc + q_local lands θ on
                //   θ_start − η·mean(δ) (for the SGD-style apply θ−=η·q).
                debug_assert!(self.is_sync_step(ctx.step));
                let mut q = mean_delta;
                crate::tensor::axpy(&mut q, -1.0, &self.delta_acc);
                crate::tensor::axpy(&mut q, 1.0, &q_local);
                self.delta_acc.fill(0.0);
                scratch.put_f32(q_local);
                q
            }
        }
    }

    fn rate(&self) -> f64 {
        1.0 / self.period as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replicate::mean_decoded;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};

    fn ctx(step: u64) -> ReplCtx {
        ReplCtx {
            step,
            shard: 0,
            seed: 3,
        }
    }

    #[test]
    fn syncs_exactly_every_period() {
        let mut r = DiLoCoReplicator::new(4, false, Dtype::F32, 8);
        let mut s = Scratch::new();
        let mut synced = Vec::new();
        for step in 0..12 {
            let mut buf = vec![1.0f32; 8];
            let (_, p) = r.extract(&ctx(step), &mut buf, &mut s);
            if let Some(p) = p {
                synced.push(step);
                // keep state consistent for the next window
                let _ = r.finalize(&ctx(step), vec![1.0; 8], Some(p.values), &mut s);
            }
        }
        assert_eq!(synced, vec![3, 7, 11]);
    }

    #[test]
    fn local_steps_apply_whole_buffer() {
        let mut r = DiLoCoReplicator::new(10, false, Dtype::F32, 4);
        let mut buf = vec![2.0f32, -1.0, 0.5, 0.0];
        let (q, p) = r.extract(&ctx(0), &mut buf, &mut Scratch::new());
        assert!(p.is_none());
        assert_eq!(q, vec![2.0, -1.0, 0.5, 0.0]);
        assert_eq!(buf, vec![0.0; 4]);
    }

    #[test]
    fn two_workers_land_on_average_trajectory() {
        // Simulate 2 ranks over one sync window with distinct updates and
        // check the federated-averaging identity: Σ applied updates equals
        // the mean of the two workers' total displacements.
        proptest(16, |g| {
            let period = g.usize(1, 6) as u64;
            let len = g.usize(1, 40);
            let mut ra = DiLoCoReplicator::new(period, false, Dtype::F32, len);
            let mut rb = DiLoCoReplicator::new(period, false, Dtype::F32, len);
            let mut sa = Scratch::new();
            let mut sb = Scratch::new();
            let mut applied_a = vec![0.0f32; len];
            let mut applied_b = vec![0.0f32; len];
            let mut total_a = vec![0.0f32; len];
            let mut total_b = vec![0.0f32; len];
            for step in 0..period {
                let ua = g.vec_normal(len, 1.0);
                let ub = g.vec_normal(len, 1.0);
                crate::tensor::axpy(&mut total_a, 1.0, &ua);
                crate::tensor::axpy(&mut total_b, 1.0, &ub);
                let mut bufa = ua.clone();
                let mut bufb = ub.clone();
                let c = ctx(step);
                let (qa, pa) = ra.extract(&c, &mut bufa, &mut sa);
                let (qb, pb) = rb.extract(&c, &mut bufb, &mut sb);
                let (fa, fb) = match (pa, pb) {
                    (Some(pa), Some(pb)) => {
                        let payloads = vec![pa, pb];
                        let ma = mean_decoded(&ra, &c, &payloads, len, &mut sa);
                        let mb = ma.clone();
                        (
                            ra.finalize(&c, qa, Some(ma), &mut sa),
                            rb.finalize(&c, qb, Some(mb), &mut sb),
                        )
                    }
                    (None, None) => (
                        ra.finalize(&c, qa, None, &mut sa),
                        rb.finalize(&c, qb, None, &mut sb),
                    ),
                    _ => panic!("ranks must agree on sync steps"),
                };
                crate::tensor::axpy(&mut applied_a, 1.0, &fa);
                crate::tensor::axpy(&mut applied_b, 1.0, &fb);
            }
            // After the window both ranks applied the same total: the mean.
            let mean: Vec<f32> = total_a
                .iter()
                .zip(&total_b)
                .map(|(a, b)| 0.5 * (a + b))
                .collect();
            prop_assert(
                approx_slice_eq(&applied_a, &mean, 1e-4),
                format!("rank a off average (period={period})"),
            );
            prop_assert(
                approx_slice_eq(&applied_b, &mean, 1e-4),
                format!("rank b off average (period={period})"),
            );
        });
    }

    #[test]
    fn average_bandwidth_matches_rate() {
        let r = DiLoCoReplicator::new(32, false, Dtype::F32, 64);
        assert!((r.rate() - 1.0 / 32.0).abs() < 1e-12);
    }
}
