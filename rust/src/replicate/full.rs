//! Full replication: the whole buffer crosses the wire every step.
//!
//! With the AdamW optimizer this is the paper's conventional Hybrid-FSDP
//! baseline (full inter-node gradient synchronization); with sign enabled
//! it doubles as the "Decoupled-AdamW full replication" arm of Fig 10b.

use super::{ReplCtx, Replicator};
use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

#[derive(Debug)]
pub struct FullReplicator {
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
}

impl FullReplicator {
    pub fn new(sign: bool, dtype: Dtype) -> FullReplicator {
        FullReplicator {
            sign,
            dtype,
            is_packed: false,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }

}

impl Replicator for FullReplicator {
    fn name(&self) -> String {
        format!(
            "full{}{}",
            if self.sign { "-sign" } else { "" },
            if self.dtype != Dtype::F32 {
                format!("-{}", self.dtype.name())
            } else {
                String::new()
            }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        let mut values = scratch.take_f32();
        values.extend_from_slice(buf);
        buf.fill(0.0);
        let payload = self.mk_payload(None, values);
        let mut q_local = scratch.take_f32_zeroed(payload.values.len());
        self.decode(ctx, &payload, &mut q_local, scratch);
        (q_local, Some(payload))
    }

    fn decode(&self, _ctx: &ReplCtx, payload: &Payload, out: &mut [f32], _scratch: &mut Scratch) {
        out.copy_from_slice(&payload.values);
    }

    fn rate(&self) -> f64 {
        1.0
    }

    fn gather_mode(&self) -> super::GatherMode {
        // Dense full-gradient sync rides the ring (NCCL all-reduce), which
        // is why the conventional baseline *does* scale in Figs 5/6.
        super::GatherMode::RingAllReduce
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ships_everything() {
        let mut r = FullReplicator::new(false, Dtype::F32);
        let mut buf = vec![1.0f32, -2.0, 3.0];
        let c = ReplCtx {
            step: 0,
            shard: 0,
            seed: 0,
        };
        let (q, p) = r.extract(&c, &mut buf, &mut Scratch::new());
        let p = p.unwrap();
        assert_eq!(q, vec![1.0, -2.0, 3.0]);
        assert_eq!(buf, vec![0.0; 3]);
        assert_eq!(p.wire_bytes(), 12);
        assert!(p.indices.is_none());
    }

    #[test]
    fn signed_full_is_ternary() {
        // Paper wire format: signs as ±1.0 in dtype (4096 B), unless the
        // ternary packing extension is on (2 bits → 256 B).
        let c = ReplCtx {
            step: 0,
            shard: 0,
            seed: 0,
        };
        let mut r = FullReplicator::new(true, Dtype::F32);
        let (_, p) = r.extract(&c, &mut vec![0.5f32; 1024], &mut Scratch::new());
        let p = p.unwrap();
        assert_eq!(p.wire_bytes(), 4096);
        assert!(p.values.iter().all(|&v| v == 1.0));

        let mut r = FullReplicator::new(true, Dtype::F32).packed(true);
        let (_, p) = r.extract(&c, &mut vec![0.5f32; 1024], &mut Scratch::new());
        assert_eq!(p.unwrap().wire_bytes(), 256); // 2 bits/value
    }
}
