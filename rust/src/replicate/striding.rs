//! Striding replication (introduced by DeToNATION): every n-th component,
//! with a step-rotating offset so all components are visited every n
//! steps.
//!
//! Like Random, the index set is reproducible from `(step, stride)` alone
//! — no indices on the wire. The paper finds Striding weakest on
//! translation (Fig 2a), competitive on highly-structured image data
//! (Fig 2b "the structure provided by this scheme works in highly
//! structured data"), and unstable on causal LM (Fig 3).

use super::{ReplCtx, Replicator};
use crate::compress::{Payload, Scratch};
use crate::tensor::Dtype;

#[derive(Debug)]
pub struct StridingReplicator {
    /// Select one of every `stride` components.
    pub stride: usize,
    pub sign: bool,
    pub dtype: Dtype,
    is_packed: bool,
    /// Adaptive-controller mode: peers may run different strides, so the
    /// payload carries this instance's stride as its `sel` hint (4 B)
    /// and decode reads the *payload's* stride, not its own. Off by
    /// default — fixed-rate payloads stay bit-identical.
    is_adaptive: bool,
}

impl StridingReplicator {
    pub fn new(rate: f64, sign: bool, dtype: Dtype) -> StridingReplicator {
        assert!(rate > 0.0 && rate <= 1.0);
        let stride = (1.0 / rate).round().max(1.0) as usize;
        StridingReplicator {
            stride,
            sign,
            dtype,
            is_packed: false,
            is_adaptive: false,
        }
    }

    /// Builder: enable the 2-bit ternary wire extension (see
    /// `compress::Payload::packed`).
    pub fn packed(mut self, packed: bool) -> Self {
        self.is_packed = packed;
        self
    }

    /// Builder: ship the stride as the payload's `sel` hint so peers at
    /// controller-tuned heterogeneous strides decode each other.
    pub fn adaptive(mut self, adaptive: bool) -> Self {
        self.is_adaptive = adaptive;
        self
    }

    fn mk_payload(&self, indices: Option<Vec<u32>>, values: Vec<f32>) -> Payload {
        let p = Payload::new(indices, values, self.dtype, self.sign);
        if self.is_packed && self.sign {
            p.with_packing()
        } else {
            p
        }
    }


    /// Offset rotates with the step: offset = step mod stride.
    fn offset(&self, ctx: &ReplCtx) -> usize {
        (ctx.step % self.stride as u64) as usize
    }

    pub fn indices(&self, ctx: &ReplCtx, len: usize) -> impl Iterator<Item = usize> + '_ {
        Self::indices_at(self.stride, ctx, len)
    }

    /// The strided index set at an explicit stride — decode uses the
    /// *payload's* stride (its `sel` hint) when present, so a peer at a
    /// different controller-tuned rate is recoverable.
    fn indices_at(stride: usize, ctx: &ReplCtx, len: usize) -> impl Iterator<Item = usize> {
        ((ctx.step % stride as u64) as usize..len).step_by(stride)
    }
}

impl Replicator for StridingReplicator {
    fn name(&self) -> String {
        format!(
            "striding-1/{}{}",
            self.stride,
            if self.sign { "-sign" } else { "" }
        )
    }

    fn extract(
        &mut self,
        ctx: &ReplCtx,
        buf: &mut [f32],
        scratch: &mut Scratch,
    ) -> (Vec<f32>, Option<Payload>) {
        let len = buf.len();
        let mut values = scratch.take_f32();
        values.extend(self.indices(ctx, len).map(|i| buf[i]));
        for i in self.indices(ctx, len) {
            buf[i] = 0.0;
        }
        let mut payload = self.mk_payload(None, values);
        if self.is_adaptive {
            payload = payload.with_sel(self.stride as u32);
        }
        let mut q_local = scratch.take_f32_zeroed(len);
        self.decode(ctx, &payload, &mut q_local, scratch);
        (q_local, Some(payload))
    }

    fn decode(&self, ctx: &ReplCtx, payload: &Payload, out: &mut [f32], _scratch: &mut Scratch) {
        let n = out.len();
        let stride = match payload.sel {
            Some(s) => (s as usize).max(1),
            None => self.stride,
        };
        for (i, &v) in Self::indices_at(stride, ctx, n).zip(&payload.values) {
            out[i] = v;
        }
    }

    fn rate(&self) -> f64 {
        1.0 / self.stride as f64
    }

    fn set_rate(&mut self, rate: f64) -> bool {
        assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");
        self.stride = (1.0 / rate).round().max(1.0) as usize;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ctx(step: u64) -> ReplCtx {
        ReplCtx {
            step,
            shard: 0,
            seed: 5,
        }
    }

    #[test]
    fn stride_from_rate() {
        assert_eq!(StridingReplicator::new(1.0 / 8.0, true, Dtype::F32).stride, 8);
        assert_eq!(StridingReplicator::new(1.0, true, Dtype::F32).stride, 1);
    }

    #[test]
    fn offset_rotates_and_covers_everything() {
        let r = StridingReplicator::new(1.0 / 4.0, false, Dtype::F32);
        let mut seen = vec![false; 64];
        for step in 0..4 {
            for i in r.indices(&ctx(step), 64) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "4 steps at stride 4 cover all");
    }

    #[test]
    fn extract_selects_strided_components() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..64).map(|_| rng.normal_f32(1.0) + 2.0).collect();
        let mut buf = orig.clone();
        let mut r = StridingReplicator::new(1.0 / 8.0, false, Dtype::F32);
        let c = ctx(3); // offset 3
        let (q, _) = r.extract(&c, &mut buf, &mut Scratch::new());
        for i in 0..64 {
            if i % 8 == 3 {
                assert_eq!(buf[i], 0.0);
                assert_eq!(q[i], orig[i]);
            } else {
                assert_eq!(buf[i], orig[i]);
                assert_eq!(q[i], 0.0);
            }
        }
    }

    #[test]
    fn decode_equals_local_q() {
        let mut rng = Rng::new(2);
        let mut buf: Vec<f32> = (0..100).map(|_| rng.normal_f32(1.0)).collect();
        let mut r = StridingReplicator::new(1.0 / 4.0, true, Dtype::F32);
        let c = ctx(1);
        let mut s = Scratch::new();
        let (q, p) = r.extract(&c, &mut buf, &mut s);
        let mut out = vec![0.0f32; 100];
        r.decode(&c, &p.unwrap(), &mut out, &mut s);
        assert_eq!(q, out);
    }

    #[test]
    fn adaptive_sel_hint_makes_decode_stride_agnostic() {
        // Controller mode: a 1/16 peer's payload decodes correctly on a
        // rank whose own instance runs 1/4, because the stride rides the
        // payload. Non-adaptive payloads carry no hint (bit-frozen wire).
        let mut rng = Rng::new(7);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0)).collect();
        let c = ctx(5);
        let mut s = Scratch::new();
        let mut slow = StridingReplicator::new(1.0 / 16.0, false, Dtype::F32).adaptive(true);
        let mut buf = orig.clone();
        let (q, p) = slow.extract(&c, &mut buf, &mut s);
        let p = p.unwrap();
        assert_eq!(p.sel, Some(16));
        let fast = StridingReplicator::new(1.0 / 4.0, false, Dtype::F32).adaptive(true);
        let mut out = vec![0.0f32; 256];
        fast.decode(&c, &p, &mut out, &mut s);
        assert_eq!(out, q, "decoder's own stride leaked into decode");
        // fixed-rate mode ships no hint
        let mut fixed = StridingReplicator::new(1.0 / 16.0, false, Dtype::F32);
        let (_, pf) = fixed.extract(&c, &mut orig.clone(), &mut s);
        assert_eq!(pf.unwrap().sel, None);
        // set_rate retunes the stride in place
        assert!(slow.set_rate(1.0 / 4.0));
        assert_eq!(slow.stride, 4);
    }

    #[test]
    fn no_indices_on_wire() {
        let mut buf = vec![1.0f32; 32];
        let mut r = StridingReplicator::new(1.0 / 2.0, false, Dtype::F32);
        let (_, p) = r.extract(&ctx(0), &mut buf, &mut Scratch::new());
        let p = p.unwrap();
        assert!(p.indices.is_none());
        assert_eq!(p.wire_bytes(), 16 * 4);
    }
}
