//! `detonation` — the launcher CLI.
//!
//! Subcommands:
//!   train        run one training experiment (flags mirror config keys)
//!   validate     cross-validate the Rust DCT extraction against the AOT
//!                Pallas artifact (L1↔L3 numerics check)
//!   models       list available artifacts
//!   help
//!
//! Example:
//!   detonation train --model lm-tiny --nodes 2 --accels 2 \
//!       --opt demo-sgd --repl demo:1/8 --steps 200 --val-every 50

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::util::argparse::ArgParser;

fn main() -> Result<()> {
    detonation::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "train" => cmd_train(rest),
        "validate" => cmd_validate(rest),
        "models" => cmd_models(rest),
        _ => {
            println!(
                "detonation — DeToNATION / FlexDeMo reproduction\n\n\
                 USAGE: detonation <train|validate|models> [flags]\n\n\
                 train     run one experiment (see `detonation train --help`)\n\
                 validate  cross-check Rust DCT vs the Pallas artifact\n\
                 models    list artifacts in the artifacts directory\n"
            );
            Ok(())
        }
    }
}

fn train_parser() -> ArgParser {
    ArgParser::new("detonation train", "run one FlexDeMo training experiment")
        .opt("model", "lm-tiny", "artifact name (see `detonation models`)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("nodes", "2", "number of nodes (replication width)")
        .opt("accels", "2", "accelerators per node (sharding width)")
        .opt("opt", "demo-sgd", "optimizer: demo-sgd|decoupled-adamw|adamw|sgd")
        .opt(
            "repl",
            "demo:1/8",
            "replicator: demo:1/8|random:1/16|striding:1/8|diloco:8|full \
             (+ :nosign :bf16 :chunk=N; diloco also :async=S)",
        )
        .opt(
            "staleness",
            "0",
            "async DiLoCo: apply the periodic sync S steps after its \
             launch while local steps keep running (diloco only, S < \
             period; 0 = synchronous, bit-identical to plain diloco; \
             'auto' derives one S per node from its compute/NIC profile)",
        )
        .opt(
            "node-staleness",
            "",
            "per-node staleness overrides for async DiLoCo, \
             NODE:S[,NODE:S...] (diloco only; patches the global/auto \
             value; in a mixed table S = 0 makes that node aggregate at \
             the launch step itself — under wait it blocks on every \
             peer like the synchronous scheme, under drop/partial it \
             averages whatever has landed by then; an all-zero table is \
             plain synchronous diloco, late policy inert)",
        )
        .opt(
            "late-policy",
            "wait",
            "what an async DiLoCo aggregation does with peer deltas that \
             miss its arrival deadline: wait = whole-group window (PR 4 \
             semantics), drop = NoLoCo-style quorum with the averaging \
             denominator corrected to the contributing set, partial = \
             fold late deltas into that node's next window",
        )
        .opt("lr", "0.001", "learning rate")
        .opt("warmup", "0", "linear warmup steps")
        .opt("steps", "100", "training steps")
        .opt("seed", "3383", "experiment seed")
        .opt("val-every", "0", "validate every N steps (0 = never)")
        .opt("val-batches", "8", "validation batches")
        .opt("inter-mbps", "0", "throttle inter-node bandwidth (Mbps, 0 = HPC default)")
        .opt("streams", "0", "distinct gradient streams (0 = world size)")
        .opt(
            "threads",
            "1",
            "persistent worker-pool slots driving fwd/bwd fan-out AND the \
             chunk-parallel kernels (collectives, optimizer, DCT, eval); \
             0 = one per hardware thread; never changes numerics",
        )
        .opt(
            "trace-out",
            "",
            "write the step schedule (comm events, per-rank lanes) as \
             Chrome-trace JSON to this path after the run",
        )
        .opt(
            "bucket-mb",
            "0",
            "pipeline reduce-scatter/gather into buckets of this many MiB \
             (0 = whole-phase; overlap mode only)",
        )
        .opt("straggler", "", "per-node compute slowdown, NODE:FACTOR[,..]")
        .opt("node-mbps", "", "per-node NIC bandwidth override, NODE:MBPS[,..]")
        .opt(
            "churn",
            "",
            "deterministic membership timeline, EVENT:NODE@STEP[,..] with \
             EVENT = join|leave|crash (e.g. 'leave:1@10,join:1@20'); a \
             leaver keeps its state frozen, a crasher loses it; node 0 \
             anchors the group and cannot churn",
        )
        .opt(
            "crash",
            "",
            "crash shorthand, NODE@STEP[:REJOIN][,..] — node crashes at \
             STEP and (with :REJOIN) rejoins at that step, restoring its \
             private state from the stashed checkpoint when \
             --checkpoint-dir is set",
        )
        .opt(
            "quorum",
            "0",
            "finalize a deferred sync window once at least K of the \
             group's contributions have landed; the earliest late \
             transfers are waited for only up to the quorum, the rest \
             follow --late-policy (0 = off)",
        )
        .opt(
            "checkpoint-dir",
            "",
            "publish a full trainer checkpoint (latest.ckpt) at every \
             window-quiescent step; crashes stash it for checkpointed \
             rejoin, and restore is bit-identical to the uninterrupted run",
        )
        .opt(
            "link-fault",
            "",
            "deterministic link-fault timeline, KIND:SRC-DST@PARAM[,..] \
             with KIND = drop|corrupt (@pP, fault probability per \
             attempt), flap (@A..B, link dead for steps A..B), degrade \
             (@Fx, link runs at F times bandwidth); '*' wildcards an \
             endpoint (e.g. 'drop:0-2@p0.05,flap:2-0@40..90'); failed or \
             corrupt transfers retry with timeout+backoff, all \
             deterministic from --seed",
        )
        .opt(
            "max-retries",
            "3",
            "retry attempts for a failed/corrupt transfer before the \
             sender is treated as late under --late-policy",
        )
        .opt(
            "retry-timeout",
            "0.1",
            "sim-seconds a sender waits on a failed attempt before \
             re-charging the transfer on the NIC",
        )
        .opt(
            "retry-backoff",
            "0.05",
            "base of the capped exponential backoff added per retry \
             (sim-seconds; cap = 8x base)",
        )
        .opt(
            "topology",
            "full",
            "which peers each node exchanges deltas with per sync window: \
             full = the whole replication group (bit-identical to the \
             pre-topology path), ring = the two ring neighbors, \
             random-pair = a seeded perfect matching re-drawn every \
             window, hier:<F> = fabric reduce inside the node plus an \
             F-wide rotating inter-node fanout; averaging always divides \
             by the contributing set actually heard from",
        )
        .opt(
            "compress-control",
            "off",
            "closed-loop per-node compression-rate control: off = fixed \
             spec rate (bit-identical to no flag), aimd[:key=val...] = \
             per --control-window, back a node's rate off \
             multiplicatively when its NIC is congested AND comm is \
             exposed, raise it additively when the NIC idles (keys: \
             add, mul, hi, lo, exposed; demo/random/striding only)",
        )
        .opt(
            "control-window",
            "8",
            "steps per rate-controller window (occupancy sampled and \
             rates retuned at each window boundary)",
        )
        .opt(
            "rate-min",
            "1/64",
            "controller floor: no node's rate is tuned below this \
             ('1/N' or a float in (0, 1])",
        )
        .opt(
            "rate-max",
            "1/4",
            "controller cap: no node's rate is tuned above this",
        )
        .flag("no-overlap", "serialize phases (legacy barrier clock)")
        .opt("name", "cli", "experiment name (results/<name>/)")
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let args = train_parser().parse(argv);
    let mut cfg = ExperimentConfig::default();
    for key in [
        "model", "artifacts", "nodes", "accels", "opt", "repl", "lr", "warmup", "steps", "seed",
        "val-every", "val-batches", "streams", "threads", "bucket-mb",
    ] {
        cfg.apply_arg(key, args.str(key))?;
    }
    // Applied only when given on the command line, so the flag's default
    // never clobbers an `:async=S` component inside --repl — while an
    // explicit `--staleness 0` still overrides it back to S = 0.
    if argv
        .iter()
        .any(|a| a == "--staleness" || a.starts_with("--staleness="))
    {
        cfg.apply_arg("staleness", args.str("staleness"))?;
    }
    let mbps: f64 = args.f64("inter-mbps");
    if mbps > 0.0 {
        cfg.apply_arg("inter-mbps", args.str("inter-mbps"))?;
    }
    if args.flag("no-overlap") {
        cfg.overlap = false;
    }
    for key in [
        "straggler",
        "node-mbps",
        "trace-out",
        "node-staleness",
        "churn",
        "crash",
        "checkpoint-dir",
        "link-fault",
    ] {
        if !args.str(key).is_empty() {
            cfg.apply_arg(key, args.str(key))?;
        }
    }
    for key in [
        "max-retries",
        "retry-timeout",
        "retry-backoff",
        "topology",
        "compress-control",
        "control-window",
        "rate-min",
        "rate-max",
    ] {
        cfg.apply_arg(key, args.str(key))?;
    }
    if args.str("quorum") != "0" {
        cfg.apply_arg("quorum", args.str("quorum"))?;
    }
    // "wait" is the universal default, so only a non-default policy (or
    // an explicit flag) needs to reach the config — mirroring how
    // --staleness avoids clobbering an `:async=S,policy` repl component.
    if args.str("late-policy") != "wait"
        || argv
            .iter()
            .any(|a| a == "--late-policy" || a.starts_with("--late-policy="))
    {
        cfg.apply_arg("late-policy", args.str("late-policy"))?;
    }
    let rt = runtime()?;
    let mut exp = Experiment::new(args.str("name"), &results_root());
    let run = exp.run(&rt, &cfg, None)?;
    println!(
        "final loss {:.4}{}  sim time {}  inter-node {}  exposed comm {} (hidden {:.0}%)",
        run.final_loss().unwrap_or(f64::NAN),
        run.final_val_loss()
            .map(|v| format!("  val {v:.4}"))
            .unwrap_or_default(),
        detonation::util::fmt_secs(run.total_sim_time()),
        detonation::util::fmt_bytes(run.total_inter_bytes()),
        detonation::util::fmt_secs(run.total_exposed_comm()),
        run.overlap_efficiency() * 100.0,
    );
    println!("{}", exp.finish()?);
    Ok(())
}

fn cmd_validate(argv: &[String]) -> Result<()> {
    let args = ArgParser::new(
        "detonation validate",
        "cross-validate Rust DCT extraction against the AOT Pallas artifact",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .parse(argv);
    let rt = runtime()?;
    let dir = std::path::PathBuf::from(args.str("artifacts"));
    let mut checked = 0;
    for (len, chunk, k, sign) in [
        (16384usize, 64usize, 8usize, true),
        (16384, 64, 8, false),
        (16384, 32, 4, true),
        (16384, 128, 16, true),
    ] {
        let name = format!(
            "dct_extract_{len}_c{chunk}_k{k}{}",
            if sign { "_sign" } else { "" }
        );
        let path = dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            println!("skip {name} (artifact missing)");
            continue;
        }
        let art = rt.load_hlo(&path)?;
        let mut rng = detonation::util::rng::Rng::new(42);
        let m: Vec<f32> = (0..len).map(|_| rng.normal_f32(1.0)).collect();
        let outs = art.execute_vec(&m)?;
        anyhow::ensure!(outs.len() == 2, "{name}: expected (q, m_next)");

        // Rust-native extraction (the hot path implementation).
        let mut buf = m.clone();
        let mut repl = detonation::replicate::DemoReplicator::new(
            chunk,
            k,
            sign,
            detonation::tensor::Dtype::F32,
        );
        use detonation::replicate::{ReplCtx, Replicator};
        let ctx = ReplCtx {
            step: 0,
            shard: 0,
            seed: 0,
        };
        let mut scratch = detonation::compress::Scratch::new();
        let (q_rust, _) = repl.extract(&ctx, &mut buf, &mut scratch);
        let max_q = outs[0]
            .iter()
            .zip(&q_rust)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let max_m = outs[1]
            .iter()
            .zip(&buf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        anyhow::ensure!(
            max_q < 2e-3 && max_m < 2e-3,
            "{name}: mismatch q={max_q} m={max_m}"
        );
        println!("{name}: OK (max |Δq|={max_q:.2e}, max |Δm|={max_m:.2e})");
        checked += 1;
    }
    anyhow::ensure!(checked > 0, "no extraction artifacts found in {dir:?}");
    println!("cross-validation passed for {checked} artifact(s)");
    Ok(())
}

fn cmd_models(argv: &[String]) -> Result<()> {
    let args = ArgParser::new("detonation models", "list available model artifacts")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse(argv);
    let dir = std::path::PathBuf::from(args.str("artifacts"));
    let mut found = false;
    let mut entries: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().to_string())
        .collect();
    entries.sort();
    for name in entries {
        if let Some(base) = name.strip_suffix(".meta.json") {
            let meta = std::fs::read_to_string(dir.join(&name))?;
            let m = detonation::runtime::Manifest::parse(&meta)?;
            println!(
                "{base:<16} family={:<8} params={:>12} batch={}x{}",
                m.family, m.param_count, m.batch, m.seq
            );
            found = true;
        }
    }
    if !found {
        println!("no artifacts in {dir:?} — run `make artifacts`");
    }
    Ok(())
}
