//! FSDP-style flat-parameter sharding + the FlexDeMo hybrid mesh.
//!
//! PyTorch FSDP flattens a wrapped module's parameters into one
//! contiguous buffer and splits it evenly across the sharding group; we do
//! the same: `FlatLayout` maps named tensors into a flat buffer (manifest
//! order), and `ShardSpec` cuts the (padded) buffer into |S| equal ranges.
//!
//! Padding: shard lengths are rounded up to a multiple of
//! [`SHARD_ALIGN`] = 768 = lcm{16,32,64,96,128,192,256} so every chunk
//! size in the paper's Fig 11 sweep divides every shard exactly — the DeMo
//! replicator never sees a ragged tail chunk.
//!
//! The hybrid mesh (paper Appendix A): rank (node n, accel a) shards
//! within its node (group S = all accels of node n) and replicates with
//! the ranks holding *the same shard index* on other nodes (group R =
//! accel a of every node). |R|=1 degrades to pure FSDP, |S|=1 to DeMo-DDP.

use crate::net::Topology;

/// Pad shards so all paper chunk sizes divide them: lcm(16..256 sweep).
pub const SHARD_ALIGN: usize = 768;

/// One named tensor's slot in the flat buffer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlatSlot {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Flat packing of a parameter list (manifest order).
#[derive(Clone, Debug)]
pub struct FlatLayout {
    pub slots: Vec<FlatSlot>,
    /// Unpadded logical length (sum of tensor sizes).
    pub logical_len: usize,
    /// Padded length (multiple of `SHARD_ALIGN · shards` when sharded via
    /// `ShardSpec::even`).
    pub padded_len: usize,
}

impl FlatLayout {
    pub fn new(params: &[(String, Vec<usize>)]) -> FlatLayout {
        let mut slots = Vec::with_capacity(params.len());
        let mut offset = 0usize;
        for (name, shape) in params {
            let len = shape.iter().product();
            slots.push(FlatSlot {
                name: name.clone(),
                shape: shape.clone(),
                offset,
                len,
            });
            offset += len;
        }
        FlatLayout {
            slots,
            logical_len: offset,
            padded_len: offset, // finalized by `pad_for`
        }
    }

    /// Round the padded length up so `shards` equal shards are each a
    /// multiple of `SHARD_ALIGN`.
    pub fn pad_for(mut self, shards: usize) -> FlatLayout {
        let unit = SHARD_ALIGN * shards.max(1);
        self.padded_len = self.logical_len.div_ceil(unit) * unit;
        self
    }

    pub fn slot(&self, name: &str) -> Option<&FlatSlot> {
        self.slots.iter().find(|s| s.name == name)
    }

    /// View of one tensor inside a flat buffer.
    pub fn tensor<'a>(&self, flat: &'a [f32], name: &str) -> Option<&'a [f32]> {
        let s = self.slot(name)?;
        Some(&flat[s.offset..s.offset + s.len])
    }
}

/// Even partition of `[0, padded_len)` into `count` contiguous ranges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub ranges: Vec<(usize, usize)>,
    pub padded_len: usize,
}

impl ShardSpec {
    pub fn even(padded_len: usize, count: usize) -> ShardSpec {
        assert!(count >= 1);
        assert_eq!(
            padded_len % (SHARD_ALIGN * count),
            0,
            "padded_len {padded_len} not aligned for {count} shards — call FlatLayout::pad_for"
        );
        let per = padded_len / count;
        ShardSpec {
            ranges: (0..count).map(|i| (i * per, (i + 1) * per)).collect(),
            padded_len,
        }
    }

    pub fn count(&self) -> usize {
        self.ranges.len()
    }

    pub fn range(&self, shard: usize) -> (usize, usize) {
        self.ranges[shard]
    }

    pub fn shard_len(&self) -> usize {
        let (lo, hi) = self.ranges[0];
        hi - lo
    }

    /// Which shard owns flat index `i`.
    pub fn owner_of(&self, i: usize) -> usize {
        assert!(i < self.padded_len);
        i / self.shard_len()
    }
}

/// The full FlexDeMo process mesh: topology × shard layout.
#[derive(Clone, Debug)]
pub struct HybridMesh {
    pub topo: Topology,
    pub shards: ShardSpec,
}

impl HybridMesh {
    pub fn new(topo: Topology, layout: &FlatLayout) -> HybridMesh {
        let shards = ShardSpec::even(layout.padded_len, topo.accels_per_node);
        HybridMesh { topo, shards }
    }

    /// Shard range owned by a rank (determined by its accel index).
    pub fn shard_of(&self, rank: usize) -> (usize, usize) {
        self.shards.range(self.topo.accel_of(rank))
    }

    /// The ranks that replicate shard index `a` (R-group of accel a).
    pub fn repl_group_of_shard(&self, a: usize) -> Vec<usize> {
        self.topo.repl_group(self.topo.rank(0, a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, proptest};

    fn params(sizes: &[usize]) -> Vec<(String, Vec<usize>)> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| (format!("p{i}"), vec![s]))
            .collect()
    }

    #[test]
    fn layout_offsets_are_contiguous() {
        let l = FlatLayout::new(&params(&[10, 20, 30]));
        assert_eq!(l.logical_len, 60);
        assert_eq!(l.slots[0].offset, 0);
        assert_eq!(l.slots[1].offset, 10);
        assert_eq!(l.slots[2].offset, 30);
    }

    #[test]
    fn layout_handles_multidim_shapes() {
        let l = FlatLayout::new(&[
            ("w".into(), vec![4, 8]),
            ("b".into(), vec![8]),
        ]);
        assert_eq!(l.logical_len, 40);
        assert_eq!(l.slot("b").unwrap().offset, 32);
    }

    #[test]
    fn padding_makes_aligned_shards() {
        for shards in [1usize, 2, 3, 4, 8] {
            let l = FlatLayout::new(&params(&[1000, 37])).pad_for(shards);
            assert_eq!(l.padded_len % (SHARD_ALIGN * shards), 0);
            assert!(l.padded_len >= l.logical_len);
            assert!(l.padded_len - l.logical_len < SHARD_ALIGN * shards);
            let spec = ShardSpec::even(l.padded_len, shards);
            assert_eq!(spec.shard_len() % SHARD_ALIGN, 0);
        }
    }

    #[test]
    fn shards_partition_range_property() {
        proptest(64, |g| {
            let shards = g.usize(1, 9);
            let len = g.usize(1, 100_000);
            let l = FlatLayout::new(&params(&[len])).pad_for(shards);
            let spec = ShardSpec::even(l.padded_len, shards);
            // union of ranges = [0, padded), disjoint, ordered
            let mut cursor = 0;
            for &(lo, hi) in &spec.ranges {
                prop_assert(lo == cursor, format!("gap at {lo}"));
                prop_assert(hi > lo, "empty shard");
                cursor = hi;
            }
            prop_assert(cursor == l.padded_len, "ranges don't cover");
            // owner_of agrees with ranges
            for s in 0..shards {
                let (lo, hi) = spec.range(s);
                prop_assert(spec.owner_of(lo) == s, "owner lo");
                prop_assert(spec.owner_of(hi - 1) == s, "owner hi-1");
            }
        });
    }

    #[test]
    fn every_chunk_size_divides_shards() {
        let l = FlatLayout::new(&params(&[12345])).pad_for(4);
        let spec = ShardSpec::even(l.padded_len, 4);
        for chunk in [16usize, 32, 64, 96, 128, 192, 256] {
            assert_eq!(spec.shard_len() % chunk, 0, "chunk {chunk}");
        }
    }

    #[test]
    fn tensor_view_reads_correct_slice() {
        let l = FlatLayout::new(&params(&[3, 2]));
        let flat = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(l.tensor(&flat, "p0").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(l.tensor(&flat, "p1").unwrap(), &[4.0, 5.0]);
        assert!(l.tensor(&flat, "nope").is_none());
    }

    #[test]
    fn hybrid_mesh_shard_by_accel_index() {
        let topo = Topology::new(2, 4);
        let l = FlatLayout::new(&params(&[10_000])).pad_for(4);
        let mesh = HybridMesh::new(topo, &l);
        // same accel index on both nodes owns the same range
        for a in 0..4 {
            let r0 = mesh.shard_of(mesh.topo.rank(0, a));
            let r1 = mesh.shard_of(mesh.topo.rank(1, a));
            assert_eq!(r0, r1);
            assert_eq!(mesh.repl_group_of_shard(a), vec![a, 4 + a]);
        }
    }

    #[test]
    fn degenerate_meshes() {
        // |R| = 1 (single node): pure FSDP.
        let l = FlatLayout::new(&params(&[5000])).pad_for(4);
        let mesh = HybridMesh::new(Topology::new(1, 4), &l);
        assert_eq!(mesh.repl_group_of_shard(0), vec![0]);
        // |S| = 1 (one accel per node): DeMo-style DDP.
        let l = FlatLayout::new(&params(&[5000])).pad_for(1);
        let mesh = HybridMesh::new(Topology::new(4, 1), &l);
        assert_eq!(mesh.shard_of(2), (0, l.padded_len));
        assert_eq!(mesh.repl_group_of_shard(0), vec![0, 1, 2, 3]);
    }
}
