//! Explicitly unrolled SIMD-width lane primitives for the chunk kernels.
//!
//! Every hot sweep in the data plane (optimizer steps, collective
//! accumulations, DCT butterflies, the residual scatter, the eval
//! reduction) runs over the fixed 16Ki-element grid of
//! [`crate::parallel::CHUNK`]. This module supplies the lane-level inner
//! loops for those sweeps: fixed-width value types ([`F32x8`], [`F64x4`])
//! whose elementwise operators are written as straight-line per-lane
//! loops the compiler fully unrolls and vectorizes, plus free slice
//! kernels (`axpy`, `scale`, `decay_step`, …) that walk a slice one lane
//! block at a time with a scalar tail.
//!
//! The types are std-only manual unrolling today, but deliberately shaped
//! like `std::simd::Simd<f32, 8>` / `Simd<f64, 4>` (`splat`, slice
//! load/store, arithmetic via `std::ops`) so the portable-SIMD types can
//! drop in when they stabilize.
//!
//! # Numeric contract
//!
//! Every f32 kernel here is **bit-identical** to its scalar loop: the
//! per-element float chain (operand order and association) is exactly the
//! one the pre-lane scalar sweep performed, and lanes only change *which*
//! elements are in flight together, never how any single element is
//! computed. This is pinned by the tail tests below at every length in
//! `0..4·LANE` and across `CHUNK` boundaries, against the
//! autovectorization-proof references in [`scalar`].
//!
//! The one exception is [`sq_dev_half_sum`], the eval reduction: a
//! horizontal f64 sum has a serial dependence chain, so vectorizing it
//! *requires* reassociation. It takes the same one-time, thereafter
//! length-invariant reassociation the chunk grid itself took when eval
//! went chunk-parallel: [`F64_LANES`] lane accumulators striped over
//! consecutive elements, folded in lane order, scalar tail appended. The
//! exact association is documented on the function and pinned by a test.

use std::ops::{Add, Div, Mul, Sub};

/// Lane width of [`F32x8`]: f32 elements processed per unrolled step.
pub const F32_LANES: usize = 8;

/// Lane width of [`F64x4`]: f64 elements processed per unrolled step.
pub const F64_LANES: usize = 4;

/// Eight f32 lanes, processed elementwise by every operator.
///
/// `#[repr(transparent)]` over `[f32; 8]` — the same layout
/// `std::simd::Simd<f32, 8>` guarantees, so the port is a type swap.
#[derive(Clone, Copy, Debug)]
#[repr(transparent)]
pub struct F32x8(pub [f32; F32_LANES]);

/// Four f64 lanes, processed elementwise by every operator.
///
/// Carries the reversed/interleaving loads the blocked DCT butterflies
/// need in addition to the plain elementwise surface.
#[derive(Clone, Copy, Debug)]
#[repr(transparent)]
pub struct F64x4(pub [f64; F64_LANES]);

impl F32x8 {
    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; F32_LANES])
    }

    /// Load the first [`F32_LANES`] elements of `s` (panics if shorter).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        F32x8(s[..F32_LANES].try_into().unwrap())
    }

    /// Store the lanes into the first [`F32_LANES`] elements of `s`.
    #[inline(always)]
    pub fn store(self, s: &mut [f32]) {
        s[..F32_LANES].copy_from_slice(&self.0);
    }

    /// Per-lane `sqrt`.
    #[inline(always)]
    pub fn sqrt(self) -> F32x8 {
        let mut r = self.0;
        for v in r.iter_mut() {
            *v = v.sqrt();
        }
        F32x8(r)
    }
}

impl Add for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn add(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a += b;
        }
        F32x8(r)
    }
}

impl Sub for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn sub(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a -= b;
        }
        F32x8(r)
    }
}

impl Mul for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn mul(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a *= b;
        }
        F32x8(r)
    }
}

impl Div for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn div(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a /= b;
        }
        F32x8(r)
    }
}

impl F64x4 {
    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f64) -> F64x4 {
        F64x4([v; F64_LANES])
    }

    /// Load the first [`F64_LANES`] elements of `s` (panics if shorter).
    #[inline(always)]
    pub fn load(s: &[f64]) -> F64x4 {
        F64x4(s[..F64_LANES].try_into().unwrap())
    }

    /// Load the first [`F64_LANES`] elements of `s` in reverse order:
    /// lane `j` gets `s[F64_LANES - 1 - j]`. This is the mirrored read of
    /// the DCT-II butterfly (`b = cur[m - 1 - i]`).
    #[inline(always)]
    pub fn load_rev(s: &[f64]) -> F64x4 {
        let mut r = [0.0; F64_LANES];
        for (j, v) in r.iter_mut().enumerate() {
            *v = s[F64_LANES - 1 - j];
        }
        F64x4(r)
    }

    /// Store the lanes into the first [`F64_LANES`] elements of `s`.
    #[inline(always)]
    pub fn store(self, s: &mut [f64]) {
        s[..F64_LANES].copy_from_slice(&self.0);
    }

    /// Store the lanes reversed: `s[F64_LANES - 1 - j] = lane j`. The
    /// mirrored write of the DCT-III butterfly (`nxt[m - 1 - i] = …`).
    #[inline(always)]
    pub fn store_rev(self, s: &mut [f64]) {
        for (j, &v) in self.0.iter().enumerate() {
            s[F64_LANES - 1 - j] = v;
        }
    }

    /// Interleave lanes with `o`: returns
    /// `([a0, b0, a1, b1], [a2, b2, a3, b3])` — the even/odd zip of the
    /// DCT-II recombination pass.
    #[inline(always)]
    pub fn interleave(self, o: F64x4) -> (F64x4, F64x4) {
        let a = self.0;
        let b = o.0;
        (
            F64x4([a[0], b[0], a[1], b[1]]),
            F64x4([a[2], b[2], a[3], b[3]]),
        )
    }

    /// De-interleave two adjacent lane blocks: for consecutive memory
    /// `[x0..x3] = self`, `[x4..x7] = o`, returns the even-index lanes
    /// `[x0, x2, x4, x6]` and the odd-index lanes `[x1, x3, x5, x7]` —
    /// the split of the DCT-III de-interleave pass.
    #[inline(always)]
    pub fn deinterleave(self, o: F64x4) -> (F64x4, F64x4) {
        let a = self.0;
        let b = o.0;
        (
            F64x4([a[0], a[2], b[0], b[2]]),
            F64x4([a[1], a[3], b[1], b[3]]),
        )
    }
}

impl Add for F64x4 {
    type Output = F64x4;
    #[inline(always)]
    fn add(self, o: F64x4) -> F64x4 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a += b;
        }
        F64x4(r)
    }
}

impl Sub for F64x4 {
    type Output = F64x4;
    #[inline(always)]
    fn sub(self, o: F64x4) -> F64x4 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a -= b;
        }
        F64x4(r)
    }
}

impl Mul for F64x4 {
    type Output = F64x4;
    #[inline(always)]
    fn mul(self, o: F64x4) -> F64x4 {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a *= b;
        }
        F64x4(r)
    }
}

/// Constants shared by the fused Adam-family sweeps ([`adamw_step`],
/// [`dadamw_accum`]): moment decays and the step-`t` bias corrections
/// `bc1 = 1 - beta1^t`, `bc2 = 1 - beta2^t`.
#[derive(Clone, Copy, Debug)]
pub struct AdamConsts {
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// First-moment bias correction `1 - beta1^t`.
    pub bc1: f32,
    /// Second-moment bias correction `1 - beta2^t`.
    pub bc2: f32,
    /// Denominator fuzz.
    pub eps: f32,
}

/// `y[i] += alpha * x[i]` — the hot axpy, eight elements per step.
/// Bit-identical to the scalar loop at every length.
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let va = F32x8::splat(alpha);
    let blocks = y.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        (F32x8::load(&y[i..]) + va * F32x8::load(&x[i..])).store(&mut y[i..]);
        i += F32_LANES;
    }
    for (yi, &xi) in y[blocks..].iter_mut().zip(&x[blocks..]) {
        *yi += alpha * xi;
    }
}

/// `y[i] *= alpha` — the averaging rescale in collectives and
/// `mean_into`. Bit-identical to the scalar loop at every length.
pub fn scale(y: &mut [f32], alpha: f32) {
    let va = F32x8::splat(alpha);
    let blocks = y.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        (F32x8::load(&y[i..]) * va).store(&mut y[i..]);
        i += F32_LANES;
    }
    for yi in &mut y[blocks..] {
        *yi *= alpha;
    }
}

/// `y[i] -= x[i]` — the DeMo residual subtract after decode.
/// Bit-identical to the scalar loop at every length.
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let blocks = y.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        (F32x8::load(&y[i..]) - F32x8::load(&x[i..])).store(&mut y[i..]);
        i += F32_LANES;
    }
    for (yi, &xi) in y[blocks..].iter_mut().zip(&x[blocks..]) {
        *yi -= xi;
    }
}

/// Fused decoupled-weight-decay step: `p[i] = p[i] * decay - lr * q[i]`
/// (the single-sweep kernel behind every SGD-family `apply`).
/// Bit-identical to the scalar loop at every length.
pub fn decay_step(p: &mut [f32], decay: f32, lr: f32, q: &[f32]) {
    debug_assert_eq!(p.len(), q.len());
    let vd = F32x8::splat(decay);
    let vlr = F32x8::splat(lr);
    let blocks = p.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        (F32x8::load(&p[i..]) * vd - vlr * F32x8::load(&q[i..])).store(&mut p[i..]);
        i += F32_LANES;
    }
    for (pi, &qi) in p[blocks..].iter_mut().zip(&q[blocks..]) {
        *pi = *pi * decay - lr * qi;
    }
}

/// DeMo momentum decay-and-accumulate: `m[i] = beta * m[i] + g[i]`.
/// Bit-identical to the scalar loop at every length.
pub fn momentum(m: &mut [f32], beta: f32, g: &[f32]) {
    debug_assert_eq!(m.len(), g.len());
    let vb = F32x8::splat(beta);
    let blocks = m.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        (vb * F32x8::load(&m[i..]) + F32x8::load(&g[i..])).store(&mut m[i..]);
        i += F32_LANES;
    }
    for (mi, &gi) in m[blocks..].iter_mut().zip(&g[blocks..]) {
        *mi = beta * *mi + gi;
    }
}

/// Fused AdamW sweep: moment update, bias correction, decoupled weight
/// decay, and parameter step in one pass:
///
/// ```text
/// m1 = beta1 * m1 + (1 - beta1) * g
/// m2 = beta2 * m2 + (1 - beta2) * g * g
/// if wd > 0 { p *= 1 - lr * wd }
/// p -= lr * (m1 / bc1) / (sqrt(m2 / bc2) + eps)
/// ```
///
/// Bit-identical to the scalar loop at every length (the `wd` branch is
/// uniform across the sweep, so hoisting it changes no float op).
pub fn adamw_step(
    m1: &mut [f32],
    m2: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    c: AdamConsts,
    lr: f32,
    wd: f32,
) {
    debug_assert_eq!(m1.len(), g.len());
    debug_assert_eq!(m2.len(), g.len());
    debug_assert_eq!(p.len(), g.len());
    let vb1 = F32x8::splat(c.beta1);
    let vb2 = F32x8::splat(c.beta2);
    let vc1 = F32x8::splat(1.0 - c.beta1);
    let vc2 = F32x8::splat(1.0 - c.beta2);
    let vbc1 = F32x8::splat(c.bc1);
    let vbc2 = F32x8::splat(c.bc2);
    let veps = F32x8::splat(c.eps);
    let vlr = F32x8::splat(lr);
    let vdecay = F32x8::splat(1.0 - lr * wd);
    let blocks = g.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        let gv = F32x8::load(&g[i..]);
        let nm1 = vb1 * F32x8::load(&m1[i..]) + vc1 * gv;
        let nm2 = vb2 * F32x8::load(&m2[i..]) + vc2 * gv * gv;
        nm1.store(&mut m1[i..]);
        nm2.store(&mut m2[i..]);
        let mhat = nm1.div(vbc1);
        let vhat = nm2.div(vbc2);
        let mut pv = F32x8::load(&p[i..]);
        if wd > 0.0 {
            pv = pv * vdecay;
        }
        (pv - vlr * mhat / (vhat.sqrt() + veps)).store(&mut p[i..]);
        i += F32_LANES;
    }
    for i in blocks..g.len() {
        let gv = g[i];
        m1[i] = c.beta1 * m1[i] + (1.0 - c.beta1) * gv;
        m2[i] = c.beta2 * m2[i] + (1.0 - c.beta2) * gv * gv;
        let mhat = m1[i] / c.bc1;
        let vhat = m2[i] / c.bc2;
        if wd > 0.0 {
            p[i] *= 1.0 - lr * wd;
        }
        p[i] -= lr * mhat / (vhat.sqrt() + c.eps);
    }
}

/// Decoupled-AdamW accumulate sweep: the Adam moment update plus the
/// bias-corrected update accumulated into `buf` (the parameter step
/// happens later in [`decay_step`]):
///
/// ```text
/// m1 = beta1 * m1 + (1 - beta1) * g
/// m2 = beta2 * m2 + (1 - beta2) * g * g
/// buf += (m1 / bc1) / (sqrt(m2 / bc2) + eps)
/// ```
///
/// Bit-identical to the scalar loop at every length.
pub fn dadamw_accum(m1: &mut [f32], m2: &mut [f32], buf: &mut [f32], g: &[f32], c: AdamConsts) {
    debug_assert_eq!(m1.len(), g.len());
    debug_assert_eq!(m2.len(), g.len());
    debug_assert_eq!(buf.len(), g.len());
    let vb1 = F32x8::splat(c.beta1);
    let vb2 = F32x8::splat(c.beta2);
    let vc1 = F32x8::splat(1.0 - c.beta1);
    let vc2 = F32x8::splat(1.0 - c.beta2);
    let vbc1 = F32x8::splat(c.bc1);
    let vbc2 = F32x8::splat(c.bc2);
    let veps = F32x8::splat(c.eps);
    let blocks = g.len() / F32_LANES * F32_LANES;
    let mut i = 0;
    while i < blocks {
        let gv = F32x8::load(&g[i..]);
        let nm1 = vb1 * F32x8::load(&m1[i..]) + vc1 * gv;
        let nm2 = vb2 * F32x8::load(&m2[i..]) + vc2 * gv * gv;
        nm1.store(&mut m1[i..]);
        nm2.store(&mut m2[i..]);
        let mhat = nm1.div(vbc1);
        let vhat = nm2.div(vbc2);
        (F32x8::load(&buf[i..]) + mhat / (vhat.sqrt() + veps)).store(&mut buf[i..]);
        i += F32_LANES;
    }
    for i in blocks..g.len() {
        let gv = g[i];
        m1[i] = c.beta1 * m1[i] + (1.0 - c.beta1) * gv;
        m2[i] = c.beta2 * m2[i] + (1.0 - c.beta2) * gv * gv;
        let mhat = m1[i] / c.bc1;
        let vhat = m2[i] / c.bc2;
        buf[i] += mhat / (vhat.sqrt() + c.eps);
    }
}

/// Lane-parallel eval reduction: `sum_i 0.5 * ((p[i] - t[i]) as f64)^2`.
///
/// **This is the one reassociated kernel in the module** — a horizontal
/// f64 sum is a serial dependence chain, so vectorizing it requires
/// changing the association. The lane order is fixed by the slice length
/// alone (never by thread count or hardware):
///
/// 1. [`F64_LANES`] accumulators are striped over consecutive
///    4-element blocks (lane `j` accumulates elements `4k + j`);
/// 2. the lanes are folded left to right
///    (`((l0 + l1) + l2) + l3`);
/// 3. the tail elements (`len % 4`) are added sequentially, in order.
///
/// The per-element term `0.5 * dev * dev` with `dev = (p - t) as f64`
/// (f32 subtract, then widen) is unchanged from the scalar sweep. Like
/// the chunk-grid reassociation before it, this moves validation losses
/// by last-bit amounts exactly once; results remain invariant across
/// thread counts thereafter. Pinned by
/// `sq_dev_half_sum_matches_documented_lane_order`.
pub fn sq_dev_half_sum(p: &[f32], t: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), t.len());
    let blocks = p.len() / F64_LANES * F64_LANES;
    let mut acc = [0.0f64; F64_LANES];
    let mut i = 0;
    while i < blocks {
        for j in 0..F64_LANES {
            let dev = (p[i + j] - t[i + j]) as f64;
            acc[j] += 0.5 * dev * dev;
        }
        i += F64_LANES;
    }
    let mut total = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for (&pv, &tv) in p[blocks..].iter().zip(&t[blocks..]) {
        let dev = (pv - tv) as f64;
        total += 0.5 * dev * dev;
    }
    total
}

/// Strict one-element-at-a-time reference sweeps.
///
/// Each function here computes the *same float chain* as its lane
/// counterpart's scalar tail — the pre-lane kernels verbatim — but the
/// loop index is passed through [`std::hint::black_box`] on every
/// iteration. The opaque index defeats the auto-vectorizer (the compiler
/// cannot prove consecutive accesses), pinning a genuine scalar sweep
/// without altering a single float operation. Two users:
///
/// - the tail tests in this module, as the bit-identity reference;
/// - `benches/kernels.rs`, as the scalar arm of `lane_speedup` — so the
///   ≥2× gate measures lanes against real scalar code, not against
///   whatever the auto-vectorizer did to a plain loop.
#[allow(clippy::needless_range_loop)] // indices are deliberately explicit
pub mod scalar {
    use std::hint::black_box;

    use super::AdamConsts;

    /// Strict scalar `y[i] += alpha * x[i]` (see [`super::axpy`]).
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for i in 0..y.len() {
            let i = black_box(i);
            y[i] += alpha * x[i];
        }
    }

    /// Strict scalar `y[i] *= alpha` (see [`super::scale`]).
    pub fn scale(y: &mut [f32], alpha: f32) {
        for i in 0..y.len() {
            let i = black_box(i);
            y[i] *= alpha;
        }
    }

    /// Strict scalar `y[i] -= x[i]` (see [`super::sub_assign`]).
    pub fn sub_assign(y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for i in 0..y.len() {
            let i = black_box(i);
            y[i] -= x[i];
        }
    }

    /// Strict scalar fused decay step (see [`super::decay_step`]).
    pub fn decay_step(p: &mut [f32], decay: f32, lr: f32, q: &[f32]) {
        debug_assert_eq!(p.len(), q.len());
        for i in 0..p.len() {
            let i = black_box(i);
            p[i] = p[i] * decay - lr * q[i];
        }
    }

    /// Strict scalar momentum sweep (see [`super::momentum`]).
    pub fn momentum(m: &mut [f32], beta: f32, g: &[f32]) {
        debug_assert_eq!(m.len(), g.len());
        for i in 0..m.len() {
            let i = black_box(i);
            m[i] = beta * m[i] + g[i];
        }
    }

    /// Strict scalar fused AdamW sweep (see [`super::adamw_step`]).
    pub fn adamw_step(
        m1: &mut [f32],
        m2: &mut [f32],
        p: &mut [f32],
        g: &[f32],
        c: AdamConsts,
        lr: f32,
        wd: f32,
    ) {
        debug_assert_eq!(m1.len(), g.len());
        debug_assert_eq!(m2.len(), g.len());
        debug_assert_eq!(p.len(), g.len());
        for i in 0..g.len() {
            let i = black_box(i);
            let gv = g[i];
            m1[i] = c.beta1 * m1[i] + (1.0 - c.beta1) * gv;
            m2[i] = c.beta2 * m2[i] + (1.0 - c.beta2) * gv * gv;
            let mhat = m1[i] / c.bc1;
            let vhat = m2[i] / c.bc2;
            if wd > 0.0 {
                p[i] *= 1.0 - lr * wd;
            }
            p[i] -= lr * mhat / (vhat.sqrt() + c.eps);
        }
    }

    /// Strict scalar decoupled-AdamW accumulate (see
    /// [`super::dadamw_accum`]).
    pub fn dadamw_accum(m1: &mut [f32], m2: &mut [f32], buf: &mut [f32], g: &[f32], c: AdamConsts) {
        debug_assert_eq!(m1.len(), g.len());
        debug_assert_eq!(m2.len(), g.len());
        debug_assert_eq!(buf.len(), g.len());
        for i in 0..g.len() {
            let i = black_box(i);
            let gv = g[i];
            m1[i] = c.beta1 * m1[i] + (1.0 - c.beta1) * gv;
            m2[i] = c.beta2 * m2[i] + (1.0 - c.beta2) * gv * gv;
            let mhat = m1[i] / c.bc1;
            let vhat = m2[i] / c.bc2;
            buf[i] += mhat / (vhat.sqrt() + c.eps);
        }
    }

    /// Strict sequential eval reduction — the pre-lane per-chunk sweep
    /// (serial f64 chain; compare [`super::sq_dev_half_sum`], which
    /// reassociates).
    pub fn sq_dev_half_sum(p: &[f32], t: &[f32]) -> f64 {
        debug_assert_eq!(p.len(), t.len());
        let mut acc = 0.0f64;
        for i in 0..p.len() {
            let i = black_box(i);
            let dev = (p[i] - t[i]) as f64;
            acc += 0.5 * dev * dev;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random data with varied magnitudes (sign
    /// flips, scale spread) so bit mismatches cannot hide.
    fn data(seed: u32, len: usize) -> Vec<f32> {
        let mut state = seed.wrapping_mul(2654435761).wrapping_add(12345);
        (0..len)
            .map(|_| {
                state = state.wrapping_mul(1664525).wrapping_add(1013904223);
                // map to roughly [-2, 2) with a full mantissa in play
                (state as f32 / u32::MAX as f32) * 4.0 - 2.0
            })
            .collect()
    }

    fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: element {i} {a} vs {b}");
        }
    }

    /// Every tail length through four full lane blocks, plus lengths
    /// straddling the parallel grid's CHUNK boundary (the sizes the
    /// pooled kernels actually hand to these sweeps).
    fn lengths() -> Vec<usize> {
        let mut v: Vec<usize> = (0..4 * F32_LANES).collect();
        let c = crate::parallel::CHUNK;
        v.extend([c - 1, c, c + 1, 2 * c + 17]);
        v
    }

    const CONSTS: AdamConsts = AdamConsts {
        beta1: 0.9,
        beta2: 0.999,
        bc1: 0.271,
        bc2: 0.00997,
        eps: 1e-8,
    };

    #[test]
    fn axpy_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let x = data(1, len);
            let y0 = data(2, len);
            let mut want = y0.clone();
            scalar::axpy(&mut want, -0.3, &x);
            let mut got = y0.clone();
            axpy(&mut got, -0.3, &x);
            assert_bits_eq(&got, &want, &format!("axpy len={len}"));
        }
    }

    #[test]
    fn scale_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let y0 = data(3, len);
            let mut want = y0.clone();
            scalar::scale(&mut want, 1.0 / 3.0);
            let mut got = y0;
            scale(&mut got, 1.0 / 3.0);
            assert_bits_eq(&got, &want, &format!("scale len={len}"));
        }
    }

    #[test]
    fn sub_assign_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let x = data(4, len);
            let y0 = data(5, len);
            let mut want = y0.clone();
            scalar::sub_assign(&mut want, &x);
            let mut got = y0;
            sub_assign(&mut got, &x);
            assert_bits_eq(&got, &want, &format!("sub_assign len={len}"));
        }
    }

    #[test]
    fn decay_step_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let q = data(6, len);
            let p0 = data(7, len);
            let mut want = p0.clone();
            scalar::decay_step(&mut want, 0.999, 0.01, &q);
            let mut got = p0;
            decay_step(&mut got, 0.999, 0.01, &q);
            assert_bits_eq(&got, &want, &format!("decay_step len={len}"));
        }
    }

    #[test]
    fn momentum_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let g = data(8, len);
            let m0 = data(9, len);
            let mut want = m0.clone();
            scalar::momentum(&mut want, 0.95, &g);
            let mut got = m0;
            momentum(&mut got, 0.95, &g);
            assert_bits_eq(&got, &want, &format!("momentum len={len}"));
        }
    }

    #[test]
    fn adamw_step_bit_matches_scalar_at_every_tail_length() {
        for wd in [0.0f32, 0.01] {
            for len in lengths() {
                let g = data(10, len);
                let m1_0 = data(11, len);
                // second moments must be non-negative for sqrt
                let m2_0: Vec<f32> = data(12, len).iter().map(|v| v.abs()).collect();
                let p0 = data(13, len);
                let (mut wm1, mut wm2, mut wp) = (m1_0.clone(), m2_0.clone(), p0.clone());
                scalar::adamw_step(&mut wm1, &mut wm2, &mut wp, &g, CONSTS, 0.01, wd);
                let (mut gm1, mut gm2, mut gp) = (m1_0, m2_0, p0);
                adamw_step(&mut gm1, &mut gm2, &mut gp, &g, CONSTS, 0.01, wd);
                let ctx = format!("adamw len={len} wd={wd}");
                assert_bits_eq(&gm1, &wm1, &format!("{ctx} m1"));
                assert_bits_eq(&gm2, &wm2, &format!("{ctx} m2"));
                assert_bits_eq(&gp, &wp, &format!("{ctx} p"));
            }
        }
    }

    #[test]
    fn dadamw_accum_bit_matches_scalar_at_every_tail_length() {
        for len in lengths() {
            let g = data(14, len);
            let m1_0 = data(15, len);
            let m2_0: Vec<f32> = data(16, len).iter().map(|v| v.abs()).collect();
            let b0 = data(17, len);
            let (mut wm1, mut wm2, mut wb) = (m1_0.clone(), m2_0.clone(), b0.clone());
            scalar::dadamw_accum(&mut wm1, &mut wm2, &mut wb, &g, CONSTS);
            let (mut gm1, mut gm2, mut gb) = (m1_0, m2_0, b0);
            dadamw_accum(&mut gm1, &mut gm2, &mut gb, &g, CONSTS);
            let ctx = format!("dadamw len={len}");
            assert_bits_eq(&gm1, &wm1, &format!("{ctx} m1"));
            assert_bits_eq(&gm2, &wm2, &format!("{ctx} m2"));
            assert_bits_eq(&gb, &wb, &format!("{ctx} buf"));
        }
    }

    /// The scalar reference module really is the plain loop: black_box
    /// on the index changes codegen, never values.
    #[test]
    fn scalar_reference_is_the_plain_loop() {
        let x = data(18, 1001);
        let y0 = data(19, 1001);
        let mut a = y0.clone();
        scalar::axpy(&mut a, 0.7, &x);
        let mut b = y0;
        for (yi, &xi) in b.iter_mut().zip(&x) {
            *yi += 0.7 * xi;
        }
        assert_bits_eq(&a, &b, "scalar::axpy vs plain loop");
    }

    /// Pin the documented association of the one reassociated kernel:
    /// four lane accumulators over consecutive 4-element blocks, folded
    /// left to right, tail appended sequentially.
    #[test]
    fn sq_dev_half_sum_matches_documented_lane_order() {
        for len in lengths() {
            let p = data(20, len);
            let t = data(21, len);
            let blocks = len / F64_LANES * F64_LANES;
            let mut acc = [0.0f64; F64_LANES];
            let mut i = 0;
            while i < blocks {
                for (j, a) in acc.iter_mut().enumerate() {
                    let dev = (p[i + j] - t[i + j]) as f64;
                    *a += 0.5 * dev * dev;
                }
                i += F64_LANES;
            }
            let mut want = ((acc[0] + acc[1]) + acc[2]) + acc[3];
            for j in blocks..len {
                let dev = (p[j] - t[j]) as f64;
                want += 0.5 * dev * dev;
            }
            let got = sq_dev_half_sum(&p, &t);
            assert_eq!(got.to_bits(), want.to_bits(), "len={len}: {got} vs {want}");
        }
    }

    /// On exactly-representable data the reassociation cannot change the
    /// value at all, so lane and strict-sequential sums agree exactly.
    #[test]
    fn sq_dev_half_sum_equals_sequential_on_exact_data() {
        let p: Vec<f32> = (0..103).map(|i| (i % 7) as f32).collect();
        let t = vec![0.0f32; 103];
        let want = scalar::sq_dev_half_sum(&p, &t);
        assert_eq!(sq_dev_half_sum(&p, &t), want);
        let direct: f64 = p.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum();
        assert_eq!(want, direct);
    }

    #[test]
    fn f64x4_shuffles() {
        let a = F64x4([1.0, 2.0, 3.0, 4.0]);
        let b = F64x4([5.0, 6.0, 7.0, 8.0]);
        assert_eq!(F64x4::load_rev(&[1.0, 2.0, 3.0, 4.0]).0, [4.0, 3.0, 2.0, 1.0]);
        let mut out = [0.0; 4];
        a.store_rev(&mut out);
        assert_eq!(out, [4.0, 3.0, 2.0, 1.0]);
        let (lo, hi) = a.interleave(b);
        assert_eq!(lo.0, [1.0, 5.0, 2.0, 6.0]);
        assert_eq!(hi.0, [3.0, 7.0, 4.0, 8.0]);
        let (ev, od) = a.deinterleave(b);
        assert_eq!(ev.0, [1.0, 3.0, 5.0, 7.0]);
        assert_eq!(od.0, [2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn f32x8_ops_elementwise() {
        let a = F32x8::splat(6.0);
        let b = F32x8::splat(2.0);
        assert_eq!((a + b).0, [8.0; 8]);
        assert_eq!((a - b).0, [4.0; 8]);
        assert_eq!((a * b).0, [12.0; 8]);
        assert_eq!((a / b).0, [3.0; 8]);
        assert_eq!(F32x8::splat(9.0).sqrt().0, [3.0; 8]);
    }
}
