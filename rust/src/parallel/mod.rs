//! Deterministic data-plane parallelism: a persistent worker pool plus
//! chunk-parallel primitives over a **fixed, thread-count-independent
//! chunk grid**.
//!
//! The trainer ([`crate::train::Trainer`]) creates one [`WorkerPool`]
//! from `--threads` and every numeric hot path — the collectives' ring
//! reductions ([`crate::collectives`]), the fused optimizer update
//! kernels ([`crate::optim`]), the surrogate eval loop, the DeMo
//! decode/residual scatter, the blocked DCT batches ([`crate::dct`]),
//! and the per-stream fwd/bwd fan-out — dispatches onto it. Workers are
//! spawned once and parked between jobs (no per-step
//! `std::thread::scope` re-spawn). Note this pool is the *host
//! wall-clock* axis; simulated time is owned by
//! [`crate::train::engine::StepEngine`] and the two never interact.
//!
//! ## Determinism contract
//!
//! `--threads N` must never change a single bit of any result (the
//! contract `train` documents and the integration suite prop-tests).
//! Two rules make that hold by construction:
//!
//! 1. **Work is partitioned on a fixed grid.** [`chunk_range`] cuts a
//!    flat buffer into [`CHUNK`]-element chunks whose boundaries depend
//!    only on the buffer length — never on the worker count. Each chunk
//!    is computed exactly as the scalar loop would compute that index
//!    range, so elementwise kernels are bit-identical at any width.
//! 2. **Reductions accumulate on the grid, not on the workers.**
//!    [`sum_chunks`] has each task write its partial into a slot indexed
//!    by *chunk id*; the partials are folded sequentially in chunk
//!    order. Which worker produced a partial is irrelevant.
//!
//! Per-worker scratch (e.g. the DCT arenas) is allowed because scratch
//! *contents* never reach an output — every user fully overwrites its
//! scratch before reading it.
//!
//! Inside each chunk, the sweeps themselves run on the explicitly
//! unrolled SIMD-width primitives of [`lanes`] (f32×8 / f64×4), which
//! preserve every contracted kernel's per-element float chain exactly —
//! so the vectorization is invisible to the determinism contract above.
//!
//! ## Zero allocations
//!
//! Dispatch allocates nothing: jobs are borrowed closures handed to the
//! workers through a mutex-guarded slot (the borrow is erased for the
//! duration of [`WorkerPool::run`], which blocks until every task has
//! retired, so no closure or slice outlives its frame). The steady-state
//! collectives and optimizer kernels running on the pool are
//! allocation-free end to end (asserted with a counting allocator in
//! `benches/kernels.rs`).

use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

pub mod lanes;

/// Fixed chunk size (elements) of the deterministic grid. Big enough
/// that per-task overhead vanishes, small enough that a handful of
/// chunks exist even for modest shards.
pub const CHUNK: usize = 1 << 14;

/// Number of grid chunks covering a buffer of `len` elements.
#[inline]
pub fn chunk_count(len: usize) -> usize {
    len.div_ceil(CHUNK)
}

/// Half-open element range of grid chunk `i` within a buffer of `len`.
#[inline]
pub fn chunk_range(len: usize, i: usize) -> (usize, usize) {
    let lo = i * CHUNK;
    (lo, ((i + 1) * CHUNK).min(len))
}

/// A job is a borrowed `Fn(worker, task)` whose lifetime is erased
/// (transmuted to `'static`) while it sits in the shared slot; `run`
/// keeps the real borrow alive until every task has retired, so the
/// erased reference never outlives the closure.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize, usize) + Sync),
    n_tasks: usize,
    next: usize,
    completed: usize,
    epoch: u64,
}

#[derive(Default)]
struct PoolState {
    job: Option<Job>,
    epoch: u64,
    shutdown: bool,
    panic_msg: Option<String>,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// Submitters park here until their job's tasks have all retired.
    done: Condvar,
}

thread_local! {
    /// Set while a pool worker (or a caller inside `run`) is executing
    /// tasks — nested `run` calls detect it and execute inline instead
    /// of deadlocking on the shared job slot.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Persistent worker pool. `width` execution slots: the submitting
/// thread is slot 0 and participates in every job; `width - 1` parked
/// worker threads fill slots `1..width`.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    width: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("width", &self.width).finish()
    }
}

impl WorkerPool {
    /// Build a pool of `threads` execution slots. `0` = one slot per
    /// hardware thread; `1` = fully inline (no worker threads at all).
    pub fn new(threads: usize) -> Arc<WorkerPool> {
        let width = match threads {
            0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
            t => t,
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..width)
            .map(|slot| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("detonation-worker-{slot}"))
                    .spawn(move || worker_loop(&shared, slot))
                    .expect("spawn pool worker")
            })
            .collect();
        Arc::new(WorkerPool {
            shared,
            handles,
            width,
        })
    }

    /// The process-wide inline pool (width 1) — the default executor for
    /// code paths that were never handed a trainer pool (tests, tools).
    pub fn inline() -> &'static WorkerPool {
        static INLINE: OnceLock<WorkerPool> = OnceLock::new();
        INLINE.get_or_init(|| WorkerPool {
            shared: Arc::new(Shared {
                state: Mutex::new(PoolState::default()),
                work: Condvar::new(),
                done: Condvar::new(),
            }),
            handles: Vec::new(),
            width: 1,
        })
    }

    /// Number of execution slots; worker indices passed to job closures
    /// are `0..width()` (slot 0 is the submitting thread).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Execute `n_tasks` invocations of `f(worker, task)` across the
    /// pool and block until all have retired. Task→worker assignment is
    /// dynamic (work-stealing off a shared counter) and therefore
    /// nondeterministic — callers must make results depend only on
    /// `task`, never on `worker` (worker-indexed scratch is fine when it
    /// is fully overwritten before use). Panics in `f` are caught,
    /// drained, and re-raised on the submitting thread.
    pub fn run<F>(&self, n_tasks: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        // Inline when the pool is serial, the job is trivial, or we are
        // already inside a pool task (nested dispatch).
        if self.width == 1 || n_tasks == 1 || IN_POOL_TASK.with(|t| t.get()) {
            for t in 0..n_tasks {
                f(0, t);
            }
            return;
        }
        // Safety: `run` blocks below until every task of this job has
        // retired (the job slot is cleared by the last retirer), so the
        // lifetime-erased borrow cannot outlive `f`.
        let f_erased: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(&f as &(dyn Fn(usize, usize) + Sync)) };
        let epoch;
        {
            let mut st = self.shared.state.lock().unwrap();
            // One job at a time: a concurrent submitter queues here until
            // the slot frees (overwriting an in-flight job would let its
            // submitter return while workers still hold the erased
            // closure — soundness, not just correctness).
            while st.job.is_some() {
                st = self.shared.done.wait(st).unwrap();
            }
            st.epoch += 1;
            epoch = st.epoch;
            st.job = Some(Job {
                f: f_erased,
                n_tasks,
                next: 0,
                completed: 0,
                epoch,
            });
            self.shared.work.notify_all();
        }
        // The submitting thread participates as worker slot 0.
        execute_tasks(&self.shared, 0, epoch);
        let mut st = self.shared.state.lock().unwrap();
        while st.job.as_ref().is_some_and(|j| j.epoch == epoch) {
            st = self.shared.done.wait(st).unwrap();
        }
        if let Some(msg) = st.panic_msg.take() {
            drop(st);
            panic!("worker pool task panicked: {msg}");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, slot: usize) {
    let mut last_epoch = 0u64;
    loop {
        let epoch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let fresh = match &st.job {
                    Some(j) if j.epoch != last_epoch => Some(j.epoch),
                    _ => None,
                };
                if let Some(e) = fresh {
                    break e;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        last_epoch = epoch;
        execute_tasks(shared, slot, epoch);
    }
}

/// Pull tasks of job `epoch` until exhausted. Counters live under the
/// mutex; `f` runs outside it. The job slot is cleared (and `done`
/// signalled) by whichever executor retires the last task, so a job
/// pointer can never be dereferenced after `run` returns.
fn execute_tasks(shared: &Shared, slot: usize, epoch: u64) {
    loop {
        let (f, task, n_tasks) = {
            let mut st = shared.state.lock().unwrap();
            match &mut st.job {
                Some(j) if j.epoch == epoch && j.next < j.n_tasks => {
                    let t = j.next;
                    j.next += 1;
                    (j.f, t, j.n_tasks)
                }
                _ => return,
            }
        };
        let result = IN_POOL_TASK.with(|flag| {
            flag.set(true);
            // The erased borrow is alive: the submitting `run` frame is
            // blocked until this task retires below.
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| f(slot, task)));
            flag.set(false);
            r
        });
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic_msg.is_none() {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                st.panic_msg = Some(msg);
            }
        }
        let mut finished = false;
        if let Some(j) = &mut st.job {
            if j.epoch == epoch {
                j.completed += 1;
                finished = j.completed == n_tasks;
            }
        }
        if finished {
            st.job = None;
            shared.done.notify_all();
        }
    }
}

/// Cheap clonable handle threaded through structs that may or may not
/// have been handed a trainer pool; `get` falls back to the process-wide
/// inline executor.
#[derive(Clone, Default)]
pub struct PoolHandle(Option<Arc<WorkerPool>>);

impl PoolHandle {
    pub fn new(pool: Arc<WorkerPool>) -> PoolHandle {
        PoolHandle(Some(pool))
    }

    pub fn get(&self) -> &WorkerPool {
        self.0.as_deref().unwrap_or_else(WorkerPool::inline)
    }
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PoolHandle(width={})", self.get().width())
    }
}

/// Lifetime-erased `&mut [T]` that tasks slice disjoint ranges out of.
///
/// Safety contract: concurrent [`SlicePtr::range`] calls must cover
/// pairwise-disjoint ranges, and no range may outlive the `run` call it
/// was taken inside (the original borrow is frozen for that duration).
pub struct SlicePtr<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SlicePtr<T> {}
unsafe impl<T: Send> Sync for SlicePtr<T> {}

impl<T> std::fmt::Debug for SlicePtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SlicePtr(len={})", self.len)
    }
}

impl<T> SlicePtr<T> {
    pub fn new(s: &mut [T]) -> SlicePtr<T> {
        SlicePtr {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reborrow `[lo, hi)` mutably.
    ///
    /// # Safety
    /// Ranges handed out to concurrently running tasks must be disjoint,
    /// and the underlying buffer must outlive the use (guaranteed when
    /// called inside the `run` whose frame created this `SlicePtr`).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

/// Run `f(worker, lo, hi)` over the fixed chunk grid of `[0, len)`.
pub fn run_chunks<F>(pool: &WorkerPool, len: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let n = chunk_count(len);
    pool.run(n, |w, c| {
        let (lo, hi) = chunk_range(len, c);
        f(w, lo, hi);
    });
}

/// Chunk-parallel `f(chunk_of_out)` over one mutable buffer.
pub fn for_each_chunk<T, F>(pool: &WorkerPool, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    let p = SlicePtr::new(data);
    run_chunks(pool, len, |_w, lo, hi| {
        // Safety: grid chunks are disjoint.
        f(lo, unsafe { p.range(lo, hi) });
    });
}

/// Chunk-parallel zip over one mutable and one shared buffer of equal
/// length: `f(chunk_of_y, chunk_of_x)`.
pub fn zip_chunks<F>(pool: &WorkerPool, y: &mut [f32], x: &[f32], f: F)
where
    F: Fn(&mut [f32], &[f32]) + Sync,
{
    assert_eq!(y.len(), x.len());
    let len = y.len();
    let p = SlicePtr::new(y);
    run_chunks(pool, len, |_w, lo, hi| {
        // Safety: grid chunks are disjoint.
        f(unsafe { p.range(lo, hi) }, &x[lo..hi]);
    });
}

/// Deterministic chunk-grid reduction: `f(lo, hi)` produces the partial
/// of each grid chunk into a slot indexed by *chunk id*; partials are
/// folded sequentially in chunk order, so the result is independent of
/// worker count and scheduling. `partials` is caller-owned scratch
/// (resized here; steady-state callers reuse capacity).
pub fn sum_chunks<F>(pool: &WorkerPool, len: usize, partials: &mut Vec<f64>, f: F) -> f64
where
    F: Fn(usize, usize) -> f64 + Sync,
{
    let n = chunk_count(len);
    partials.clear();
    partials.resize(n, 0.0);
    let p = SlicePtr::new(partials.as_mut_slice());
    pool.run(n, |_w, c| {
        let (lo, hi) = chunk_range(len, c);
        // Safety: one slot per task, disjoint.
        unsafe { p.range(c, c + 1) }[0] = f(lo, hi);
    });
    partials.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn grid_is_exact_and_fixed() {
        for len in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 5 * CHUNK + 17] {
            let n = chunk_count(len);
            let mut covered = 0usize;
            for c in 0..n {
                let (lo, hi) = chunk_range(len, c);
                assert_eq!(lo, covered, "len={len} chunk {c}");
                assert!(hi > lo && hi <= len);
                covered = hi;
            }
            assert_eq!(covered, len, "len={len} grid does not cover");
        }
    }

    #[test]
    fn every_task_runs_exactly_once_at_any_width() {
        for threads in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(threads);
            let n = 257;
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |_w, t| {
                counts[t].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                counts.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = WorkerPool::new(4);
        for round in 0..50 {
            let hits = AtomicUsize::new(0);
            pool.run(round % 7 + 1, |_w, _t| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), round % 7 + 1);
        }
    }

    #[test]
    fn zip_chunks_bit_matches_scalar_at_any_width() {
        let n = 3 * CHUNK + 123;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut want: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        let orig = want.clone();
        for (yi, xi) in want.iter_mut().zip(&x) {
            *yi += 0.5 * *xi;
        }
        for threads in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(threads);
            let mut got = orig.clone();
            zip_chunks(&pool, &mut got, &x, |ys, xs| {
                for (yi, xi) in ys.iter_mut().zip(xs) {
                    *yi += 0.5 * *xi;
                }
            });
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}: zip_chunks diverged from scalar"
            );
        }
    }

    #[test]
    fn sum_chunks_is_width_independent() {
        let n = 7 * CHUNK + 991;
        let data: Vec<f32> = (0..n).map(|i| ((i * 2654435761) as f32).to_bits() as f32 * 1e-30).collect();
        let sum_at = |threads: usize| {
            let pool = WorkerPool::new(threads);
            let mut partials = Vec::new();
            sum_chunks(&pool, n, &mut partials, |lo, hi| {
                data[lo..hi].iter().map(|&x| x as f64).sum()
            })
        };
        let s1 = sum_at(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(s1.to_bits(), sum_at(threads).to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        pool.run(4, |_w, _t| {
            pool.run(3, |_w2, _t2| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn worker_panic_propagates() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |_w, t| {
                if t == 5 {
                    panic!("boom in task 5");
                }
            });
        }));
        let msg = format!("{:?}", r.expect_err("should propagate"));
        assert!(msg.contains("boom in task 5"), "{msg}");
        // the pool survives and remains usable
        let hits = AtomicUsize::new(0);
        pool.run(4, |_w, _t| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn inline_pool_is_width_one_and_static() {
        let a = WorkerPool::inline() as *const WorkerPool;
        let b = WorkerPool::inline() as *const WorkerPool;
        assert_eq!(a, b);
        assert_eq!(WorkerPool::inline().width(), 1);
        let h = PoolHandle::default();
        assert_eq!(h.get().width(), 1);
    }

    #[test]
    fn worker_indices_stay_in_width() {
        let pool = WorkerPool::new(3);
        let bad = AtomicUsize::new(0);
        pool.run(64, |w, _t| {
            if w >= 3 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
            // give other workers a chance to participate
            std::thread::yield_now();
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }
}
