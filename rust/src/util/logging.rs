//! Leveled stderr logger wired into the `log` facade.
//!
//! `DETONATION_LOG={error|warn|info|debug|trace}` controls verbosity
//! (default `info`). Timestamps are relative to process start — this is a
//! simulator, the interesting clock is `net::SimTime`.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Call once from every entrypoint.
pub fn init() {
    let level = match std::env::var("DETONATION_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        level,
    });
    // Ignore AlreadySet — tests may init several times.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
