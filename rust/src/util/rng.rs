//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so this is a from-scratch
//! substrate (DESIGN.md §3): SplitMix64 for seeding/stream-splitting and
//! Xoshiro256++ as the workhorse generator, plus the distributions the
//! framework needs (uniform, normal, zipf, permutations).
//!
//! Determinism is a core *feature*, not a convenience: the Random and
//! Striding replicators regenerate their index sets from `(seed, step,
//! shard)` independently on every rank — the paper's trick for skipping
//! index transfer — so cross-rank bit-identical streams are load-bearing
//! and covered by tests.

/// SplitMix64: tiny, full-period, used to derive seeds and split streams.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (the reference-recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            cached_normal: None,
        }
    }

    /// Derive an independent child stream. Streams for distinct tags are
    /// decorrelated by hashing the tag through SplitMix64.
    pub fn split(&self, tag: u64) -> Rng {
        let mut sm = SplitMix64::new(
            self.s[0] ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17),
        );
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            cached_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) — Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal f32 with mean 0 and given std.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32(std);
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (rejection-free
    /// inverse-CDF over precomputed weights is overkill; this uses the
    /// standard rejection sampler which is exact).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Rejection method (Devroye). Valid for s > 0, s != 1 handled via t.
        debug_assert!(n >= 1);
        let n_f = n as f64;
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = if (s - 1.0).abs() < 1e-9 {
                n_f.powf(u).max(1.0)
            } else {
                let t = n_f.powf(1.0 - s);
                ((t - 1.0) * u + 1.0).powf(1.0 / (1.0 - s))
            };
            let k = x.floor().clamp(1.0, n_f);
            let ratio = (k / x).powf(s);
            if v * ratio <= 1.0 {
                return k as usize - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n), sorted ascending.
    /// Floyd's algorithm: O(k) expected, no O(n) scratch.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// [`Rng::sample_indices`] into a reusable buffer (same draws, same
    /// result). The membership set is still built internally, so this is
    /// not allocation-free — it only spares the output vector.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n, "sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        out.clear();
        out.extend(chosen);
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 (from the published SplitMix64).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_decorrelate() {
        let root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 16];
        for _ in 0..100_000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[3]);
        assert!(counts[3] > counts[10]);
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let k = r.range(0, 64);
            let v = r.sample_indices(64, k);
            assert_eq!(v.len(), k);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(v.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn sample_indices_full() {
        let mut r = Rng::new(17);
        let v = r.sample_indices(10, 10);
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
