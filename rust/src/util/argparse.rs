//! Declarative CLI argument parser (substrate — no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, defaults,
//! required args, and auto-generated `--help`. Used by the `detonation`
//! launcher, every example, and the bench harness.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
    required: bool,
}

/// Builder-style argument parser.
#[derive(Debug, Default)]
pub struct ArgParser {
    program: String,
    about: String,
    opts: Vec<Spec>,
    positionals: Vec<Spec>,
}

/// Parsed argument values.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Extra positionals beyond the declared ones (e.g. bench filters).
    pub rest: Vec<String>,
}

impl ArgParser {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Spec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
            required: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    /// Boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
            required: false,
        });
        self
    }

    /// Declared positional argument (optional; parsed in order).
    pub fn pos(mut self, name: &str, help: &str) -> Self {
        self.positionals.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [OPTIONS]", self.program, self.about, self.program);
        for p in &self.positionals {
            s.push_str(&format!(" [{}]", p.name));
        }
        s.push_str("\n\nOPTIONS:\n");
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else if let Some(d) = &o.default {
                format!("  --{} <v={}>", o.name, d)
            } else {
                format!("  --{} <v> (required)", o.name)
            };
            s.push_str(&format!("{left:<34} {}\n", o.help));
        }
        for p in &self.positionals {
            s.push_str(&format!("  [{}]{:<28} {}\n", p.name, "", p.help));
        }
        s
    }

    /// Parse; on `--help` prints usage and exits 0; on error prints and
    /// exits 2 (launcher behaviour). Use `try_parse` in tests.
    pub fn parse(self, argv: &[String]) -> Args {
        match self.try_parse(argv) {
            Ok(a) => a,
            Err(ParseOutcome::Help(u)) => {
                println!("{u}");
                std::process::exit(0);
            }
            Err(ParseOutcome::Error(e)) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse std::env::args() (skipping argv[0]).
    pub fn parse_env(self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&argv)
    }

    pub fn try_parse(&self, argv: &[String]) -> Result<Args, ParseOutcome> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut rest = Vec::new();
        let mut pos_idx = 0usize;

        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(ParseOutcome::Help(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| ParseOutcome::Error(format!("unknown option --{key}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(ParseOutcome::Error(format!("--{key} takes no value")));
                    }
                    flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| ParseOutcome::Error(format!("--{key} needs a value")))?
                        }
                    };
                    values.insert(key, val);
                }
            } else if pos_idx < self.positionals.len() {
                values.insert(self.positionals[pos_idx].name.clone(), a.clone());
                pos_idx += 1;
            } else {
                rest.push(a.clone());
            }
            i += 1;
        }

        for o in &self.opts {
            if o.required && !values.contains_key(&o.name) {
                return Err(ParseOutcome::Error(format!("missing required --{}", o.name)));
            }
        }
        Ok(Args { values, flags, rest })
    }
}

#[derive(Debug)]
pub enum ParseOutcome {
    Help(String),
    Error(String),
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("argument --{name} not declared/set"))
    }

    pub fn string(&self, name: &str) -> String {
        self.str(name).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.parse_num(name)
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name);
        raw.parse().unwrap_or_else(|e| {
            eprintln!("error: --{name}={raw}: {e}");
            std::process::exit(2);
        })
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        let raw = self.str(name);
        if raw.is_empty() {
            return vec![];
        }
        raw.split(',').map(|s| s.trim().to_string()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn parser() -> ArgParser {
        ArgParser::new("t", "test")
            .opt("steps", "100", "steps")
            .opt("model", "lm-tiny", "model")
            .flag("verbose", "chatty")
            .req("out", "output dir")
            .pos("figure", "figure id")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parser()
            .try_parse(&argv(&["--out", "/tmp/x", "--steps=5"]))
            .unwrap();
        assert_eq!(a.usize("steps"), 5);
        assert_eq!(a.str("model"), "lm-tiny");
        assert_eq!(a.str("out"), "/tmp/x");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parser()
            .try_parse(&argv(&["fig3", "--verbose", "--out", "o", "extra"]))
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.str("figure"), "fig3");
        assert_eq!(a.rest, vec!["extra"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(matches!(
            parser().try_parse(&argv(&[])),
            Err(ParseOutcome::Error(_))
        ));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            parser().try_parse(&argv(&["--nope", "1", "--out", "o"])),
            Err(ParseOutcome::Error(_))
        ));
    }

    #[test]
    fn help_requested() {
        assert!(matches!(
            parser().try_parse(&argv(&["--help"])),
            Err(ParseOutcome::Help(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let a = ArgParser::new("t", "x")
            .opt("rates", "2,4,8", "rates")
            .try_parse(&argv(&[]))
            .unwrap();
        assert_eq!(a.list("rates"), vec!["2", "4", "8"]);
    }
}
