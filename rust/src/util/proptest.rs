//! Mini property-testing driver (substrate — the offline registry has no
//! proptest; DESIGN.md §2 substitution table).
//!
//! Seeded generation + greedy shrinking over a couple of generator shapes
//! covers the invariants this codebase states: routing/partition laws in
//! `shard`, collective algebra in `collectives`, compression round-trips
//! in `compress`, replicator determinism in `replicate`.
//!
//! Usage:
//! ```ignore
//! proptest(64, |g| {
//!     let n = g.usize(1, 100);
//!     let xs = g.vec_f32(n, -10.0, 10.0);
//!     prop_assert(check(&xs), format!("failed on {xs:?}"));
//! });
//! ```

use super::rng::Rng;

/// Generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Trace of raw choices, re-playable for shrinking.
    pub case_id: u64,
}

impl Gen {
    fn new(seed: u64, case: u64) -> Self {
        Self {
            rng: Rng::new(seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            case_id: case,
        }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        if lo >= hi {
            return lo;
        }
        // Bias toward small values (shrink-friendly distribution).
        if self.rng.next_f64() < 0.25 {
            lo + (self.rng.below((hi - lo).min(4) as u64 + 1) as usize).min(hi - lo)
        } else {
            self.rng.range(lo, hi + 1)
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Uniform f64 in [lo, hi) — used by the event-engine properties
    /// (durations, bandwidths, slowdown factors).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, std);
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }

    /// Power-of-two in [2^lo_pow, 2^hi_pow].
    pub fn pow2(&mut self, lo_pow: u32, hi_pow: u32) -> usize {
        1usize << self.rng.range(lo_pow as usize, hi_pow as usize + 1)
    }
}

/// Failure carrying the reproducing case id.
#[derive(Debug)]
pub struct PropFailure {
    pub case_id: u64,
    pub message: String,
}

thread_local! {
    static FAILURE: std::cell::RefCell<Option<String>> = const { std::cell::RefCell::new(None) };
}

/// Assert inside a property; records the message instead of panicking so
/// the driver can report the failing case id.
pub fn prop_assert(cond: bool, msg: impl Into<String>) {
    if !cond {
        FAILURE.with(|f| {
            let mut f = f.borrow_mut();
            if f.is_none() {
                *f = Some(msg.into());
            }
        });
    }
}

/// Approximate float equality helper for properties.
pub fn approx_eq(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

pub fn approx_slice_eq(a: &[f32], b: &[f32], tol: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| approx_eq(*x, *y, tol))
}

/// Run `cases` iterations of `prop`. Panics with the seed + case id of the
/// first failure. Seed comes from DETONATION_PROP_SEED (default 0xD37)
/// so failures reproduce exactly in CI and locally.
pub fn proptest<F: FnMut(&mut Gen)>(cases: u64, mut prop: F) {
    let seed = std::env::var("DETONATION_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD37u64);
    for case in 0..cases {
        FAILURE.with(|f| *f.borrow_mut() = None);
        let mut g = Gen::new(seed, case);
        prop(&mut g);
        let failed = FAILURE.with(|f| f.borrow_mut().take());
        if let Some(msg) = failed {
            panic!(
                "property failed (seed={seed:#x}, case={case}; rerun with \
                 DETONATION_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        proptest(32, |g| {
            let n = g.usize(0, 10);
            prop_assert(n <= 10, "range");
            count += 1;
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        proptest(32, |g| {
            let n = g.usize(0, 100);
            prop_assert(n < 50, format!("n={n}"));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        proptest(8, |g| a.push(g.u64()));
        proptest(8, |g| b.push(g.u64()));
        assert_eq!(a, b);
    }

    #[test]
    fn approx_eq_tolerates_scale() {
        assert!(approx_eq(1000.0, 1000.01, 1e-4));
        assert!(!approx_eq(1.0, 1.1, 1e-4));
    }

    #[test]
    fn f64_generator_respects_bounds() {
        proptest(64, |g| {
            let x = g.f64(2.5, 7.5);
            prop_assert((2.5..7.5).contains(&x), format!("{x} out of range"));
        });
    }
}
