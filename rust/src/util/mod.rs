//! Shared substrates: RNG, JSON, CLI parsing, logging, property testing.
//!
//! The offline registry ships only `xla`/`anyhow`/`thiserror`/`log`, so
//! everything else the framework needs is built here from scratch
//! (DESIGN.md §3 inventory).

pub mod argparse;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven. One
/// shared implementation backs both integrity layers: checkpoint files
/// append it over their payload, and wire [`crate::compress::Payload`]s
/// use it as the corruption-detecting checksum verified at decode.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };
    let mut crc = !0u32;
    for &b in bytes {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// Write `bytes` to `path` atomically: write to `<path>.tmp` in the same
/// directory, then rename over the target. An interrupted writer can
/// never leave a truncated file at `path` — at worst a stale `.tmp`
/// litters the directory. Every `BENCH_*.json` writer and the
/// checkpoint publisher go through here so `scripts/bench_gate.py` and
/// crash-rejoin restores never read a half-written artifact.
pub fn atomic_write(path: &std::path::Path, bytes: &[u8]) -> anyhow::Result<()> {
    use anyhow::Context;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating directory {}", dir.display()))?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

/// Format a byte count human-readably (metrics + bench output).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format seconds of simulated time.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors_and_sensitivity() {
        // Published check values for the IEEE polynomial.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // any single bit flip changes the checksum (CRC-32 guarantee)
        let data = b"detonation payload".to_vec();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base, "flip {byte}:{bit} undetected");
            }
        }
    }

    #[test]
    fn atomic_write_publishes_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("detonation-atomic-write");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("out.json");
        atomic_write(&path, b"{\"ok\": 1}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"ok\": 1}");
        // the staging file is gone after the rename
        assert!(!path.with_extension("json.tmp").exists());
        // overwriting an existing file replaces it whole
        atomic_write(&path, b"{}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{}");
        // writing into an unwritable location errors instead of panicking
        assert!(atomic_write(std::path::Path::new("/proc/definitely/not/here"), b"x").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0 µs");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(300.0), "5.0 min");
    }
}
