//! Shared substrates: RNG, JSON, CLI parsing, logging, property testing.
//!
//! The offline registry ships only `xla`/`anyhow`/`thiserror`/`log`, so
//! everything else the framework needs is built here from scratch
//! (DESIGN.md §3 inventory).

pub mod argparse;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;

/// Format a byte count human-readably (metrics + bench output).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format seconds of simulated time.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0 µs");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(300.0), "5.0 min");
    }
}
