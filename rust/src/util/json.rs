//! Minimal JSON parser + writer (substrate — no serde in the offline
//! registry; DESIGN.md §3).
//!
//! Covers exactly what the framework needs: the artifact manifests
//! emitted by `python/compile/aot.py`, experiment configs, and metrics
//! sinks. Full RFC 8259 value model (object/array/string/number/bool/
//! null), UTF-8 input, `\uXXXX` escapes (incl. surrogate pairs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::new(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (metrics code filters upstream).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
}

impl JsonError {
    fn new(msg: String) -> Self {
        Self { msg }
    }
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error: {}", self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::new(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let cp =
                                0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32;
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(hi as u32).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_values() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ A 😀 ø""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀 ø");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\q\"", "nan"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = parse(r#"{"x":[1,2],"y":{"z":true}}"#).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[0.25, -17, 1e-3, 123456789]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(0.25));
        assert_eq!(a[1].as_f64(), Some(-17.0));
        assert_eq!(a[2].as_f64(), Some(1e-3));
        assert_eq!(a[3].as_usize(), Some(123456789));
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"name":"lm-tiny","params":[{"name":"embed/tok","shape":[256,64],"init":["normal",0.02]}]}"#;
        let v = parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("embed/tok"));
        assert_eq!(
            p.get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![256, 64]
        );
    }
}
