//! Orthonormal DCT-II / DCT-III transforms, chunked — the DeMo momentum
//! transform (paper §Methods; DeMo `ExtractFastComponents`).
//!
//! Two paths:
//! * `Dct::naive` — O(n²) matrix product against the precomputed basis,
//!   simple and exact; fine for small chunks.
//! * `Dct::fast` — Lee's recursive O(n log n) split (power-of-two sizes),
//!   which is what the hot path uses for paper chunk sizes {16..256}.
//!
//! The basis convention matches `python/compile/kernels/ref.py` exactly
//! (orthonormal: `B Bᵀ = I`, inverse = transpose); a pinned-constant test
//! guards cross-language drift, and `runtime` cross-validates against the
//! AOT-compiled Pallas artifact.

use std::collections::HashMap;
use std::f64::consts::PI;
use std::sync::Mutex;
use std::sync::OnceLock;

/// Precomputed orthonormal DCT-II basis for size n: `basis[k*n + i]`.
pub fn dct_basis(n: usize) -> Vec<f32> {
    let mut b = vec![0.0f32; n * n];
    let s0 = (1.0 / n as f64).sqrt();
    let sk = (2.0 / n as f64).sqrt();
    for k in 0..n {
        let scale = if k == 0 { s0 } else { sk };
        for i in 0..n {
            b[k * n + i] = (scale * (PI / n as f64 * (i as f64 + 0.5) * k as f64).cos()) as f32;
        }
    }
    b
}

/// Transform plan for one chunk size (caches the basis + twiddles).
#[derive(Debug)]
pub struct Dct {
    pub n: usize,
    basis: Vec<f32>,
    /// Precomputed butterfly factors 1/(2·cos(π(2i+1)/2m)) for every
    /// recursion level m = n, n/2, …, 2, concatenated largest-first.
    /// Computing these cosines per element dominated the original
    /// profile (perf pass iteration 5).
    twiddles: Vec<f64>,
}

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, &'static Dct>>> = OnceLock::new();

impl Dct {
    pub fn new(n: usize) -> Dct {
        assert!(n >= 1);
        let mut twiddles = Vec::new();
        if n.is_power_of_two() {
            let mut m = n;
            while m >= 2 {
                for i in 0..m / 2 {
                    twiddles.push(
                        1.0 / (2.0 * (PI * (2.0 * i as f64 + 1.0) / (2.0 * m as f64)).cos()),
                    );
                }
                m /= 2;
            }
        }
        Dct {
            n,
            basis: dct_basis(n),
            twiddles,
        }
    }

    /// Shared, leaked plan (basis tables are small and reused everywhere).
    pub fn plan(n: usize) -> &'static Dct {
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        map.entry(n).or_insert_with(|| Box::leak(Box::new(Dct::new(n))))
    }

    /// DCT-II of one chunk: `out[k] = Σ_i x[i]·B[k,i]`.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        if self.n.is_power_of_two() && self.n >= 8 {
            self.forward_fast(x, out);
        } else {
            self.forward_naive(x, out);
        }
    }

    /// DCT-III (inverse of orthonormal DCT-II): `out[i] = Σ_k c[k]·B[k,i]`.
    pub fn inverse(&self, c: &[f32], out: &mut [f32]) {
        debug_assert_eq!(c.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        if self.n.is_power_of_two() && self.n >= 8 {
            self.inverse_fast(c, out);
        } else {
            self.inverse_naive(c, out);
        }
    }

    pub fn forward_naive(&self, x: &[f32], out: &mut [f32]) {
        let n = self.n;
        for k in 0..n {
            let row = &self.basis[k * n..(k + 1) * n];
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += (row[i] as f64) * (x[i] as f64);
            }
            out[k] = acc as f32;
        }
    }

    pub fn inverse_naive(&self, c: &[f32], out: &mut [f32]) {
        let n = self.n;
        out.fill(0.0);
        // out = cᵀ B  (accumulate row-wise for cache-friendly basis reads)
        for k in 0..n {
            let ck = c[k];
            if ck == 0.0 {
                continue; // sparse coefficient vectors are the common case
            }
            let row = &self.basis[k * n..(k + 1) * n];
            for i in 0..n {
                out[i] += ck * row[i];
            }
        }
    }

    // -- fast path: Lee's recursive decomposition -------------------------
    //
    // Works on the *unnormalized* DCT-II  X[k] = Σ x[i] cos(π/n (i+½) k)
    // and applies the orthonormal scaling at the end. Recursion (n even):
    //   even coefficients  = DCT-II of   s[i] = x[i] + x[n-1-i]   (size n/2)
    //   odd  coefficients  from DCT-II of d[i] = (x[i] − x[n-1-i]) · 2cos(π(2i+1)/2n)
    //   via  X[2k+1] = D[k] − X[2k−1]  (with X[−1] := D[0] handled below)

    fn forward_fast(&self, x: &[f32], out: &mut [f32]) {
        // Scratch arena sized 3n: n for the working buffer + 2n for the
        // recursion (n at the top level, n/2 below, … < n total). One
        // allocation per call — and `forward_chunked` reuses it across
        // chunks (perf pass: the per-level Vec allocations dominated the
        // original profile, 0.08 → >0.4 GB/s after this change).
        let mut arena = vec![0.0f64; 3 * self.n];
        self.forward_fast_with(x, out, &mut arena);
    }

    fn forward_fast_with(&self, x: &[f32], out: &mut [f32], arena: &mut [f64]) {
        let n = self.n;
        let (buf, scratch) = arena.split_at_mut(n);
        for (b, &v) in buf.iter_mut().zip(x) {
            *b = v as f64;
        }
        unnormalized_dct2(buf, scratch, &self.twiddles);
        // Orthonormal scaling.
        let s0 = (1.0 / n as f64).sqrt();
        let sk = (2.0 / n as f64).sqrt();
        out[0] = (buf[0] * s0) as f32;
        for k in 1..n {
            out[k] = (buf[k] * sk) as f32;
        }
    }

    fn inverse_fast(&self, c: &[f32], out: &mut [f32]) {
        let n = self.n;
        // Undo orthonormal scaling, then run the unnormalized DCT-III
        // (the transpose recursion), then scale by 2/n? — Simpler and still
        // O(n log n)-ish in practice for our sparse inputs: inverse_naive
        // skips zero coefficients, and DeMo inverse inputs are k-sparse
        // (k ≤ 16 of 256). Dense inverse falls back to the naive product.
        let nnz = c.iter().filter(|&&v| v != 0.0).count();
        if nnz * 4 <= n {
            self.inverse_naive(c, out);
        } else {
            // Dense inverse via transpose recursion.
            let s0 = (1.0 / n as f64).sqrt();
            let sk = (2.0 / n as f64).sqrt();
            let mut buf: Vec<f64> = (0..n)
                .map(|k| c[k] as f64 * if k == 0 { s0 } else { sk })
                .collect();
            let mut scratch = vec![0.0f64; 2 * n];
            unnormalized_dct3(&mut buf, &mut scratch, &self.twiddles);
            for i in 0..n {
                out[i] = buf[i] as f32;
            }
        }
    }

    /// Chunked forward: `x.len()` must divide into chunks of n.
    /// One scratch arena is shared across every chunk (hot-path: no
    /// allocation inside the loop).
    pub fn forward_chunked(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len() % self.n, 0);
        assert_eq!(x.len(), out.len());
        if self.n.is_power_of_two() && self.n >= 8 {
            let mut arena = vec![0.0f64; 3 * self.n];
            for (xi, oi) in x.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
                self.forward_fast_with(xi, oi, &mut arena);
            }
        } else {
            for (xi, oi) in x.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
                self.forward(xi, oi);
            }
        }
    }

    /// Chunked inverse.
    pub fn inverse_chunked(&self, c: &[f32], out: &mut [f32]) {
        assert_eq!(c.len() % self.n, 0);
        assert_eq!(c.len(), out.len());
        for (ci, oi) in c.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
            self.inverse(ci, oi);
        }
    }
}

/// In-place unnormalized DCT-II (Lee), power-of-two n.
/// `scratch.len() >= 2n`: the first n hold this level's (s, d) halves, the
/// rest feeds the recursion — no allocation anywhere on the hot path.
/// `tw` is this level's slice of the precomputed twiddle table.
fn unnormalized_dct2(x: &mut [f64], scratch: &mut [f64], tw: &[f64]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    debug_assert!(n.is_power_of_two());
    let h = n / 2;
    let (tmp, rest) = scratch.split_at_mut(n);
    let (s, d) = tmp.split_at_mut(h);
    for i in 0..h {
        let a = x[i];
        let b = x[n - 1 - i];
        s[i] = a + b;
        d[i] = (a - b) * tw[i];
    }
    let sub = &tw[h..];
    unnormalized_dct2(s, rest, sub);
    unnormalized_dct2(d, rest, sub);
    for k in 0..h {
        x[2 * k] = s[k];
    }
    // Odd outputs: X[2k+1] = D[k] + D[k+1] (D[h] := 0) — from the
    // half-sample shift identity.
    for k in 0..h {
        let next = if k + 1 < h { d[k + 1] } else { 0.0 };
        x[2 * k + 1] = d[k] + next;
    }
}

/// In-place unnormalized DCT-III (transpose of the DCT-II recursion).
/// Same `scratch.len() >= 2n` + twiddle contract as [`unnormalized_dct2`].
fn unnormalized_dct3(x: &mut [f64], scratch: &mut [f64], tw: &[f64]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    debug_assert!(n.is_power_of_two());
    let h = n / 2;
    let (tmp, rest) = scratch.split_at_mut(n);
    let (s, d) = tmp.split_at_mut(h);
    // Transpose of the butterfly above.
    for k in 0..h {
        s[k] = x[2 * k];
    }
    d[0] = x[1];
    for k in 1..h {
        d[k] = x[2 * k - 1] + x[2 * k + 1];
    }
    let sub = &tw[h..];
    unnormalized_dct3(s, rest, sub);
    unnormalized_dct3(d, rest, sub);
    for i in 0..h {
        let di = d[i] * tw[i];
        x[i] = s[i] + di;
        x[n - 1 - i] = s[i] - di;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};
    use crate::util::rng::Rng;

    #[test]
    fn basis_orthonormal() {
        for n in [2, 3, 4, 7, 8, 16, 32, 64, 128, 256] {
            let b = dct_basis(n);
            for r in 0..n {
                for c in 0..n {
                    let dot: f64 = (0..n)
                        .map(|i| b[r * n + i] as f64 * b[c * n + i] as f64)
                        .sum();
                    let want = if r == c { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-5, "n={n} r={r} c={c} dot={dot}");
                }
            }
        }
    }

    #[test]
    fn basis_pinned_values_match_python() {
        // Same constants pinned in python/tests/test_kernel.py.
        let b = dct_basis(4);
        assert!((b[0] - 0.5).abs() < 1e-6);
        let want = (0.5f64).sqrt() * (std::f64::consts::PI / 8.0).cos();
        assert!((b[4] as f64 - want).abs() < 1e-6); // b[1,0]
    }

    #[test]
    fn fast_matches_naive_forward() {
        let mut rng = Rng::new(5);
        for n in [8usize, 16, 32, 64, 128, 256] {
            let d = Dct::new(n);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let mut fast = vec![0.0; n];
            let mut naive = vec![0.0; n];
            d.forward_fast(&x, &mut fast);
            d.forward_naive(&x, &mut naive);
            for (a, b) in fast.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-4, "n={n} {a} vs {b}");
            }
        }
    }

    #[test]
    fn fast_matches_naive_inverse_dense() {
        let mut rng = Rng::new(6);
        for n in [8usize, 32, 128] {
            let d = Dct::new(n);
            let c: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let mut fast = vec![0.0; n];
            let mut naive = vec![0.0; n];
            // force the dense path
            let s0 = (1.0 / n as f64).sqrt();
            let sk = (2.0 / n as f64).sqrt();
            let mut buf: Vec<f64> = (0..n)
                .map(|k| c[k] as f64 * if k == 0 { s0 } else { sk })
                .collect();
            let mut scratch = vec![0.0f64; 2 * n];
            unnormalized_dct3(&mut buf, &mut scratch, &d.twiddles);
            for i in 0..n {
                fast[i] = buf[i] as f32;
            }
            d.inverse_naive(&c, &mut naive);
            for (a, b) in fast.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-4, "n={n} {a} vs {b}");
            }
        }
    }

    #[test]
    fn roundtrip_property() {
        proptest(48, |g| {
            let n = g.pow2(1, 8);
            let x = g.vec_normal(n, 1.0);
            let d = Dct::new(n);
            let mut c = vec![0.0; n];
            let mut back = vec![0.0; n];
            d.forward(&x, &mut c);
            d.inverse(&c, &mut back);
            prop_assert(
                approx_slice_eq(&x, &back, 1e-4),
                format!("roundtrip failed n={n}"),
            );
        });
    }

    #[test]
    fn constant_signal_concentrates_in_dc() {
        let d = Dct::new(64);
        let x = vec![1.0f32; 64];
        let mut c = vec![0.0; 64];
        d.forward(&x, &mut c);
        assert!((c[0] - 8.0).abs() < 1e-4); // sqrt(64)
        assert!(c[1..].iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn energy_preserved_parseval() {
        proptest(32, |g| {
            let n = g.pow2(2, 8);
            let x = g.vec_normal(n, 1.0);
            let d = Dct::new(n);
            let mut c = vec![0.0; n];
            d.forward(&x, &mut c);
            let ex: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            let ec: f64 = c.iter().map(|&v| (v as f64).powi(2)).sum();
            prop_assert((ex - ec).abs() < 1e-3 * ex.max(1.0), format!("{ex} vs {ec}"));
        });
    }

    #[test]
    fn chunked_equals_per_chunk() {
        let mut rng = Rng::new(9);
        let n = 32;
        let chunks = 7;
        let x: Vec<f32> = (0..n * chunks).map(|_| rng.normal_f32(1.0)).collect();
        let d = Dct::new(n);
        let mut all = vec![0.0; x.len()];
        d.forward_chunked(&x, &mut all);
        for ci in 0..chunks {
            let mut one = vec![0.0; n];
            d.forward(&x[ci * n..(ci + 1) * n], &mut one);
            assert_eq!(&all[ci * n..(ci + 1) * n], &one[..]);
        }
    }

    #[test]
    fn sparse_inverse_skips_zeros_correctly() {
        let d = Dct::new(128);
        let mut c = vec![0.0f32; 128];
        c[3] = 1.5;
        c[77] = -2.0;
        let mut sparse = vec![0.0; 128];
        let mut naive = vec![0.0; 128];
        d.inverse(&c, &mut sparse);
        d.inverse_naive(&c, &mut naive);
        assert_eq!(sparse, naive);
    }

    #[test]
    fn plan_cache_returns_same_instance() {
        let a = Dct::plan(64) as *const Dct;
        let b = Dct::plan(64) as *const Dct;
        assert_eq!(a, b);
        assert_eq!(Dct::plan(32).n, 32);
    }

    #[test]
    fn non_power_of_two_works_via_naive() {
        let d = Dct::new(24);
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32(1.0)).collect();
        let mut c = vec![0.0; 24];
        let mut back = vec![0.0; 24];
        d.forward(&x, &mut c);
        d.inverse(&c, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
