//! Orthonormal DCT-II / DCT-III transforms, chunked — the DeMo momentum
//! transform (paper §Methods; DeMo `ExtractFastComponents`).
//!
//! Three paths:
//! * `Dct::naive` — O(n²) matrix product against the precomputed basis,
//!   simple and exact; fine for small chunks.
//! * `Dct::fast` — Lee's recursive O(n log n) split (power-of-two sizes),
//!   kept as the single-chunk reference implementation.
//! * the **blocked multi-chunk** kernels behind `forward_chunked_with` /
//!   `inverse_chunked_with` — the hot path. They run the same Lee
//!   butterflies level-by-level over a whole block of chunks at once, so
//!   each level's twiddle slice is loaded once per block (cache-resident)
//!   instead of once per chunk, and all scratch lives in a reusable
//!   [`DctScratch`] arena: the steady state performs zero heap
//!   allocations. Per chunk the floating-point dag is identical to the
//!   recursive path, so results are bit-identical (tested).
//!
//! `Dct::plan` is lock-free for power-of-two sizes (one `OnceLock` slot
//! per size — the paper's chunk sizes {16..256} all live there); only
//! exotic non-power-of-two sizes fall back to a mutexed map.
//!
//! The basis convention matches `python/compile/kernels/ref.py` exactly
//! (orthonormal: `B Bᵀ = I`, inverse = transpose); a pinned-constant test
//! guards cross-language drift, and `runtime` cross-validates against the
//! AOT-compiled Pallas artifact.

use std::collections::HashMap;
use std::f64::consts::PI;
use std::sync::Mutex;
use std::sync::OnceLock;

use crate::parallel::lanes::{F64x4, F64_LANES};

/// Precomputed orthonormal DCT-II basis for size n: `basis[k*n + i]`.
pub fn dct_basis(n: usize) -> Vec<f32> {
    let mut b = vec![0.0f32; n * n];
    let s0 = (1.0 / n as f64).sqrt();
    let sk = (2.0 / n as f64).sqrt();
    for k in 0..n {
        let scale = if k == 0 { s0 } else { sk };
        for i in 0..n {
            b[k * n + i] = (scale * (PI / n as f64 * (i as f64 + 0.5) * k as f64).cos()) as f32;
        }
    }
    b
}

/// Reusable workspace for the blocked chunked transforms: two ping-pong
/// f64 blocks for the level passes, an f32 segment for sparse scatter,
/// and the dense-chunk batch list. Hold one per worker (it lives inside
/// `compress::Scratch`) and thread it through the `_with` entry points —
/// after warm-up no call allocates.
#[derive(Debug, Default)]
pub struct DctScratch {
    a: Vec<f64>,
    b: Vec<f64>,
    seg: Vec<f32>,
    pending: Vec<usize>,
}

impl DctScratch {
    pub fn new() -> DctScratch {
        DctScratch::default()
    }
}

/// Target f64 elements per blocked pass: two ~64 KiB ping-pong buffers
/// stay cache-resident while `BLOCK_F64 / n` chunks share each pass over
/// the per-level twiddle slice.
const BLOCK_F64: usize = 8192;

/// Transform plan for one chunk size (caches the basis + twiddles).
#[derive(Debug)]
pub struct Dct {
    pub n: usize,
    basis: Vec<f32>,
    /// Precomputed butterfly factors 1/(2·cos(π(2i+1)/2m)) for every
    /// recursion level m = n, n/2, …, 2, concatenated largest-first
    /// (level m starts at offset n−m). Computing these cosines per
    /// element dominated the original profile (perf pass iteration 5).
    twiddles: Vec<f64>,
}

/// Lock-free plan slots for power-of-two sizes up to 2^12 — every hot
/// caller (the paper's chunk sizes are 16..256) takes this path without
/// ever touching a lock after initialization.
const POW2_SLOTS: usize = 13;
static POW2_PLANS: [OnceLock<&'static Dct>; POW2_SLOTS] =
    [const { OnceLock::new() }; POW2_SLOTS];
/// Fallback for non-power-of-two sizes (cold path only).
static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, &'static Dct>>> = OnceLock::new();

impl Dct {
    pub fn new(n: usize) -> Dct {
        assert!(n >= 1);
        let mut twiddles = Vec::new();
        if n.is_power_of_two() {
            let mut m = n;
            while m >= 2 {
                for i in 0..m / 2 {
                    twiddles.push(
                        1.0 / (2.0 * (PI * (2.0 * i as f64 + 1.0) / (2.0 * m as f64)).cos()),
                    );
                }
                m /= 2;
            }
        }
        Dct {
            n,
            basis: dct_basis(n),
            twiddles,
        }
    }

    /// Shared, leaked plan (basis tables are small and reused everywhere).
    /// Power-of-two sizes resolve through a dedicated `OnceLock` slot —
    /// no lock, no contention, safe to hammer from any number of threads
    /// (tested below); other sizes fall back to a mutexed map.
    pub fn plan(n: usize) -> &'static Dct {
        if n.is_power_of_two() {
            let slot = n.trailing_zeros() as usize;
            if slot < POW2_SLOTS {
                return POW2_PLANS[slot].get_or_init(|| Box::leak(Box::new(Dct::new(n))));
            }
        }
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        map.entry(n).or_insert_with(|| Box::leak(Box::new(Dct::new(n))))
    }

    /// Chunks per blocked pass for this size.
    fn block_chunks(&self) -> usize {
        (BLOCK_F64 / self.n).max(1)
    }

    /// DCT-II of one chunk: `out[k] = Σ_i x[i]·B[k,i]`.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        if self.n.is_power_of_two() && self.n >= 8 {
            self.forward_fast(x, out);
        } else {
            self.forward_naive(x, out);
        }
    }

    /// DCT-III (inverse of orthonormal DCT-II): `out[i] = Σ_k c[k]·B[k,i]`.
    pub fn inverse(&self, c: &[f32], out: &mut [f32]) {
        debug_assert_eq!(c.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        if self.n.is_power_of_two() && self.n >= 8 {
            self.inverse_fast(c, out);
        } else {
            self.inverse_naive(c, out);
        }
    }

    pub fn forward_naive(&self, x: &[f32], out: &mut [f32]) {
        let n = self.n;
        for k in 0..n {
            let row = &self.basis[k * n..(k + 1) * n];
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += (row[i] as f64) * (x[i] as f64);
            }
            out[k] = acc as f32;
        }
    }

    pub fn inverse_naive(&self, c: &[f32], out: &mut [f32]) {
        let n = self.n;
        out.fill(0.0);
        // out = cᵀ B  (accumulate row-wise for cache-friendly basis reads)
        for k in 0..n {
            let ck = c[k];
            if ck == 0.0 {
                continue; // sparse coefficient vectors are the common case
            }
            let row = &self.basis[k * n..(k + 1) * n];
            crate::parallel::lanes::axpy(out, ck, row);
        }
    }

    // -- fast path: Lee's recursive decomposition -------------------------
    //
    // Works on the *unnormalized* DCT-II  X[k] = Σ x[i] cos(π/n (i+½) k)
    // and applies the orthonormal scaling at the end. Recursion (n even):
    //   even coefficients  = DCT-II of   s[i] = x[i] + x[n-1-i]   (size n/2)
    //   odd  coefficients  from DCT-II of d[i] = (x[i] − x[n-1-i]) · 2cos(π(2i+1)/2n)
    //   via  X[2k+1] = D[k] − X[2k−1]  (with X[−1] := D[0] handled below)

    fn forward_fast(&self, x: &[f32], out: &mut [f32]) {
        // Scratch arena sized 3n: n for the working buffer + 2n for the
        // recursion (n at the top level, n/2 below, … < n total).
        let mut arena = vec![0.0f64; 3 * self.n];
        self.forward_fast_with(x, out, &mut arena);
    }

    fn forward_fast_with(&self, x: &[f32], out: &mut [f32], arena: &mut [f64]) {
        let n = self.n;
        let (buf, scratch) = arena.split_at_mut(n);
        for (b, &v) in buf.iter_mut().zip(x) {
            *b = v as f64;
        }
        unnormalized_dct2(buf, scratch, &self.twiddles);
        // Orthonormal scaling.
        let s0 = (1.0 / n as f64).sqrt();
        let sk = (2.0 / n as f64).sqrt();
        out[0] = (buf[0] * s0) as f32;
        for k in 1..n {
            out[k] = (buf[k] * sk) as f32;
        }
    }

    fn inverse_fast(&self, c: &[f32], out: &mut [f32]) {
        let n = self.n;
        // DeMo inverse inputs are k-sparse (k ≤ 16 of 256): inverse_naive
        // skips zero coefficients, so the sparse case is O(nnz·n). Dense
        // inverse falls back to the O(n log n) transpose recursion.
        let nnz = c.iter().filter(|&&v| v != 0.0).count();
        if nnz * 4 <= n {
            self.inverse_naive(c, out);
        } else {
            // Dense inverse via transpose recursion.
            let s0 = (1.0 / n as f64).sqrt();
            let sk = (2.0 / n as f64).sqrt();
            let mut buf: Vec<f64> = (0..n)
                .map(|k| c[k] as f64 * if k == 0 { s0 } else { sk })
                .collect();
            let mut scratch = vec![0.0f64; 2 * n];
            unnormalized_dct3(&mut buf, &mut scratch, &self.twiddles);
            for i in 0..n {
                out[i] = buf[i] as f32;
            }
        }
    }

    /// Chunked forward: `x.len()` must divide into chunks of n.
    /// Allocates a fresh [`DctScratch`] — hot callers should hold one and
    /// use [`Dct::forward_chunked_with`] instead.
    pub fn forward_chunked(&self, x: &[f32], out: &mut [f32]) {
        let mut s = DctScratch::new();
        self.forward_chunked_with(x, out, &mut s);
    }

    /// Blocked chunked forward: processes `BLOCK_F64 / n` chunks per pass
    /// over the basis/twiddles. Bit-identical to the recursive per-chunk
    /// path; zero allocations once `s` is warm.
    pub fn forward_chunked_with(&self, x: &[f32], out: &mut [f32], s: &mut DctScratch) {
        assert_eq!(x.len() % self.n, 0);
        assert_eq!(x.len(), out.len());
        let n = self.n;
        if !(n.is_power_of_two() && n >= 8) {
            for (xi, oi) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
                self.forward(xi, oi);
            }
            return;
        }
        let block = self.block_chunks();
        let n_chunks = x.len() / n;
        let mut base = 0usize;
        while base < n_chunks {
            let cnt = block.min(n_chunks - base);
            let (lo, hi) = (base * n, (base + cnt) * n);
            self.forward_block(&x[lo..hi], &mut out[lo..hi], s);
            base += cnt;
        }
    }

    /// One blocked DCT-II pass over `cnt = x.len()/n` chunks at once,
    /// level by level: each level's twiddle slice is loaded once per
    /// block instead of once per chunk. Per chunk the float dag equals
    /// the recursive `unnormalized_dct2`, so outputs are bit-identical.
    fn forward_block(&self, x: &[f32], out: &mut [f32], s: &mut DctScratch) {
        let n = self.n;
        let total = x.len();
        let DctScratch { a, b, .. } = s;
        a.clear();
        a.resize(total, 0.0);
        b.clear();
        b.resize(total, 0.0);
        for (dst, &v) in a.iter_mut().zip(x) {
            *dst = v as f64;
        }
        dct2_block_passes(n, &self.twiddles, a, b);
        // Orthonormal scaling into the f32 output (result lands in `a`:
        // the pass count 2·log2(n) is even).
        let s0 = (1.0 / n as f64).sqrt();
        let sk = (2.0 / n as f64).sqrt();
        for (cseg, oseg) in a.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            oseg[0] = (cseg[0] * s0) as f32;
            for k in 1..n {
                oseg[k] = (cseg[k] * sk) as f32;
            }
        }
    }

    /// Pool-dispatched blocked chunked forward: the fixed `BLOCK_F64`
    /// block grid of [`Dct::forward_chunked_with`] fans out across the
    /// worker pool, each slot transforming its blocks into `ws[slot]`'s
    /// arena. Block boundaries depend only on `(len, n)` — never on the
    /// worker count — and each block runs the exact serial kernel, so
    /// output is bit-identical to the serial path at any `--threads N`.
    /// `ws` must hold at least `pool.width()` arenas.
    pub fn forward_chunked_pooled(
        &self,
        x: &[f32],
        out: &mut [f32],
        pool: &crate::parallel::WorkerPool,
        ws: &mut [DctScratch],
    ) {
        assert_eq!(x.len() % self.n, 0);
        assert_eq!(x.len(), out.len());
        let n = self.n;
        if !(n.is_power_of_two() && n >= 8) {
            for (xi, oi) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
                self.forward(xi, oi);
            }
            return;
        }
        assert!(ws.len() >= pool.width(), "one DctScratch per pool slot");
        let block = self.block_chunks();
        let n_chunks = x.len() / n;
        let n_blocks = n_chunks.div_ceil(block);
        let outp = crate::parallel::SlicePtr::new(out);
        let wsp = crate::parallel::SlicePtr::new(ws);
        pool.run(n_blocks, |w, b| {
            let base = b * block;
            let cnt = block.min(n_chunks - base);
            let (lo, hi) = (base * n, (base + cnt) * n);
            // Safety: blocks are disjoint; slot `w` is owned by exactly
            // one thread for the duration of the job.
            let s = unsafe { &mut wsp.range(w, w + 1)[0] };
            self.forward_block(&x[lo..hi], unsafe { outp.range(lo, hi) }, s);
        });
    }

    /// Chunked inverse. Allocates a fresh [`DctScratch`] — hot callers
    /// should hold one and use [`Dct::inverse_chunked_with`].
    pub fn inverse_chunked(&self, c: &[f32], out: &mut [f32]) {
        let mut s = DctScratch::new();
        self.inverse_chunked_with(c, out, &mut s);
    }

    /// Chunked inverse with reusable scratch: k-sparse chunks use the
    /// zero-skipping accumulation immediately, dense chunks batch into
    /// blocked DCT-III passes. Dispatch (and therefore every float) is
    /// identical to calling [`Dct::inverse`] per chunk.
    pub fn inverse_chunked_with(&self, c: &[f32], out: &mut [f32], s: &mut DctScratch) {
        assert_eq!(c.len() % self.n, 0);
        assert_eq!(c.len(), out.len());
        let n = self.n;
        if !(n.is_power_of_two() && n >= 8) {
            for (ci, oi) in c.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
                self.inverse_naive(ci, oi);
            }
            return;
        }
        let block = self.block_chunks();
        s.pending.clear();
        let n_chunks = c.len() / n;
        for ci in 0..n_chunks {
            let cseg = &c[ci * n..(ci + 1) * n];
            let nnz = cseg.iter().filter(|&&v| v != 0.0).count();
            if nnz * 4 <= n {
                self.inverse_naive(cseg, &mut out[ci * n..(ci + 1) * n]);
            } else {
                s.pending.push(ci);
                if s.pending.len() == block {
                    self.flush_dense_block(c, out, s);
                }
            }
        }
        self.flush_dense_block(c, out, s);
    }

    /// Run the batched dense DCT-III over the chunks queued in
    /// `s.pending` (gather → blocked passes → scatter).
    fn flush_dense_block(&self, c: &[f32], out: &mut [f32], s: &mut DctScratch) {
        if s.pending.is_empty() {
            return;
        }
        let n = self.n;
        let total = s.pending.len() * n;
        let DctScratch { a, b, pending, .. } = s;
        a.clear();
        a.resize(total, 0.0);
        b.clear();
        b.resize(total, 0.0);
        let s0 = (1.0 / n as f64).sqrt();
        let sk = (2.0 / n as f64).sqrt();
        for (slot, &ci) in pending.iter().enumerate() {
            let cseg = &c[ci * n..(ci + 1) * n];
            let aseg = &mut a[slot * n..(slot + 1) * n];
            aseg[0] = cseg[0] as f64 * s0;
            for k in 1..n {
                aseg[k] = cseg[k] as f64 * sk;
            }
        }
        dct3_block_passes(n, &self.twiddles, a, b);
        for (slot, &ci) in pending.iter().enumerate() {
            let aseg = &a[slot * n..(slot + 1) * n];
            let oseg = &mut out[ci * n..(ci + 1) * n];
            for i in 0..n {
                oseg[i] = aseg[i] as f32;
            }
        }
        pending.clear();
    }

    /// Sparse DCT-III of one chunk from (global index, value) pairs whose
    /// indices fall in `[base, base+n)` and ascend (debug-asserted) — the
    /// direct k-term basis accumulation the extract residual uses:
    /// O(k·n) instead of materializing a dense coefficient chunk.
    ///
    /// Bit-identical to [`Dct::inverse`] on the equivalent dense chunk:
    /// k-sparse inputs run the same zero-skipping accumulation as
    /// `inverse_naive`, dense ones (nnz·4 > n) take the O(n log n) path
    /// through the scratch arena.
    pub fn inverse_sparse(
        &self,
        base: u32,
        idx: &[u32],
        vals: &[f32],
        out: &mut [f32],
        s: &mut DctScratch,
    ) {
        let n = self.n;
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(idx.len(), vals.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must ascend");
        debug_assert!(idx.iter().all(|&i| i >= base && ((i - base) as usize) < n));
        let nnz = vals.iter().filter(|&&v| v != 0.0).count();
        if n.is_power_of_two() && n >= 8 && nnz * 4 > n {
            // Dense fallback — identical float chain to `inverse`.
            let DctScratch { a, b, seg, .. } = s;
            seg.clear();
            seg.resize(n, 0.0);
            for (&i, &v) in idx.iter().zip(vals) {
                seg[(i - base) as usize] = v;
            }
            a.clear();
            a.resize(n, 0.0);
            b.clear();
            b.resize(n, 0.0);
            let s0 = (1.0 / n as f64).sqrt();
            let sk = (2.0 / n as f64).sqrt();
            a[0] = seg[0] as f64 * s0;
            for k in 1..n {
                a[k] = seg[k] as f64 * sk;
            }
            dct3_block_passes(n, &self.twiddles, a, b);
            for (o, &v) in out.iter_mut().zip(a.iter()) {
                *o = v as f32;
            }
        } else {
            // Zero-skipping accumulation — the same float chain as
            // `inverse_naive` on the dense chunk (selected indices ascend,
            // matching its ascending-k accumulation order).
            out.fill(0.0);
            for (&i, &v) in idx.iter().zip(vals) {
                if v == 0.0 {
                    continue;
                }
                let k = (i - base) as usize;
                let row = &self.basis[k * n..(k + 1) * n];
                crate::parallel::lanes::axpy(out, v, row);
            }
        }
    }

    /// Pre-blocked reference: recursive per-chunk forward with one shared
    /// arena (the original `forward_chunked`). Kept public so tests and
    /// benches can pin the blocked kernel against it.
    pub fn forward_chunked_recursive(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len() % self.n, 0);
        assert_eq!(x.len(), out.len());
        if self.n.is_power_of_two() && self.n >= 8 {
            let mut arena = vec![0.0f64; 3 * self.n];
            for (xi, oi) in x.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
                self.forward_fast_with(xi, oi, &mut arena);
            }
        } else {
            for (xi, oi) in x.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
                self.forward(xi, oi);
            }
        }
    }

    /// Pre-blocked reference: per-chunk `inverse` (the original
    /// `inverse_chunked`); allocates on dense chunks.
    pub fn inverse_chunked_recursive(&self, c: &[f32], out: &mut [f32]) {
        assert_eq!(c.len() % self.n, 0);
        assert_eq!(c.len(), out.len());
        for (ci, oi) in c.chunks_exact(self.n).zip(out.chunks_exact_mut(self.n)) {
            self.inverse(ci, oi);
        }
    }
}

/// Blocked unnormalized DCT-II over packed segments of size n
/// (power-of-two, ≥ 2). Input in `a`; result lands back in `a` (the pass
/// count 2·log2(n) is even). Per segment this performs exactly the
/// recursion's butterflies (top-down) and interleaves (bottom-up), so the
/// per-chunk float dag — and therefore every output bit — matches
/// [`unnormalized_dct2`]. The inner loops run four lanes at a time on
/// [`F64x4`] (mirrored reads via [`F64x4::load_rev`], recombination via
/// [`F64x4::interleave`]); lanes only regroup the loop iterations, every
/// per-element chain is unchanged, so bit-identity is preserved.
fn dct2_block_passes(n: usize, twiddles: &[f64], a: &mut [f64], b: &mut [f64]) {
    let total = a.len();
    debug_assert_eq!(total, b.len());
    debug_assert_eq!(total % n, 0);
    let (mut cur, mut nxt): (&mut [f64], &mut [f64]) = (a, b);
    // Butterfly passes, top-down (segment size n, n/2, …, 2):
    //   s[i] = x[i] + x[m−1−i];  d[i] = (x[i] − x[m−1−i])·tw_m[i]
    let mut m = n;
    while m >= 2 {
        let h = m / 2;
        let tw = &twiddles[n - m..n - m + h];
        let mut seg = 0usize;
        while seg < total {
            let mut i = 0usize;
            while i + F64_LANES <= h {
                let av = F64x4::load(&cur[seg + i..]);
                let bv = F64x4::load_rev(&cur[seg + m - i - F64_LANES..]);
                (av + bv).store(&mut nxt[seg + i..]);
                ((av - bv) * F64x4::load(&tw[i..])).store(&mut nxt[seg + h + i..]);
                i += F64_LANES;
            }
            while i < h {
                let av = cur[seg + i];
                let bv = cur[seg + m - 1 - i];
                nxt[seg + i] = av + bv;
                nxt[seg + h + i] = (av - bv) * tw[i];
                i += 1;
            }
            seg += m;
        }
        std::mem::swap(&mut cur, &mut nxt);
        m /= 2;
    }
    // Interleave passes, bottom-up (2, 4, …, n):
    //   X[2k] = S[k];  X[2k+1] = D[k] + D[k+1]  (D[h] := 0)
    m = 2;
    while m <= n {
        let h = m / 2;
        let mut seg = 0usize;
        while seg < total {
            let mut k = 0usize;
            // Strictly below h so the `D[h] := 0` edge (and the read of
            // D[k+1]) never lands inside a lane block.
            while k + F64_LANES < h {
                let sv = F64x4::load(&cur[seg + k..]);
                let d0 = F64x4::load(&cur[seg + h + k..]);
                let d1 = F64x4::load(&cur[seg + h + k + 1..]);
                let (even, odd) = sv.interleave(d0 + d1);
                even.store(&mut nxt[seg + 2 * k..]);
                odd.store(&mut nxt[seg + 2 * k + F64_LANES..]);
                k += F64_LANES;
            }
            while k < h {
                nxt[seg + 2 * k] = cur[seg + k];
                let next = if k + 1 < h { cur[seg + h + k + 1] } else { 0.0 };
                nxt[seg + 2 * k + 1] = cur[seg + h + k] + next;
                k += 1;
            }
            seg += m;
        }
        std::mem::swap(&mut cur, &mut nxt);
        m *= 2;
    }
}

/// Blocked unnormalized DCT-III (transpose of [`dct2_block_passes`]):
/// de-interleave top-down, butterfly bottom-up. Input in `a`; result
/// lands back in `a`. Per segment the float dag matches
/// [`unnormalized_dct3`] bit-for-bit. Inner loops run four lanes at a
/// time on [`F64x4`] ([`F64x4::deinterleave`] for the even/odd split,
/// [`F64x4::store_rev`] for the mirrored butterfly write); per-element
/// chains are unchanged, so bit-identity is preserved.
fn dct3_block_passes(n: usize, twiddles: &[f64], a: &mut [f64], b: &mut [f64]) {
    let total = a.len();
    debug_assert_eq!(total, b.len());
    debug_assert_eq!(total % n, 0);
    let (mut cur, mut nxt): (&mut [f64], &mut [f64]) = (a, b);
    // De-interleave passes, top-down:
    //   s[k] = x[2k];  d[0] = x[1];  d[k] = x[2k−1] + x[2k+1]
    let mut m = n;
    while m >= 2 {
        let h = m / 2;
        let mut seg = 0usize;
        while seg < total {
            let mut k = 0usize;
            while k + F64_LANES <= h {
                let p0 = F64x4::load(&cur[seg + 2 * k..]);
                let p1 = F64x4::load(&cur[seg + 2 * k + F64_LANES..]);
                let (even, _) = p0.deinterleave(p1);
                even.store(&mut nxt[seg + k..]);
                k += F64_LANES;
            }
            while k < h {
                nxt[seg + k] = cur[seg + 2 * k];
                k += 1;
            }
            nxt[seg + h] = cur[seg + 1];
            let mut k = 1usize;
            while k + F64_LANES <= h {
                // d[k..k+4] needs x[2k−1..2k+6] odd-index values: two
                // overlapping de-interleaves, one starting a pair early.
                let (_, oa) = F64x4::load(&cur[seg + 2 * k - 2..])
                    .deinterleave(F64x4::load(&cur[seg + 2 * k + 2..]));
                let (_, ob) = F64x4::load(&cur[seg + 2 * k..])
                    .deinterleave(F64x4::load(&cur[seg + 2 * k + F64_LANES..]));
                (oa + ob).store(&mut nxt[seg + h + k..]);
                k += F64_LANES;
            }
            while k < h {
                nxt[seg + h + k] = cur[seg + 2 * k - 1] + cur[seg + 2 * k + 1];
                k += 1;
            }
            seg += m;
        }
        std::mem::swap(&mut cur, &mut nxt);
        m /= 2;
    }
    // Butterfly passes, bottom-up:
    //   x[i] = s[i] + d[i]·tw;  x[m−1−i] = s[i] − d[i]·tw
    m = 2;
    while m <= n {
        let h = m / 2;
        let tw = &twiddles[n - m..n - m + h];
        let mut seg = 0usize;
        while seg < total {
            let mut i = 0usize;
            while i + F64_LANES <= h {
                let sv = F64x4::load(&cur[seg + i..]);
                let di = F64x4::load(&cur[seg + h + i..]) * F64x4::load(&tw[i..]);
                (sv + di).store(&mut nxt[seg + i..]);
                (sv - di).store_rev(&mut nxt[seg + m - i - F64_LANES..]);
                i += F64_LANES;
            }
            while i < h {
                let di = cur[seg + h + i] * tw[i];
                nxt[seg + i] = cur[seg + i] + di;
                nxt[seg + m - 1 - i] = cur[seg + i] - di;
                i += 1;
            }
            seg += m;
        }
        std::mem::swap(&mut cur, &mut nxt);
        m *= 2;
    }
}

/// In-place unnormalized DCT-II (Lee), power-of-two n.
/// `scratch.len() >= 2n`: the first n hold this level's (s, d) halves, the
/// rest feeds the recursion — no allocation anywhere on the hot path.
/// `tw` is this level's slice of the precomputed twiddle table.
fn unnormalized_dct2(x: &mut [f64], scratch: &mut [f64], tw: &[f64]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    debug_assert!(n.is_power_of_two());
    let h = n / 2;
    let (tmp, rest) = scratch.split_at_mut(n);
    let (s, d) = tmp.split_at_mut(h);
    for i in 0..h {
        let a = x[i];
        let b = x[n - 1 - i];
        s[i] = a + b;
        d[i] = (a - b) * tw[i];
    }
    let sub = &tw[h..];
    unnormalized_dct2(s, rest, sub);
    unnormalized_dct2(d, rest, sub);
    for k in 0..h {
        x[2 * k] = s[k];
    }
    // Odd outputs: X[2k+1] = D[k] + D[k+1] (D[h] := 0) — from the
    // half-sample shift identity.
    for k in 0..h {
        let next = if k + 1 < h { d[k + 1] } else { 0.0 };
        x[2 * k + 1] = d[k] + next;
    }
}

/// In-place unnormalized DCT-III (transpose of the DCT-II recursion).
/// Same `scratch.len() >= 2n` + twiddle contract as [`unnormalized_dct2`].
fn unnormalized_dct3(x: &mut [f64], scratch: &mut [f64], tw: &[f64]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    debug_assert!(n.is_power_of_two());
    let h = n / 2;
    let (tmp, rest) = scratch.split_at_mut(n);
    let (s, d) = tmp.split_at_mut(h);
    // Transpose of the butterfly above.
    for k in 0..h {
        s[k] = x[2 * k];
    }
    d[0] = x[1];
    for k in 1..h {
        d[k] = x[2 * k - 1] + x[2 * k + 1];
    }
    let sub = &tw[h..];
    unnormalized_dct3(s, rest, sub);
    unnormalized_dct3(d, rest, sub);
    for i in 0..h {
        let di = d[i] * tw[i];
        x[i] = s[i] + di;
        x[n - 1 - i] = s[i] - di;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{approx_slice_eq, prop_assert, proptest};
    use crate::util::rng::Rng;

    #[test]
    fn basis_orthonormal() {
        for n in [2, 3, 4, 7, 8, 16, 32, 64, 128, 256] {
            let b = dct_basis(n);
            for r in 0..n {
                for c in 0..n {
                    let dot: f64 = (0..n)
                        .map(|i| b[r * n + i] as f64 * b[c * n + i] as f64)
                        .sum();
                    let want = if r == c { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-5, "n={n} r={r} c={c} dot={dot}");
                }
            }
        }
    }

    #[test]
    fn basis_pinned_values_match_python() {
        // Same constants pinned in python/tests/test_kernel.py.
        let b = dct_basis(4);
        assert!((b[0] - 0.5).abs() < 1e-6);
        let want = (0.5f64).sqrt() * (std::f64::consts::PI / 8.0).cos();
        assert!((b[4] as f64 - want).abs() < 1e-6); // b[1,0]
    }

    #[test]
    fn fast_matches_naive_forward() {
        let mut rng = Rng::new(5);
        for n in [8usize, 16, 32, 64, 128, 256] {
            let d = Dct::new(n);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let mut fast = vec![0.0; n];
            let mut naive = vec![0.0; n];
            d.forward_fast(&x, &mut fast);
            d.forward_naive(&x, &mut naive);
            for (a, b) in fast.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-4, "n={n} {a} vs {b}");
            }
        }
    }

    #[test]
    fn fast_matches_naive_inverse_dense() {
        let mut rng = Rng::new(6);
        for n in [8usize, 32, 128] {
            let d = Dct::new(n);
            let c: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let mut fast = vec![0.0; n];
            let mut naive = vec![0.0; n];
            // force the dense path
            let s0 = (1.0 / n as f64).sqrt();
            let sk = (2.0 / n as f64).sqrt();
            let mut buf: Vec<f64> = (0..n)
                .map(|k| c[k] as f64 * if k == 0 { s0 } else { sk })
                .collect();
            let mut scratch = vec![0.0f64; 2 * n];
            unnormalized_dct3(&mut buf, &mut scratch, &d.twiddles);
            for i in 0..n {
                fast[i] = buf[i] as f32;
            }
            d.inverse_naive(&c, &mut naive);
            for (a, b) in fast.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-4, "n={n} {a} vs {b}");
            }
        }
    }

    #[test]
    fn roundtrip_property() {
        proptest(48, |g| {
            let n = g.pow2(1, 8);
            let x = g.vec_normal(n, 1.0);
            let d = Dct::new(n);
            let mut c = vec![0.0; n];
            let mut back = vec![0.0; n];
            d.forward(&x, &mut c);
            d.inverse(&c, &mut back);
            prop_assert(
                approx_slice_eq(&x, &back, 1e-4),
                format!("roundtrip failed n={n}"),
            );
        });
    }

    #[test]
    fn constant_signal_concentrates_in_dc() {
        let d = Dct::new(64);
        let x = vec![1.0f32; 64];
        let mut c = vec![0.0; 64];
        d.forward(&x, &mut c);
        assert!((c[0] - 8.0).abs() < 1e-4); // sqrt(64)
        assert!(c[1..].iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn energy_preserved_parseval() {
        proptest(32, |g| {
            let n = g.pow2(2, 8);
            let x = g.vec_normal(n, 1.0);
            let d = Dct::new(n);
            let mut c = vec![0.0; n];
            d.forward(&x, &mut c);
            let ex: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            let ec: f64 = c.iter().map(|&v| (v as f64).powi(2)).sum();
            prop_assert((ex - ec).abs() < 1e-3 * ex.max(1.0), format!("{ex} vs {ec}"));
        });
    }

    #[test]
    fn chunked_equals_per_chunk() {
        let mut rng = Rng::new(9);
        let n = 32;
        let chunks = 7;
        let x: Vec<f32> = (0..n * chunks).map(|_| rng.normal_f32(1.0)).collect();
        let d = Dct::new(n);
        let mut all = vec![0.0; x.len()];
        d.forward_chunked(&x, &mut all);
        for ci in 0..chunks {
            let mut one = vec![0.0; n];
            d.forward(&x[ci * n..(ci + 1) * n], &mut one);
            assert_eq!(&all[ci * n..(ci + 1) * n], &one[..]);
        }
    }

    #[test]
    fn blocked_forward_bit_matches_recursive() {
        // The blocked kernel must reproduce the recursive reference
        // bit-for-bit, including across multiple block flushes.
        proptest(24, |g| {
            let n = g.pow2(3, 8);
            let n_chunks = g.usize(1, 2 * (BLOCK_F64 / n).max(1) + 3);
            let x = g.vec_normal(n * n_chunks, 1.0);
            let d = Dct::plan(n);
            let mut blocked = vec![0.0f32; x.len()];
            let mut recursive = vec![0.0f32; x.len()];
            d.forward_chunked(&x, &mut blocked);
            d.forward_chunked_recursive(&x, &mut recursive);
            prop_assert(
                blocked == recursive,
                format!("n={n} chunks={n_chunks}: blocked forward diverged"),
            );
        });
    }

    #[test]
    fn pooled_forward_bit_matches_serial_at_any_width() {
        proptest(12, |g| {
            let n = g.pow2(3, 8);
            let n_chunks = g.usize(1, 2 * (BLOCK_F64 / n).max(1) + 3);
            let x = g.vec_normal(n * n_chunks, 1.0);
            let d = Dct::plan(n);
            let mut serial = vec![0.0f32; x.len()];
            d.forward_chunked(&x, &mut serial);
            for threads in [1usize, 2, 4] {
                let pool = crate::parallel::WorkerPool::new(threads);
                let mut ws: Vec<DctScratch> =
                    (0..pool.width()).map(|_| DctScratch::new()).collect();
                let mut pooled = vec![0.0f32; x.len()];
                d.forward_chunked_pooled(&x, &mut pooled, &pool, &mut ws);
                prop_assert(
                    pooled.iter().zip(&serial).all(|(a, b)| a.to_bits() == b.to_bits()),
                    format!("n={n} chunks={n_chunks} threads={threads}: pooled diverged"),
                );
            }
        });
    }

    #[test]
    fn blocked_inverse_bit_matches_recursive() {
        // Mixed sparse/dense chunks: dispatch and floats must match the
        // per-chunk `inverse` exactly.
        proptest(24, |g| {
            let n = g.pow2(3, 7);
            let n_chunks = g.usize(1, 2 * (BLOCK_F64 / n).max(1) + 3);
            let mut c = vec![0.0f32; n * n_chunks];
            for ci in 0..n_chunks {
                // some chunks sparse, some dense
                let nnz = if g.bool() { g.usize(0, n / 8) } else { g.usize(n / 2, n) };
                for _ in 0..nnz {
                    c[ci * n + g.usize(0, n - 1)] = g.f32(-2.0, 2.0);
                }
            }
            let d = Dct::plan(n);
            let mut blocked = vec![0.0f32; c.len()];
            let mut recursive = vec![0.0f32; c.len()];
            d.inverse_chunked(&c, &mut blocked);
            d.inverse_chunked_recursive(&c, &mut recursive);
            prop_assert(
                blocked == recursive,
                format!("n={n} chunks={n_chunks}: blocked inverse diverged"),
            );
        });
    }

    #[test]
    fn inverse_sparse_bit_matches_dense_inverse() {
        proptest(32, |g| {
            let n = g.pow2(3, 7);
            let k = g.usize(1, n);
            let base = (g.usize(0, 7) * n) as u32;
            // ascending distinct local indices, spread across the chunk
            let idx: Vec<u32> = (0..k).map(|j| (j * n / k) as u32).collect();
            let vals: Vec<f32> = (0..k)
                .map(|_| if g.bool() { g.f32(-2.0, 2.0) } else { 0.0 })
                .collect();
            let d = Dct::plan(n);
            let mut dense = vec![0.0f32; n];
            for (&i, &v) in idx.iter().zip(&vals) {
                dense[i as usize] = v;
            }
            let mut want = vec![0.0f32; n];
            d.inverse(&dense, &mut want);
            let gidx: Vec<u32> = idx.iter().map(|&i| i + base).collect();
            let mut got = vec![0.0f32; n];
            let mut s = DctScratch::new();
            d.inverse_sparse(base, &gidx, &vals, &mut got, &mut s);
            prop_assert(got == want, format!("n={n} k={k}: sparse inverse diverged"));
        });
    }

    #[test]
    fn sparse_inverse_skips_zeros_correctly() {
        let d = Dct::new(128);
        let mut c = vec![0.0f32; 128];
        c[3] = 1.5;
        c[77] = -2.0;
        let mut sparse = vec![0.0; 128];
        let mut naive = vec![0.0; 128];
        d.inverse(&c, &mut sparse);
        d.inverse_naive(&c, &mut naive);
        assert_eq!(sparse, naive);
    }

    #[test]
    fn plan_cache_returns_same_instance() {
        let a = Dct::plan(64) as *const Dct;
        let b = Dct::plan(64) as *const Dct;
        assert_eq!(a, b);
        assert_eq!(Dct::plan(32).n, 32);
        // non-power-of-two fallback also caches
        let c = Dct::plan(24) as *const Dct;
        let d = Dct::plan(24) as *const Dct;
        assert_eq!(c, d);
        // huge power of two beyond the slot table still works
        assert_eq!(Dct::plan(1 << 14).n, 1 << 14);
    }

    #[test]
    fn plan_survives_thread_hammer_lock_free() {
        // Satellite: hammer `plan()` from scoped workers across the
        // paper's chunk sizes (plus a mutexed-fallback size) and check
        // every thread resolves each size to the same leaked instance.
        let sizes = [16usize, 32, 64, 128, 256, 24];
        let results: Vec<Vec<(usize, usize)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|t| {
                    scope.spawn(move || {
                        let mut seen = Vec::new();
                        for i in 0..200 {
                            let n = sizes[(t + i) % sizes.len()];
                            let d = Dct::plan(n);
                            assert_eq!(d.n, n);
                            seen.push((n, d as *const Dct as usize));
                        }
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut canonical: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for thread_seen in results {
            for (n, ptr) in thread_seen {
                let entry = canonical.entry(n).or_insert(ptr);
                assert_eq!(*entry, ptr, "plan({n}) returned a second instance");
            }
        }
    }

    #[test]
    fn non_power_of_two_works_via_naive() {
        let d = Dct::new(24);
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32(1.0)).collect();
        let mut c = vec![0.0; 24];
        let mut back = vec![0.0; 24];
        d.forward(&x, &mut c);
        d.inverse(&c, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
