//! Simulated cluster + network cost model (testbed substitute, DESIGN.md §2).
//!
//! The paper's experiments run on 2–64 GPU nodes over 200 Gbps HPC fabric
//! and on a bandwidth-controlled 10–10000 Mbps two-node link (Fig 10).
//! Here, ranks are in-process workers; every collective *really moves the
//! bytes* (so numerics are exact) while time is charged by a deterministic
//! α–β model per link class:
//!
//! ```text
//! t(transfer of B bytes) = α_link + B / β_link
//! ```
//!
//! with separate (α, β) for intra-node (NVLink/Infinity-fabric class) and
//! inter-node (network class) links. Determinism is deliberate: the paper
//! itself refrains from comparing replicator wall-clocks because HPC
//! congestion makes timings unreliable; the simulator removes that noise
//! while preserving every relative claim (volume × schedule).
//!
//! `TrafficMatrix` additionally records who-sent-how-much-to-whom, which
//! regenerates the paper's Appendix-A communication-pattern figure
//! (`figures -- fig7`).

use std::sync::Mutex;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Rank addressing: `rank = node * accels_per_node + accel`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub accels_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, accels_per_node: usize) -> Topology {
        assert!(nodes >= 1 && accels_per_node >= 1);
        Topology {
            nodes,
            accels_per_node,
        }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.accels_per_node
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.accels_per_node
    }

    pub fn accel_of(&self, rank: usize) -> usize {
        rank % self.accels_per_node
    }

    pub fn rank(&self, node: usize, accel: usize) -> usize {
        debug_assert!(node < self.nodes && accel < self.accels_per_node);
        node * self.accels_per_node + accel
    }

    /// The sharding group S of a rank: all ranks on the same node.
    pub fn shard_group(&self, rank: usize) -> Vec<usize> {
        let node = self.node_of(rank);
        (0..self.accels_per_node)
            .map(|a| self.rank(node, a))
            .collect()
    }

    /// The replication group R of a rank: the same accelerator index on
    /// every node (paper Appendix A: "accelerator 0 of node 0 replicates
    /// to accelerator 0 of node 1").
    pub fn repl_group(&self, rank: usize) -> Vec<usize> {
        let accel = self.accel_of(rank);
        (0..self.nodes).map(|n| self.rank(n, accel)).collect()
    }

    /// Link class between two ranks.
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.node_of(a) == self.node_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Slowest link class spanned by a group (a group containing two
    /// different nodes pays inter-node cost).
    pub fn group_link_class(&self, group: &[usize]) -> LinkClass {
        let first = self.node_of(group[0]);
        if group.iter().all(|&r| self.node_of(r) == first) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    IntraNode,
    InterNode,
}

/// α–β parameters for the two link classes + compute throughput.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Intra-node bandwidth, bytes/s (e.g. MI250x infinity fabric 50 GB/s).
    pub intra_bw: f64,
    /// Inter-node bandwidth, bytes/s (200 Gbps = 25 GB/s in the HPC runs;
    /// 10 Mbps..10 Gbps in the Fig 10 sweep).
    pub inter_bw: f64,
    /// Per-message latency (s).
    pub intra_lat: f64,
    pub inter_lat: f64,
    /// Modeled accelerator throughput for the compute-time part of the
    /// step clock, FLOP/s.
    pub device_flops: f64,
}

impl NetModel {
    /// The paper's HPC testbed class: fast fabric both levels.
    pub fn hpc() -> NetModel {
        NetModel {
            intra_bw: 50e9,
            inter_bw: 25e9,
            intra_lat: 5e-6,
            inter_lat: 20e-6,
            device_flops: 100e12,
        }
    }

    /// Fig 10 controlled-bandwidth testbed: 2 nodes, throttled network.
    pub fn throttled(inter_mbps: f64) -> NetModel {
        NetModel {
            inter_bw: inter_mbps * 1e6 / 8.0,
            ..NetModel::hpc()
        }
    }

    /// Paper-regime model for a scaled-down stand-in (DESIGN.md §2).
    ///
    /// Our substitute models are `s = paper_params / params` times smaller
    /// than the paper's, so every payload and every compute phase shrinks
    /// by `s`. Keeping bandwidths and device FLOP/s at the paper's testbed
    /// values and dividing the per-message latencies by `s` makes every
    /// simulated time exactly `t_paper / s` — all *ratios* between
    /// schemes (the reproduction target) are preserved bit-for-bit:
    ///   t_sim = α/s + (B/s)/bw = (α + B/bw)/s.
    ///
    /// Testbed constants: A100-class node (≈110 TFLOP/s sustained),
    /// NVLink-class intra-node (300 GB/s, 3 µs), 2×dual-port HDR
    /// inter-node (400 Gbit/s = 50 GB/s, 20 µs) — the paper's OLMo2 rig.
    pub fn paper_scaled(params: usize, paper_params: f64) -> NetModel {
        let s = (paper_params / params.max(1) as f64).max(1.0);
        NetModel {
            intra_bw: 300e9,
            inter_bw: 50e9,
            intra_lat: 3e-6 / s,
            inter_lat: 20e-6 / s,
            device_flops: 110e12,
        }
    }

    /// Override the inter-node bandwidth (Fig 10 throttling) keeping the
    /// rest of the model.
    pub fn with_inter_mbps(mut self, mbps: f64) -> NetModel {
        self.inter_bw = mbps * 1e6 / 8.0;
        self
    }

    pub fn bw(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraNode => self.intra_bw,
            LinkClass::InterNode => self.inter_bw,
        }
    }

    pub fn lat(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraNode => self.intra_lat,
            LinkClass::InterNode => self.inter_lat,
        }
    }

    /// α–β time of one message of `bytes` over a link class.
    pub fn xfer_time(&self, class: LinkClass, bytes: u64) -> SimTime {
        self.lat(class) + bytes as f64 / self.bw(class)
    }

    /// Modeled compute time for `flops` on one accelerator.
    pub fn compute_time(&self, flops: f64) -> SimTime {
        flops / self.device_flops
    }
}

/// Per-(src-node, dst-node) byte counters + totals. Thread-safe; shared by
/// all collectives in a run.
#[derive(Debug)]
pub struct TrafficMatrix {
    nodes: usize,
    /// bytes[src_node * nodes + dst_node]; diagonal = intra-node traffic.
    bytes: Mutex<Vec<u64>>,
}

impl TrafficMatrix {
    pub fn new(nodes: usize) -> TrafficMatrix {
        TrafficMatrix {
            nodes,
            bytes: Mutex::new(vec![0; nodes * nodes]),
        }
    }

    pub fn record(&self, src_node: usize, dst_node: usize, bytes: u64) {
        let mut m = self.bytes.lock().unwrap();
        m[src_node * self.nodes + dst_node] += bytes;
    }

    pub fn snapshot(&self) -> Vec<u64> {
        self.bytes.lock().unwrap().clone()
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Total bytes that crossed node boundaries (the scarce resource).
    pub fn inter_node_bytes(&self) -> u64 {
        let m = self.bytes.lock().unwrap();
        let mut total = 0;
        for s in 0..self.nodes {
            for d in 0..self.nodes {
                if s != d {
                    total += m[s * self.nodes + d];
                }
            }
        }
        total
    }

    /// Total intra-node bytes (diagonal).
    pub fn intra_node_bytes(&self) -> u64 {
        let m = self.bytes.lock().unwrap();
        (0..self.nodes).map(|i| m[i * self.nodes + i]).sum()
    }

    pub fn reset(&self) {
        self.bytes.lock().unwrap().fill(0);
    }

    /// Render as the Appendix-A-style traffic matrix (fig7).
    pub fn render(&self) -> String {
        let m = self.bytes.lock().unwrap();
        let mut out = String::from("src\\dst ");
        for d in 0..self.nodes {
            out.push_str(&format!("{:>12}", format!("node{d}")));
        }
        out.push('\n');
        for s in 0..self.nodes {
            out.push_str(&format!("node{s:<4}"));
            for d in 0..self.nodes {
                out.push_str(&format!("{:>12}", crate::util::fmt_bytes(m[s * self.nodes + d])));
            }
            out.push('\n');
        }
        out
    }
}

/// A monotonically-advancing simulated clock. Collectives advance it by
/// the *maximum* across participants (bulk-synchronous steps); compute
/// phases advance it by the slowest rank.
#[derive(Debug, Default)]
pub struct SimClock {
    now: Mutex<SimTime>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }

    pub fn now(&self) -> SimTime {
        *self.now.lock().unwrap()
    }

    pub fn advance(&self, dt: SimTime) -> SimTime {
        let mut t = self.now.lock().unwrap();
        *t += dt.max(0.0);
        *t
    }

    pub fn reset(&self) {
        *self.now.lock().unwrap() = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_addressing() {
        let t = Topology::new(3, 4);
        assert_eq!(t.world_size(), 12);
        assert_eq!(t.node_of(7), 1);
        assert_eq!(t.accel_of(7), 3);
        assert_eq!(t.rank(1, 3), 7);
        for r in 0..t.world_size() {
            assert_eq!(t.rank(t.node_of(r), t.accel_of(r)), r);
        }
    }

    #[test]
    fn shard_group_is_intra_node() {
        let t = Topology::new(2, 4);
        assert_eq!(t.shard_group(5), vec![4, 5, 6, 7]);
        assert_eq!(t.group_link_class(&t.shard_group(5)), LinkClass::IntraNode);
    }

    #[test]
    fn repl_group_is_same_accel_across_nodes() {
        let t = Topology::new(3, 4);
        assert_eq!(t.repl_group(5), vec![1, 5, 9]);
        assert_eq!(t.group_link_class(&t.repl_group(5)), LinkClass::InterNode);
    }

    #[test]
    fn repl_and_shard_groups_partition_world() {
        // Every rank appears in exactly one S-group and one R-group slot.
        let t = Topology::new(4, 3);
        let mut seen = vec![0; t.world_size()];
        for n in 0..t.nodes {
            for &r in &t.shard_group(t.rank(n, 0)) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        let mut seen = vec![0; t.world_size()];
        for a in 0..t.accels_per_node {
            for &r in &t.repl_group(t.rank(0, a)) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn xfer_time_alpha_beta() {
        let m = NetModel {
            intra_bw: 100.0,
            inter_bw: 10.0,
            intra_lat: 1.0,
            inter_lat: 2.0,
            device_flops: 1e12,
        };
        assert!((m.xfer_time(LinkClass::IntraNode, 200) - 3.0).abs() < 1e-12);
        assert!((m.xfer_time(LinkClass::InterNode, 200) - 22.0).abs() < 1e-12);
    }

    #[test]
    fn throttled_scales_inter_only() {
        let m = NetModel::throttled(10.0); // 10 Mbps
        assert!((m.inter_bw - 1.25e6).abs() < 1.0);
        assert_eq!(m.intra_bw, NetModel::hpc().intra_bw);
    }

    #[test]
    fn paper_scaled_preserves_time_ratios() {
        // A model s× smaller with s×-smaller payloads must see the same
        // ratio between two transfer sizes as the paper-scale system.
        let paper = NetModel::paper_scaled(1_200_000_000, 1.2e9); // s = 1
        let ours = NetModel::paper_scaled(135_488, 1.2e9);
        let s = 1.2e9 / 135_488.0;
        let b_paper = 33_000_000u64; // 33 MB payload at paper scale
        let b_ours = (b_paper as f64 / s) as u64;
        let tp = paper.xfer_time(LinkClass::InterNode, b_paper);
        let to = ours.xfer_time(LinkClass::InterNode, b_ours);
        assert!((tp / to / s - 1.0).abs() < 0.01, "{}", tp / to / s);
    }

    #[test]
    fn with_inter_mbps_overrides_bandwidth_only() {
        let m = NetModel::paper_scaled(135_488, 1.2e9).with_inter_mbps(10.0);
        assert!((m.inter_bw - 1.25e6).abs() < 1.0);
        assert!(m.inter_lat < 1e-8); // scaled latency kept
    }

    #[test]
    fn traffic_matrix_accounting() {
        let tm = TrafficMatrix::new(2);
        tm.record(0, 1, 100);
        tm.record(1, 0, 50);
        tm.record(0, 0, 1000);
        assert_eq!(tm.inter_node_bytes(), 150);
        assert_eq!(tm.intra_node_bytes(), 1000);
        tm.reset();
        assert_eq!(tm.inter_node_bytes(), 0);
    }

    #[test]
    fn clock_monotone() {
        let c = SimClock::new();
        c.advance(1.5);
        c.advance(-3.0); // clamped
        assert!((c.now() - 1.5).abs() < 1e-12);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn traffic_render_contains_nodes() {
        let tm = TrafficMatrix::new(2);
        tm.record(0, 1, 2048);
        let s = tm.render();
        assert!(s.contains("node0") && s.contains("2.00 KiB"));
    }
}
